"""Deterministic synthetic LM data pipeline.

Batches are a pure function of (seed, step) — the property the
checkpoint/restart machinery relies on: resuming at step k replays exactly
the batch stream a non-failed run would have seen (asserted by the
fault-tolerance tests).  Sharded placement is the caller's job
(dist.sharding.batch_spec); generation itself is host-side numpy to model
an input pipeline that is not part of the compiled step.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 0


class SyntheticLM:
    """Zipf-ish token stream with next-token labels (and stubbed modality
    frontends for the audio/vlm archs)."""

    def __init__(self, cfg: DataConfig, model_cfg: Optional[ModelConfig] = None):
        self.cfg = cfg
        self.model_cfg = model_cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.cfg.seed, step))
        B, S, V = self.cfg.global_batch, self.cfg.seq_len, self.cfg.vocab
        # zipf-like marginal over the vocab, cheap to sample
        u = rng.random((B, S + 1))
        tokens = np.minimum((u ** 3 * V).astype(np.int32), V - 1)
        out = {"tokens": tokens[:, :-1].astype(np.int32),
               "labels": tokens[:, 1:].astype(np.int32)}
        if self.model_cfg is not None and self.model_cfg.frontend == "audio_frames":
            out["frames"] = rng.standard_normal(
                (B, S, self.model_cfg.d_model)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_data(model_cfg: ModelConfig, seq_len: int, global_batch: int,
              seed: int = 0) -> SyntheticLM:
    return SyntheticLM(DataConfig(seq_len, global_batch, model_cfg.vocab,
                                  seed), model_cfg)
