from repro.data.pipeline import DataConfig, SyntheticLM, make_data

__all__ = ["DataConfig", "SyntheticLM", "make_data"]
