"""Encoder-decoder transformer (whisper-large-v3 backbone).

The audio frontend (mel + conv) is a stub per the brief: the encoder
consumes precomputed frame embeddings (B, S_enc, d_model) from
``input_specs``.  Non-causal encoder self-attention, causal decoder
self-attention + cross-attention; layernorm + GELU as in Whisper.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.models.lm import _apply_mlp, _apply_norm, _mlp_spec, _norm_spec
from repro.nn.core import init_params, stack_specs


def enc_block_spec(cfg: ModelConfig) -> Dict:
    return {"ln1": _norm_spec(cfg, cfg.d_model),
            "attn": nn.gqa_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, cfg.qkv_bias),
            "ln2": _norm_spec(cfg, cfg.d_model),
            "mlp": _mlp_spec(cfg)}


def dec_block_spec(cfg: ModelConfig) -> Dict:
    spec = enc_block_spec(cfg)
    spec["ln_x"] = _norm_spec(cfg, cfg.d_model)
    spec["cross"] = nn.gqa_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, cfg.qkv_bias)
    return spec


def model_spec(cfg: ModelConfig) -> Dict:
    return {
        "embed": nn.embedding_spec(cfg.vocab, cfg.d_model),
        "enc_layers": stack_specs(enc_block_spec(cfg), cfg.enc_layers),
        "enc_norm": _norm_spec(cfg, cfg.d_model),
        "dec_layers": stack_specs(dec_block_spec(cfg), cfg.dec_layers),
        "final_norm": _norm_spec(cfg, cfg.d_model),
    }


def init_model(cfg: ModelConfig, key: jax.Array) -> Dict:
    return init_params(model_spec(cfg), key, dtype=jnp.dtype(cfg.dtype))


def _self_attn(cfg, p, x, causal):
    B, S, _ = x.shape
    q, k, v = nn.qkv_project(p, x, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    pos = jnp.arange(S)
    q = nn.apply_rope(q, pos[None, :], cfg.rope_theta)
    k = nn.apply_rope(k, pos[None, :], cfg.rope_theta)
    o = nn.chunked_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk)
    return nn.out_project(p, o)


def _cross_attn(cfg, p, x, enc_out):
    from repro.nn.core import apply_dense
    B, S, _ = x.shape
    Se = enc_out.shape[1]
    q = apply_dense(p["wq"], x).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = apply_dense(p["wk"], enc_out).reshape(B, Se, cfg.n_kv_heads,
                                              cfg.head_dim)
    v = apply_dense(p["wv"], enc_out).reshape(B, Se, cfg.n_kv_heads,
                                              cfg.head_dim)
    o = nn.chunked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    return nn.out_project(p, o)


def encode(params: Dict, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: (B, S_enc, d_model) stubbed frontend embeddings."""
    def body(carry, layer_p):
        x = carry
        x = x + _self_attn(cfg, layer_p["attn"],
                           _apply_norm(cfg, layer_p["ln1"], x), causal=False)
        x = x + _apply_mlp(cfg, layer_p["mlp"],
                           _apply_norm(cfg, layer_p["ln2"], x))
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, frames.astype(jnp.dtype(cfg.dtype)),
                        params["enc_layers"])
    return _apply_norm(cfg, params["enc_norm"], x)


def decode_train(params: Dict, tokens: jax.Array, enc_out: jax.Array,
                 cfg: ModelConfig) -> jax.Array:
    x = nn.apply_embedding(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))

    def body(carry, layer_p):
        h = carry
        h = h + _self_attn(cfg, layer_p["attn"],
                           _apply_norm(cfg, layer_p["ln1"], h), causal=True)
        h = h + _cross_attn(cfg, layer_p["cross"],
                            _apply_norm(cfg, layer_p["ln_x"], h), enc_out)
        h = h + _apply_mlp(cfg, layer_p["mlp"],
                           _apply_norm(cfg, layer_p["ln2"], h))
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = _apply_norm(cfg, params["final_norm"], x)
    return nn.unembed(params["embed"], x)


def forward(params: Dict, frames: jax.Array, tokens: jax.Array,
            cfg: ModelConfig, mesh=None) -> jax.Array:
    return decode_train(params, tokens, encode(params, frames, cfg), cfg)
