"""Model assembly for the assigned architecture families."""

from repro.models import encdec, lm


def for_config(cfg):
    return encdec if cfg.family == "encdec" else lm
