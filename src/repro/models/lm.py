"""Decoder LM assembly: dense GQA / sliding-window / MoE / MLA / SSM /
hybrid families from one composable block vocabulary, with
scan-over-layers + optional remat so the traced HLO contains each distinct
block once (the MaxText pattern — essential for the 512-device dry-run).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.configs.base import ModelConfig
from repro.nn.attention import NO_WINDOW
from repro.nn.core import init_params, stack_specs
from repro.nn.mla import MLAConfig
from repro.nn.moe import MoEConfig
from repro.nn.ssm import SSMConfig


# ---------------------------------------------------------------------------
# config adapters
# ---------------------------------------------------------------------------

def mla_config(cfg: ModelConfig) -> MLAConfig:
    return MLAConfig(d_model=cfg.d_model, n_heads=cfg.n_heads,
                     kv_lora_rank=cfg.kv_lora_rank,
                     qk_nope_dim=cfg.qk_nope_dim,
                     qk_rope_dim=cfg.qk_rope_dim,
                     v_head_dim=cfg.v_head_dim,
                     rope_theta=cfg.rope_theta)


def moe_config(cfg: ModelConfig) -> MoEConfig:
    return MoEConfig(n_experts=cfg.n_experts, top_k=cfg.top_k,
                     d_model=cfg.d_model, d_ff=cfg.moe_d_ff,
                     n_shared=cfg.n_shared_experts,
                     shared_d_ff=cfg.n_shared_experts * cfg.moe_d_ff,
                     capacity_factor=cfg.capacity_factor)


def ssm_config(cfg: ModelConfig) -> SSMConfig:
    return SSMConfig(d_model=cfg.d_model, d_inner=cfg.d_inner,
                     n_heads=cfg.ssm_heads, head_p=cfg.ssm_head_p,
                     n_groups=cfg.ssm_groups, d_state=cfg.ssm_state)


def _norm_spec(cfg: ModelConfig, d: int) -> Dict:
    return (nn.layernorm_spec(d) if cfg.norm == "layernorm"
            else nn.rmsnorm_spec(d))


def _apply_norm(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    return (nn.apply_layernorm(p, x) if cfg.norm == "layernorm"
            else nn.apply_rmsnorm(p, x))


def _mlp_spec(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    d_ff = d_ff or cfg.d_ff
    return (nn.gelu_mlp_spec(cfg.d_model, d_ff) if cfg.mlp == "gelu"
            else nn.swiglu_spec(cfg.d_model, d_ff))


def _apply_mlp(cfg: ModelConfig, p: Dict, x: jax.Array,
               tp_axis: Optional[str] = None) -> jax.Array:
    return (nn.apply_gelu_mlp(p, x, tp_axis=tp_axis) if cfg.mlp == "gelu"
            else nn.apply_swiglu(p, x, tp_axis=tp_axis))


# ---------------------------------------------------------------------------
# block specs
# ---------------------------------------------------------------------------

def attn_spec(cfg: ModelConfig) -> Dict:
    if cfg.mla:
        return nn.mla_spec(mla_config(cfg))
    spec = nn.gqa_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                       cfg.head_dim, cfg.qkv_bias)
    if cfg.qk_norm:
        spec["q_norm"] = nn.rmsnorm_spec(cfg.head_dim, None)
        spec["k_norm"] = nn.rmsnorm_spec(cfg.head_dim, None)
    return spec


def dense_block_spec(cfg: ModelConfig) -> Dict:
    return {"ln1": _norm_spec(cfg, cfg.d_model),
            "attn": attn_spec(cfg),
            "ln2": _norm_spec(cfg, cfg.d_model),
            "mlp": _mlp_spec(cfg)}


def moe_block_spec(cfg: ModelConfig) -> Dict:
    return {"ln1": _norm_spec(cfg, cfg.d_model),
            "attn": attn_spec(cfg),
            "ln2": _norm_spec(cfg, cfg.d_model),
            "moe": nn.moe_spec(moe_config(cfg))}


def ssm_block_spec(cfg: ModelConfig) -> Dict:
    return {"ln1": _norm_spec(cfg, cfg.d_model),
            "ssm": nn.ssm_spec(ssm_config(cfg))}


def model_spec(cfg: ModelConfig) -> Dict:
    spec: Dict = {"embed": nn.embedding_spec(cfg.vocab, cfg.d_model),
                  "final_norm": _norm_spec(cfg, cfg.d_model)}
    if not cfg.tie_embeddings:
        spec["lm_head"] = nn.lm_head_spec(cfg.d_model, cfg.vocab)

    if cfg.family == "dense":
        spec["layers"] = stack_specs(dense_block_spec(cfg), cfg.n_layers)
    elif cfg.family == "moe":
        n_moe = cfg.n_layers - cfg.first_dense_layers
        if cfg.first_dense_layers:
            spec["dense_layers"] = stack_specs(dense_block_spec(cfg),
                                               cfg.first_dense_layers)
        spec["layers"] = stack_specs(moe_block_spec(cfg), n_moe)
    elif cfg.family == "ssm":
        spec["layers"] = stack_specs(ssm_block_spec(cfg), cfg.n_layers)
    elif cfg.family == "hybrid":
        spec["layers"] = stack_specs(ssm_block_spec(cfg), cfg.n_layers)
        spec["shared_block"] = dense_block_spec(cfg)
    else:
        raise ValueError(f"model_spec: unsupported family {cfg.family}")
    return spec


def init_model(cfg: ModelConfig, key: jax.Array) -> Dict:
    return init_params(model_spec(cfg), key, dtype=jnp.dtype(cfg.dtype))


# ---------------------------------------------------------------------------
# forward blocks
# ---------------------------------------------------------------------------

def window_schedule(cfg: ModelConfig) -> np.ndarray:
    """Per-layer attention window (NO_WINDOW = global).  Gemma-style: every
    ``global_every``-th layer (1-indexed) is global, the rest local.
    Host-side numpy: consumed statically by the decode path and as traced
    scan xs by the training path."""
    if cfg.window is None:
        return np.full((cfg.n_layers,), NO_WINDOW, np.int32)
    idx = np.arange(cfg.n_layers)
    is_global = (idx % cfg.global_every) == (cfg.global_every - 1) \
        if cfg.global_every else np.zeros((cfg.n_layers,), bool)
    return np.where(is_global, NO_WINDOW, cfg.window).astype(np.int32)


def apply_attn(cfg: ModelConfig, p: Dict, x: jax.Array, *,
               window=NO_WINDOW, q_offset: int = 0,
               causal: bool = True, tp_axis: Optional[str] = None
               ) -> jax.Array:
    if cfg.mla:
        return nn.apply_mla(p, x, mla_config(cfg), causal=causal,
                            q_offset=q_offset, chunk=cfg.attn_chunk)
    B, S, _ = x.shape
    # explicit TP (inside a shard_map over tp_axis): the projection weights
    # are head shards, so the local head counts come from the *local* shard
    # shapes; the output projection psums the per-shard partials
    if tp_axis is None:
        n_heads, n_kv = cfg.n_heads, cfg.n_kv_heads
    else:
        n_heads = p["wq"]["w"].shape[1] // cfg.head_dim
        n_kv = p["wk"]["w"].shape[1] // cfg.head_dim
    q, k, v = nn.qkv_project(p, x, n_heads, n_kv, cfg.head_dim)
    if cfg.qk_norm:
        q = nn.apply_rmsnorm(p["q_norm"], q)
        k = nn.apply_rmsnorm(p["k_norm"], k)
    positions = q_offset + jnp.arange(S)
    q = nn.apply_rope(q, positions[None, :], cfg.rope_theta)
    k = nn.apply_rope(k, positions[None, :], cfg.rope_theta)
    o = nn.chunked_attention(q, k, v, causal=causal, window=window,
                             chunk=cfg.attn_chunk, q_offset=q_offset)
    return nn.out_project(p, o, tp_axis=tp_axis)


def dense_block(cfg: ModelConfig, p: Dict, x: jax.Array, *,
                window=NO_WINDOW, mesh=None,
                tp_axis: Optional[str] = None) -> jax.Array:
    x = x + apply_attn(cfg, p["attn"], _apply_norm(cfg, p["ln1"], x),
                       window=window, tp_axis=tp_axis)
    x = x + _apply_mlp(cfg, p["mlp"], _apply_norm(cfg, p["ln2"], x),
                       tp_axis=tp_axis)
    return x


def moe_block(cfg: ModelConfig, p: Dict, x: jax.Array, *,
              window=NO_WINDOW, mesh=None,
              tp_axis: Optional[str] = None) -> jax.Array:
    x = x + apply_attn(cfg, p["attn"], _apply_norm(cfg, p["ln1"], x),
                       window=window, tp_axis=tp_axis)
    x = x + nn.apply_moe(p["moe"], _apply_norm(cfg, p["ln2"], x),
                         moe_config(cfg), mesh=mesh)
    return x


def ssm_block(cfg: ModelConfig, p: Dict, x: jax.Array, **_) -> jax.Array:
    return x + nn.apply_ssm(p["ssm"], _apply_norm(cfg, p["ln1"], x),
                            ssm_config(cfg))


_BLOCK_OF = {"dense": dense_block, "moe": moe_block, "ssm": ssm_block}


def stage_forward(cfg: ModelConfig, stacked: Dict, x: jax.Array,
                  windows: Optional[jnp.ndarray] = None,
                  tp_axis: Optional[str] = None) -> jax.Array:
    """Apply a contiguous sub-stack of decoder blocks — one pipeline stage.

    ``stacked`` holds this stage's layers with a leading layer dim (any
    length that the leaves agree on); ``windows`` is the matching slice of
    :func:`window_schedule` for attention families (may be traced — the
    pipeline step slices it by ``axis_index`` inside shard_map).  Runs with
    ``mesh=None``: the pipeline step owns all collectives explicitly.
    ``tp_axis`` names the tensor-parallel mesh axis when the stage runs
    inside a shard_map over a ``pipe × model`` mesh: the attention/MLP
    weights are then head-/column-shards and the blocks psum their partial
    projections over it (see ``repro.nn.layers`` / ``repro.nn.attention``).
    """
    block = _BLOCK_OF.get(cfg.family)
    if block is None:
        raise ValueError(f"stage_forward: unsupported family {cfg.family}")
    if cfg.family == "ssm":
        windows = None   # ssm blocks take no attention window
        if tp_axis is not None:
            raise ValueError("stage_forward: ssm blocks have no TP path")
    return _scan_layers(cfg, block, stacked, x, windows=windows,
                        tp_axis=tp_axis)


def head_forward(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Final norm + (tied) unembedding: residual stream -> logits."""
    x = _apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        return nn.unembed(params["embed"], x)
    return nn.apply_lm_head(params["lm_head"], x)


def embed_forward(params: Dict, tokens: jax.Array,
                  cfg: ModelConfig) -> jax.Array:
    """Token embedding (with the gemma sqrt(d) scale) -> residual stream."""
    x = nn.apply_embedding(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    if cfg.name.startswith("gemma"):
        x = x * (cfg.d_model ** 0.5)   # gemma embeds are sqrt(d)-scaled
    return x


# ---------------------------------------------------------------------------
# full forward (train / prefill)
# ---------------------------------------------------------------------------

def _sp_constraint(cfg: ModelConfig, x: jax.Array, mesh):
    """Sequence-parallel residual stream: between blocks, activations live
    sequence-sharded on the model axis so norms/router/elementwise work is
    1/TP of the replicated cost and the TP collectives become
    all-gather/reduce-scatter pairs (Megatron-SP)."""
    if not (cfg.seq_parallel and mesh is not None
            and "model" in mesh.axis_names):
        return x
    from jax.sharding import PartitionSpec as P
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(dp, "model", None)))


def _scan_layers(cfg: ModelConfig, block, stacked: Dict, x: jax.Array,
                 windows: Optional[jnp.ndarray] = None,
                 mesh=None, tp_axis: Optional[str] = None) -> jax.Array:
    body = functools.partial(block, cfg, mesh=mesh)
    if tp_axis is not None:   # ssm_block has no tp_axis kwarg; only bind
        body = functools.partial(body, tp_axis=tp_axis)  # it when in use

    def scan_fn(carry, xs):
        carry = _sp_constraint(cfg, carry, mesh)
        if windows is not None:
            layer_p, win = xs
            out = body(layer_p, carry, window=win)
        else:
            out = body(xs, carry)
        return out, None

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_saveable
                  if cfg.remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        scan_fn = jax.checkpoint(scan_fn, policy=policy)
    xs = (stacked, windows) if windows is not None else stacked
    x, _ = jax.lax.scan(scan_fn, x, xs)
    return x


def forward(params: Dict, tokens: jax.Array, cfg: ModelConfig,
            mesh=None) -> jax.Array:
    """tokens (B, S) -> logits (B, S, vocab).  Works for every decoder
    family; whisper lives in repro.models.encdec."""
    x = embed_forward(params, tokens, cfg)

    if cfg.family == "dense":
        x = _scan_layers(cfg, dense_block, params["layers"], x,
                         windows=window_schedule(cfg), mesh=mesh)
    elif cfg.family == "moe":
        if cfg.first_dense_layers:
            x = _scan_layers(cfg, dense_block, params["dense_layers"], x,
                             windows=window_schedule(cfg)
                             [: cfg.first_dense_layers], mesh=mesh)
        x = _scan_layers(cfg, moe_block, params["layers"], x,
                         windows=window_schedule(cfg)
                         [cfg.first_dense_layers:], mesh=mesh)
    elif cfg.family == "ssm":
        x = _scan_layers(cfg, ssm_block, params["layers"], x, mesh=mesh)
    elif cfg.family == "hybrid":
        x = _hybrid_forward(params, x, cfg, mesh)
    else:
        raise ValueError(cfg.family)

    return head_forward(params, x, cfg)


def _hybrid_forward(params: Dict, x: jax.Array, cfg: ModelConfig,
                    mesh) -> jax.Array:
    """Zamba2: scan groups of ``shared_attn_every`` Mamba2 layers, applying
    the single shared attention+MLP block after each group."""
    k = cfg.shared_attn_every
    assert cfg.n_layers % k == 0, (cfg.n_layers, k)
    n_groups = cfg.n_layers // k
    grouped = jax.tree.map(
        lambda a: a.reshape((n_groups, k) + a.shape[1:]), params["layers"])
    shared = params["shared_block"]

    def group_fn(carry, group_params):
        def inner(c, layer_p):
            return ssm_block(cfg, layer_p, c), None
        h, _ = jax.lax.scan(inner, carry, group_params)
        h = dense_block(cfg, shared, h, mesh=mesh)
        return h, None

    if cfg.remat:
        group_fn = jax.checkpoint(group_fn,
                                  policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(group_fn, x, grouped)
    return x
