from repro.serve.decode import (decode_step, init_caches, init_paged_caches,
                                paged_cache_kinds, paged_decode_step)
from repro.serve.engine import ServeEngine, generate, schedule_plan
from repro.serve.loadgen import TrafficConfig, poisson_trace, run_load
from repro.serve.pool import KVBlockPool, PoolCapacityError, PoolError
from repro.serve.scheduler import FairScheduler, Request, Tenant

__all__ = ["decode_step", "init_caches", "init_paged_caches",
           "paged_cache_kinds", "paged_decode_step", "generate",
           "ServeEngine", "schedule_plan", "KVBlockPool",
           "PoolCapacityError", "PoolError", "FairScheduler", "Request",
           "Tenant", "TrafficConfig", "poisson_trace", "run_load"]
