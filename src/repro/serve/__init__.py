from repro.serve.decode import decode_step, init_caches
from repro.serve.engine import generate

__all__ = ["decode_step", "init_caches", "generate"]
