"""Serving: KV caches + single-token decode steps for every family.

Decode is deliberately *unrolled* over layers (unlike the scanned training
path): each layer owns its cache pytree, so per-layer cache shapes can
differ — gemma's local layers keep a bounded ``window``-sized ring buffer
while its global layers keep the full sequence; mamba layers keep an O(1)
recurrent state.  The decode HLO is tiny per layer, so unrolling stays
cheap to compile while making the memory roofline of ``decode_32k`` /
``long_500k`` honest.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.models.lm import (_apply_mlp, _apply_norm, mla_config, moe_config,
                             ssm_config, window_schedule)
from repro.nn.attention import NO_WINDOW
from repro.nn.mla import apply_mla_decode, init_mla_cache
from repro.nn.ssm import apply_ssm_decode, init_ssm_cache

_NEG = -1e30


def _layer_params(stacked: Dict, i: int) -> Dict:
    return jax.tree.map(lambda a: a[i], stacked)


def _cache_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def _attn_cache(cfg: ModelConfig, batch: int, length: int) -> Dict:
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, _cache_dtype(cfg)),
            "v": jnp.zeros(shape, _cache_dtype(cfg))}


def init_caches(cfg: ModelConfig, batch: int, max_seq: int) -> List:
    """One cache pytree per layer (family-dependent shapes)."""
    caches: List = []
    if cfg.family in ("dense", "moe"):
        wins = [int(w) for w in window_schedule(cfg)]
        for li in range(cfg.n_layers):
            if cfg.mla:
                caches.append(init_mla_cache(mla_config(cfg), batch, max_seq,
                                             _cache_dtype(cfg)))
            else:
                length = max_seq if wins[li] >= NO_WINDOW \
                    else min(wins[li], max_seq)
                caches.append(_attn_cache(cfg, batch, length))
    elif cfg.family == "ssm":
        for _ in range(cfg.n_layers):
            caches.append(init_ssm_cache(ssm_config(cfg), batch,
                                         _cache_dtype(cfg)))
    elif cfg.family == "hybrid":
        for _ in range(cfg.n_layers):
            caches.append(init_ssm_cache(ssm_config(cfg), batch,
                                         _cache_dtype(cfg)))
        for _ in range(cfg.n_layers // cfg.shared_attn_every):
            caches.append(_attn_cache(cfg, batch, max_seq))
    elif cfg.family == "encdec":
        from repro.configs.whisper_large_v3 import ENC_LEN_DECODE
        for _ in range(cfg.dec_layers):
            c = _attn_cache(cfg, batch, max_seq)
            c["ck"] = jnp.zeros((batch, ENC_LEN_DECODE, cfg.n_kv_heads,
                                 cfg.head_dim), _cache_dtype(cfg))
            c["cv"] = jnp.zeros_like(c["ck"])
            caches.append(c)
    else:
        raise ValueError(cfg.family)
    return caches


# ---------------------------------------------------------------------------
# per-layer decode attention
# ---------------------------------------------------------------------------

def _positions(pos, batch: int) -> jax.Array:
    """(B, 1) rope positions from a scalar or per-row ``(B,)`` pos."""
    pos = jnp.asarray(pos, jnp.int32)
    return pos[:, None] if pos.ndim >= 1 else jnp.full((batch, 1), pos)


def _attn_decode(cfg: ModelConfig, p: Dict, x: jax.Array, cache: Dict,
                 pos, window: int) -> Tuple[jax.Array, Dict]:
    """x: (B, 1, d); ring buffer for local windows, absolute cache else.

    ``pos`` is a scalar (all rows at the same position — static batch)
    or a ``(B,)`` vector (ragged rows — continuous batching), in which
    case the key mask becomes per-row ``(B, S)``."""
    from repro.nn.core import apply_dense
    B = x.shape[0]
    q = apply_dense(p["wq"], x).reshape(B, 1, cfg.n_heads, cfg.head_dim)
    k = apply_dense(p["wk"], x).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
    v = apply_dense(p["wv"], x).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = nn.apply_rmsnorm(p["q_norm"], q)
        k = nn.apply_rmsnorm(p["k_norm"], k)
    positions = _positions(pos, B)
    q = nn.apply_rope(q, positions, cfg.rope_theta)
    k = nn.apply_rope(k, positions, cfg.rope_theta)

    ragged = jnp.asarray(pos).ndim >= 1
    S = cache["k"].shape[1]
    ring = window < NO_WINDOW and S <= window
    if ring:
        k_cache = jnp.concatenate([cache["k"][:, 1:], k.astype(cache["k"].dtype)],
                                  axis=1)
        v_cache = jnp.concatenate([cache["v"][:, 1:], v.astype(cache["v"].dtype)],
                                  axis=1)
        if ragged:
            k_positions = positions - (S - 1) + jnp.arange(S)[None]  # (B,S)
        else:
            k_positions = pos - (S - 1) + jnp.arange(S)
        mask = k_positions >= 0
    else:
        k_cache = nn.update_cache(cache["k"], k, pos)
        v_cache = nn.update_cache(cache["v"], v, pos)
        if ragged:
            k_positions = jnp.arange(S)[None]                        # (B,S)
            mask = (k_positions <= positions) & \
                   (k_positions > positions - window)
        else:
            k_positions = jnp.arange(S)
            mask = (k_positions <= pos) & (k_positions > pos - window)

    o = _masked_decode_attn(q, k_cache, v_cache, mask)
    out = nn.out_project(p, o)
    return out, {"k": k_cache, "v": v_cache, **{kk: vv for kk, vv in
                                                cache.items()
                                                if kk not in ("k", "v")}}


def _masked_decode_attn(q, k_cache, v_cache, mask):
    """mask: (S,) shared across rows, or (B, S) per-row (ragged pos)."""
    B, _, H, D = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    qf = q.astype(jnp.float32) * (D ** -0.5)
    logits = jnp.einsum("bqhgd,bshd->bhgqs", qf.reshape(B, 1, KH, G, D),
                        k_cache.astype(jnp.float32))
    maskb = (mask[None, None, None, None] if mask.ndim == 1
             else mask[:, None, None, None, :])
    logits = jnp.where(maskb, logits, _NEG)
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    ell = p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhgqs,bshd->bhgqd", p, v_cache.astype(jnp.float32)) / ell
    return o.transpose(0, 3, 1, 2, 4).reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def decode_step(params: Dict, caches: List, token: jax.Array, pos,
                cfg: ModelConfig, mesh=None,
                enc_out: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, List]:
    """token (B, 1) int32 -> logits (B, vocab); updates caches.

    ``pos`` is a scalar (all rows decode the same position — the static
    generate path) or a ``(B,)`` int vector of per-row positions (the
    continuous-batching ragged path; each row reads/writes its own cache
    position).  SSM/recurrent layers carry no position and advance one
    step per call either way."""
    x = nn.apply_embedding(params["embed"], token).astype(jnp.dtype(cfg.dtype))
    if cfg.name.startswith("gemma"):
        x = x * (cfg.d_model ** 0.5)   # gemma scales embeddings (as forward)
    new_caches = list(caches)
    wins = [int(w) for w in window_schedule(cfg)] \
        if cfg.family in ("dense", "moe") else []

    if cfg.family in ("dense", "moe"):
        dense_head = cfg.first_dense_layers if cfg.family == "moe" else 0
        for li in range(cfg.n_layers):
            if cfg.family == "moe" and li >= dense_head:
                p = _layer_params(params["layers"], li - dense_head)
            elif cfg.family == "moe":
                p = _layer_params(params["dense_layers"], li)
            else:
                p = _layer_params(params["layers"], li)
            h = _apply_norm(cfg, p["ln1"], x)
            if cfg.mla:
                a, new_caches[li] = apply_mla_decode(p["attn"], h,
                                                     caches[li], pos,
                                                     mla_config(cfg))
            else:
                a, new_caches[li] = _attn_decode(cfg, p["attn"], h,
                                                 caches[li], pos, wins[li])
            x = x + a
            h = _apply_norm(cfg, p["ln2"], x)
            if cfg.family == "moe" and li >= dense_head:
                x = x + nn.apply_moe(p["moe"], h, moe_config(cfg), mesh=mesh)
            else:
                x = x + _apply_mlp(cfg, p["mlp"], h)

    elif cfg.family == "ssm":
        for li in range(cfg.n_layers):
            p = _layer_params(params["layers"], li)
            h = _apply_norm(cfg, p["ln1"], x)
            y, new_caches[li] = apply_ssm_decode(p["ssm"], h, caches[li],
                                                 ssm_config(cfg))
            x = x + y

    elif cfg.family == "hybrid":
        k = cfg.shared_attn_every
        shared = params["shared_block"]
        g = 0
        for li in range(cfg.n_layers):
            p = _layer_params(params["layers"], li)
            h = _apply_norm(cfg, p["ln1"], x)
            y, new_caches[li] = apply_ssm_decode(p["ssm"], h, caches[li],
                                                 ssm_config(cfg))
            x = x + y
            if (li + 1) % k == 0:
                ci = cfg.n_layers + g
                h = _apply_norm(cfg, shared["ln1"], x)
                a, new_caches[ci] = _attn_decode(cfg, shared["attn"], h,
                                                 caches[ci], pos, NO_WINDOW)
                x = x + a
                x = x + _apply_mlp(cfg, shared["mlp"],
                                   _apply_norm(cfg, shared["ln2"], x))
                g += 1

    elif cfg.family == "encdec":
        for li in range(cfg.dec_layers):
            p = _layer_params(params["dec_layers"], li)
            h = _apply_norm(cfg, p["ln1"], x)
            a, new_caches[li] = _attn_decode(cfg, p["attn"], h, caches[li],
                                             pos, NO_WINDOW)
            x = x + a
            h = _apply_norm(cfg, p["ln_x"], x)
            q = nn.qkv_project(p["cross"], h, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim)[0]
            Se = caches[li]["ck"].shape[1]
            o = _masked_decode_attn(q, caches[li]["ck"], caches[li]["cv"],
                                    jnp.ones((Se,), bool))
            x = x + nn.out_project(p["cross"], o)
            x = x + _apply_mlp(cfg, p["mlp"], _apply_norm(cfg, p["ln2"], x))
    else:
        raise ValueError(cfg.family)

    x = _apply_norm(cfg, params["final_norm"], x)
    logits = (nn.unembed(params["embed"], x) if cfg.tie_embeddings
              else nn.apply_lm_head(params["lm_head"], x))
    return logits[:, 0], new_caches
