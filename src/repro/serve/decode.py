"""Serving: KV caches + single-token decode steps for every family.

Decode is deliberately *unrolled* over layers (unlike the scanned training
path): each layer owns its cache pytree, so per-layer cache shapes can
differ — gemma's local layers keep a bounded ``window``-sized ring buffer
while its global layers keep the full sequence; mamba layers keep an O(1)
recurrent state.  The decode HLO is tiny per layer, so unrolling stays
cheap to compile while making the memory roofline of ``decode_32k`` /
``long_500k`` honest.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.models.lm import (_apply_mlp, _apply_norm, mla_config, moe_config,
                             ssm_config, window_schedule)
from repro.nn.attention import (NO_WINDOW, masked_decode_attention,
                                paged_decode_attention, paged_update_cache)
from repro.nn.mla import (apply_mla_decode, apply_mla_paged_decode,
                          init_mla_cache, init_paged_mla_cache)
from repro.nn.ssm import apply_ssm_decode, init_ssm_cache

_NEG = -1e30


def _layer_params(stacked: Dict, i: int) -> Dict:
    return jax.tree.map(lambda a: a[i], stacked)


def _cache_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def _attn_cache(cfg: ModelConfig, batch: int, length: int) -> Dict:
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, _cache_dtype(cfg)),
            "v": jnp.zeros(shape, _cache_dtype(cfg))}


def init_caches(cfg: ModelConfig, batch: int, max_seq: int) -> List:
    """One cache pytree per layer (family-dependent shapes)."""
    caches: List = []
    if cfg.family in ("dense", "moe"):
        wins = [int(w) for w in window_schedule(cfg)]
        for li in range(cfg.n_layers):
            if cfg.mla:
                caches.append(init_mla_cache(mla_config(cfg), batch, max_seq,
                                             _cache_dtype(cfg)))
            else:
                length = max_seq if wins[li] >= NO_WINDOW \
                    else min(wins[li], max_seq)
                caches.append(_attn_cache(cfg, batch, length))
    elif cfg.family == "ssm":
        for _ in range(cfg.n_layers):
            caches.append(init_ssm_cache(ssm_config(cfg), batch,
                                         _cache_dtype(cfg)))
    elif cfg.family == "hybrid":
        for _ in range(cfg.n_layers):
            caches.append(init_ssm_cache(ssm_config(cfg), batch,
                                         _cache_dtype(cfg)))
        for _ in range(cfg.n_layers // cfg.shared_attn_every):
            caches.append(_attn_cache(cfg, batch, max_seq))
    elif cfg.family == "encdec":
        from repro.configs.whisper_large_v3 import ENC_LEN_DECODE
        for _ in range(cfg.dec_layers):
            c = _attn_cache(cfg, batch, max_seq)
            c["ck"] = jnp.zeros((batch, ENC_LEN_DECODE, cfg.n_kv_heads,
                                 cfg.head_dim), _cache_dtype(cfg))
            c["cv"] = jnp.zeros_like(c["ck"])
            caches.append(c)
    else:
        raise ValueError(cfg.family)
    return caches


def paged_cache_kinds(cfg: ModelConfig) -> List[str]:
    """Per-cache-entry layout under paging, parallel to the cache list:
    ``"paged"`` — block-major physical pages addressed through the block
    table (attention K/V, MLA latent); ``"slot"`` — per-slot rows gathered/
    scattered by slot index (recurrent SSM/conv state has no sequence
    dimension to page)."""
    if cfg.family in ("dense", "moe"):
        return ["paged"] * cfg.n_layers
    if cfg.family == "ssm":
        return ["slot"] * cfg.n_layers
    if cfg.family == "hybrid":
        return (["slot"] * cfg.n_layers
                + ["paged"] * (cfg.n_layers // cfg.shared_attn_every))
    raise ValueError(f"paged serving does not support family {cfg.family!r}")


def _paged_attn_cache(cfg: ModelConfig, num_blocks: int,
                      block_size: int) -> Dict:
    shape = (num_blocks, block_size, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, _cache_dtype(cfg)),
            "v": jnp.zeros(shape, _cache_dtype(cfg))}


def init_paged_caches(cfg: ModelConfig, num_blocks: int, block_size: int,
                      num_slots: int) -> List:
    """Block-major cache pytree for the paged serve path.

    Attention/MLA entries hold ``num_blocks`` physical pages of
    ``block_size`` positions shared by every request — memory scales with
    the KV budget, not ``num_slots × max_seq``.  Windowed layers also
    store absolute positions (their dense ring is reconstructed by a
    trailing-window gather): they spend ``max_seq/window`` more bytes per
    layer than the dense ring, the price of sharing and remapping pages.
    Recurrent entries keep ``num_slots + 1`` per-slot rows (scratch row
    included) exactly as the dense path does."""
    caches: List = []
    for kind in paged_cache_kinds(cfg):
        if kind == "slot":
            caches.append(init_ssm_cache(ssm_config(cfg), num_slots + 1,
                                         _cache_dtype(cfg)))
        elif cfg.family in ("dense", "moe") and cfg.mla:
            caches.append(init_paged_mla_cache(mla_config(cfg), num_blocks,
                                               block_size, _cache_dtype(cfg)))
        else:
            caches.append(_paged_attn_cache(cfg, num_blocks, block_size))
    return caches


# ---------------------------------------------------------------------------
# per-layer decode attention
# ---------------------------------------------------------------------------

def _positions(pos, batch: int) -> jax.Array:
    """(B, 1) rope positions from a scalar or per-row ``(B,)`` pos."""
    pos = jnp.asarray(pos, jnp.int32)
    return pos[:, None] if pos.ndim >= 1 else jnp.full((batch, 1), pos)


def _project_qkv(cfg: ModelConfig, p: Dict, x: jax.Array,
                 positions: jax.Array):
    """The decode-step q/k/v projection (+qk-norm, +rope) — shared by the
    dense slot path and the paged block-table path so both produce
    bit-identical per-token K/V before the cache write."""
    from repro.nn.core import apply_dense
    B = x.shape[0]
    q = apply_dense(p["wq"], x).reshape(B, 1, cfg.n_heads, cfg.head_dim)
    k = apply_dense(p["wk"], x).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
    v = apply_dense(p["wv"], x).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = nn.apply_rmsnorm(p["q_norm"], q)
        k = nn.apply_rmsnorm(p["k_norm"], k)
    q = nn.apply_rope(q, positions, cfg.rope_theta)
    k = nn.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attn_decode(cfg: ModelConfig, p: Dict, x: jax.Array, cache: Dict,
                 pos, window: int) -> Tuple[jax.Array, Dict]:
    """x: (B, 1, d); ring buffer for local windows, absolute cache else.

    ``pos`` is a scalar (all rows at the same position — static batch)
    or a ``(B,)`` vector (ragged rows — continuous batching), in which
    case the key mask becomes per-row ``(B, S)``."""
    B = x.shape[0]
    positions = _positions(pos, B)
    q, k, v = _project_qkv(cfg, p, x, positions)

    ragged = jnp.asarray(pos).ndim >= 1
    S = cache["k"].shape[1]
    ring = window < NO_WINDOW and S <= window
    if ring:
        k_cache = jnp.concatenate([cache["k"][:, 1:], k.astype(cache["k"].dtype)],
                                  axis=1)
        v_cache = jnp.concatenate([cache["v"][:, 1:], v.astype(cache["v"].dtype)],
                                  axis=1)
        if ragged:
            k_positions = positions - (S - 1) + jnp.arange(S)[None]  # (B,S)
        else:
            k_positions = pos - (S - 1) + jnp.arange(S)
        mask = k_positions >= 0
    else:
        k_cache = nn.update_cache(cache["k"], k, pos)
        v_cache = nn.update_cache(cache["v"], v, pos)
        if ragged:
            k_positions = jnp.arange(S)[None]                        # (B,S)
            mask = (k_positions <= positions) & \
                   (k_positions > positions - window)
        else:
            k_positions = jnp.arange(S)
            mask = (k_positions <= pos) & (k_positions > pos - window)

    o = _masked_decode_attn(q, k_cache, v_cache, mask)
    out = nn.out_project(p, o)
    return out, {"k": k_cache, "v": v_cache, **{kk: vv for kk, vv in
                                                cache.items()
                                                if kk not in ("k", "v")}}


# The decode softmax now lives in nn.attention so the paged path shares it
# op-for-op; kept under the historical local name for the call sites here.
_masked_decode_attn = masked_decode_attention


def _attn_decode_paged(cfg: ModelConfig, p: Dict, x: jax.Array, cache: Dict,
                       block_table: jax.Array, pos: jax.Array,
                       window: int, max_seq: int,
                       write_mask: jax.Array) -> Tuple[jax.Array, Dict]:
    """The paged analogue of :func:`_attn_decode`: scatter this token's K/V
    into the physical pages through the block table, then attend over a
    gather whose width matches the dense layer's cache length — so the
    outputs are bit-identical to the slot path's."""
    B = x.shape[0]
    positions = _positions(pos, B)
    q, k, v = _project_qkv(cfg, p, x, positions)
    k_pages = paged_update_cache(cache["k"], k, block_table, pos,
                                 write_mask=write_mask)
    v_pages = paged_update_cache(cache["v"], v, block_table, pos,
                                 write_mask=write_mask)
    width = max_seq if window >= NO_WINDOW else min(window, max_seq)
    o = paged_decode_attention(q, k_pages, v_pages, block_table, pos,
                               window=window, width=width)
    return nn.out_project(p, o), {"k": k_pages, "v": v_pages}


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def decode_step(params: Dict, caches: List, token: jax.Array, pos,
                cfg: ModelConfig, mesh=None,
                enc_out: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, List]:
    """token (B, 1) int32 -> logits (B, vocab); updates caches.

    ``pos`` is a scalar (all rows decode the same position — the static
    generate path) or a ``(B,)`` int vector of per-row positions (the
    continuous-batching ragged path; each row reads/writes its own cache
    position).  SSM/recurrent layers carry no position and advance one
    step per call either way."""
    x = nn.apply_embedding(params["embed"], token).astype(jnp.dtype(cfg.dtype))
    if cfg.name.startswith("gemma"):
        x = x * (cfg.d_model ** 0.5)   # gemma scales embeddings (as forward)
    new_caches = list(caches)
    wins = [int(w) for w in window_schedule(cfg)] \
        if cfg.family in ("dense", "moe") else []

    if cfg.family in ("dense", "moe"):
        dense_head = cfg.first_dense_layers if cfg.family == "moe" else 0
        for li in range(cfg.n_layers):
            if cfg.family == "moe" and li >= dense_head:
                p = _layer_params(params["layers"], li - dense_head)
            elif cfg.family == "moe":
                p = _layer_params(params["dense_layers"], li)
            else:
                p = _layer_params(params["layers"], li)
            h = _apply_norm(cfg, p["ln1"], x)
            if cfg.mla:
                a, new_caches[li] = apply_mla_decode(p["attn"], h,
                                                     caches[li], pos,
                                                     mla_config(cfg))
            else:
                a, new_caches[li] = _attn_decode(cfg, p["attn"], h,
                                                 caches[li], pos, wins[li])
            x = x + a
            h = _apply_norm(cfg, p["ln2"], x)
            if cfg.family == "moe" and li >= dense_head:
                x = x + nn.apply_moe(p["moe"], h, moe_config(cfg), mesh=mesh)
            else:
                x = x + _apply_mlp(cfg, p["mlp"], h)

    elif cfg.family == "ssm":
        for li in range(cfg.n_layers):
            p = _layer_params(params["layers"], li)
            h = _apply_norm(cfg, p["ln1"], x)
            y, new_caches[li] = apply_ssm_decode(p["ssm"], h, caches[li],
                                                 ssm_config(cfg))
            x = x + y

    elif cfg.family == "hybrid":
        k = cfg.shared_attn_every
        shared = params["shared_block"]
        g = 0
        for li in range(cfg.n_layers):
            p = _layer_params(params["layers"], li)
            h = _apply_norm(cfg, p["ln1"], x)
            y, new_caches[li] = apply_ssm_decode(p["ssm"], h, caches[li],
                                                 ssm_config(cfg))
            x = x + y
            if (li + 1) % k == 0:
                ci = cfg.n_layers + g
                h = _apply_norm(cfg, shared["ln1"], x)
                a, new_caches[ci] = _attn_decode(cfg, shared["attn"], h,
                                                 caches[ci], pos, NO_WINDOW)
                x = x + a
                x = x + _apply_mlp(cfg, shared["mlp"],
                                   _apply_norm(cfg, shared["ln2"], x))
                g += 1

    elif cfg.family == "encdec":
        for li in range(cfg.dec_layers):
            p = _layer_params(params["dec_layers"], li)
            h = _apply_norm(cfg, p["ln1"], x)
            a, new_caches[li] = _attn_decode(cfg, p["attn"], h, caches[li],
                                             pos, NO_WINDOW)
            x = x + a
            h = _apply_norm(cfg, p["ln_x"], x)
            q = nn.qkv_project(p["cross"], h, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim)[0]
            Se = caches[li]["ck"].shape[1]
            o = _masked_decode_attn(q, caches[li]["ck"], caches[li]["cv"],
                                    jnp.ones((Se,), bool))
            x = x + nn.out_project(p["cross"], o)
            x = x + _apply_mlp(cfg, p["mlp"], _apply_norm(cfg, p["ln2"], x))
    else:
        raise ValueError(cfg.family)

    x = _apply_norm(cfg, params["final_norm"], x)
    logits = (nn.unembed(params["embed"], x) if cfg.tie_embeddings
              else nn.apply_lm_head(params["lm_head"], x))
    return logits[:, 0], new_caches


def paged_decode_step(params: Dict, caches: List, block_table: jax.Array,
                      token: jax.Array, pos: jax.Array,
                      write_mask: jax.Array, cfg: ModelConfig, max_seq: int,
                      mesh=None) -> Tuple[jax.Array, List]:
    """token (B, 1) int32 -> logits (B, vocab) through the block table.

    The paged analogue of :func:`decode_step`: ``"paged"`` cache entries
    (see :func:`paged_cache_kinds`) are the full block-major page arrays —
    every lane reads/writes its own pages through its ``block_table`` row
    at its own ragged ``pos`` — while ``"slot"`` entries arrive already
    gathered to (B, ...) rows (the engine scatters them back).
    ``write_mask`` (B,) suppresses the page scatter for idle lanes and for
    shared-prefix re-run passes whose target position is owned by a
    shared block (the stored value is bit-identical, so skipping the
    write avoids a spurious copy-on-write fork without changing any
    attention operand)."""
    x = nn.apply_embedding(params["embed"], token).astype(jnp.dtype(cfg.dtype))
    if cfg.name.startswith("gemma"):
        x = x * (cfg.d_model ** 0.5)   # gemma scales embeddings (as forward)
    new_caches = list(caches)

    if cfg.family in ("dense", "moe"):
        wins = [int(w) for w in window_schedule(cfg)]
        dense_head = cfg.first_dense_layers if cfg.family == "moe" else 0
        for li in range(cfg.n_layers):
            if cfg.family == "moe" and li >= dense_head:
                p = _layer_params(params["layers"], li - dense_head)
            elif cfg.family == "moe":
                p = _layer_params(params["dense_layers"], li)
            else:
                p = _layer_params(params["layers"], li)
            h = _apply_norm(cfg, p["ln1"], x)
            if cfg.mla:
                a, new_caches[li] = apply_mla_paged_decode(
                    p["attn"], h, caches[li], block_table, pos,
                    mla_config(cfg), width=max_seq, write_mask=write_mask)
            else:
                a, new_caches[li] = _attn_decode_paged(
                    cfg, p["attn"], h, caches[li], block_table, pos,
                    wins[li], max_seq, write_mask)
            x = x + a
            h = _apply_norm(cfg, p["ln2"], x)
            if cfg.family == "moe" and li >= dense_head:
                x = x + nn.apply_moe(p["moe"], h, moe_config(cfg), mesh=mesh)
            else:
                x = x + _apply_mlp(cfg, p["mlp"], h)

    elif cfg.family == "ssm":
        for li in range(cfg.n_layers):
            p = _layer_params(params["layers"], li)
            h = _apply_norm(cfg, p["ln1"], x)
            y, new_caches[li] = apply_ssm_decode(p["ssm"], h, caches[li],
                                                 ssm_config(cfg))
            x = x + y

    elif cfg.family == "hybrid":
        k = cfg.shared_attn_every
        shared = params["shared_block"]
        g = 0
        for li in range(cfg.n_layers):
            p = _layer_params(params["layers"], li)
            h = _apply_norm(cfg, p["ln1"], x)
            y, new_caches[li] = apply_ssm_decode(p["ssm"], h, caches[li],
                                                 ssm_config(cfg))
            x = x + y
            if (li + 1) % k == 0:
                ci = cfg.n_layers + g
                h = _apply_norm(cfg, shared["ln1"], x)
                a, new_caches[ci] = _attn_decode_paged(
                    cfg, shared["attn"], h, caches[ci], block_table, pos,
                    NO_WINDOW, max_seq, write_mask)
                x = x + a
                x = x + _apply_mlp(cfg, shared["mlp"],
                                   _apply_norm(cfg, shared["ln2"], x))
                g += 1
    else:
        raise ValueError(cfg.family)

    x = _apply_norm(cfg, params["final_norm"], x)
    logits = (nn.unembed(params["embed"], x) if cfg.tie_embeddings
              else nn.apply_lm_head(params["lm_head"], x))
    return logits[:, 0], new_caches
