"""KV-cache block pool: the serve engine's memory manager.

The engine's physical KV storage is the slot-major dense cache pytree that
:func:`repro.serve.decode.init_caches` builds (one batch row per *slot*,
``max_seq`` positions per row — plus one scratch row the batched step pads
inactive lanes onto).  What continuous batching needs on top is
*accounting*: which slot a request owns, how many fixed-size **blocks** of
sequence positions it has been granted, and whether admission or another
decode step would exceed the pool — so admission control, growth, and
preemption are all decisions against one free list instead of ad-hoc
per-request math.

Blocks are ``block_size`` tokens each and come from one global free list
(``num_blocks`` total).  ``num_blocks`` may be *smaller* than
``num_slots × blocks_per_slot`` — oversubscription: more concurrent slots
than worst-case full-length sequences, the standard serving trade.  When a
decode step would cross into a block the pool cannot grant, the engine
stalls that slot and, if nothing at all can advance, preempts the youngest
request (recompute-on-readmission; see ``serve.engine``).

Capacity errors are **typed and loud**: a request whose prompt already
fills every cache position (``prompt_len >= max_seq`` — no position left
for even one generated token) raises :class:`PoolCapacityError` at
admission instead of silently letting ``decode_step`` clamp its cache
write into the last position (the old out-of-range bug).

Placement of the backing cache arrays onto a device mesh goes through the
existing dist-layer rules — :func:`repro.dist.sharding.kv_pool_shardings`
(the slot dimension plays the batch role).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional


class PoolError(RuntimeError):
    """Caller bug against the pool protocol (double alloc, double free,
    unknown request) — deliberately not a capacity signal."""


class PoolCapacityError(PoolError):
    """The request can not be granted the cache positions it needs —
    either ever (prompt fills the whole cache) or right now (free list
    exhausted and the caller asked for a hard allocation)."""


@dataclasses.dataclass
class BlockTable:
    """One request's allocation: its slot plus the granted block ids."""
    request_id: object
    slot: int
    blocks: List[int]
    tokens: int                       # cache positions covered by `blocks`

    @property
    def num_blocks(self) -> int:
        return len(self.blocks)


class KVBlockPool:
    """Fixed-size-block free list over the slot-major KV cache.

    ``num_slots`` is the concurrency bound (batch rows), ``max_seq`` the
    per-slot position capacity, ``block_size`` the grant granularity, and
    ``num_blocks`` the global token-memory budget (defaults to the
    un-oversubscribed ``num_slots * ceil(max_seq / block_size)``).
    """

    def __init__(self, num_slots: int, max_seq: int, block_size: int = 16,
                 num_blocks: Optional[int] = None):
        if num_slots < 1 or max_seq < 2 or block_size < 1:
            raise ValueError(
                f"need num_slots >= 1, max_seq >= 2, block_size >= 1; got "
                f"{num_slots}/{max_seq}/{block_size}")
        self.num_slots = int(num_slots)
        self.max_seq = int(max_seq)
        self.block_size = int(block_size)
        self.blocks_per_slot = math.ceil(self.max_seq / self.block_size)
        self.num_blocks = (int(num_blocks) if num_blocks is not None
                           else self.num_slots * self.blocks_per_slot)
        if self.num_blocks < self.blocks_per_slot:
            raise ValueError(
                f"num_blocks={self.num_blocks} cannot hold even one "
                f"full-length request ({self.blocks_per_slot} blocks)")
        self._free_slots: List[int] = list(range(self.num_slots))
        self._free_blocks: List[int] = list(range(self.num_blocks))
        self._tables: Dict[object, BlockTable] = {}
        # lifetime stats (bench / fairness table surfacing)
        self.allocs = 0
        self.frees = 0
        self.high_water_blocks = 0

    # -- capacity queries ----------------------------------------------------

    def blocks_for(self, tokens: int) -> int:
        return max(1, math.ceil(tokens / self.block_size))

    def fits(self, prompt_len: int) -> bool:
        """Whether a prompt can *ever* be served: it must leave at least
        one cache position for the first generated token's KV write."""
        return 1 <= prompt_len < self.max_seq

    @property
    def free_slot_count(self) -> int:
        return len(self._free_slots)

    @property
    def free_block_count(self) -> int:
        return len(self._free_blocks)

    @property
    def used_block_count(self) -> int:
        return self.num_blocks - len(self._free_blocks)

    def can_admit(self, prompt_len: int) -> bool:
        """Admission predicate: a free slot and enough free blocks to
        cover the prompt (decode growth is granted block-by-block)."""
        return (self.fits(prompt_len) and self._free_slots
                and len(self._free_blocks) >= self.blocks_for(prompt_len))

    def can_ensure(self, request_id, tokens: int) -> bool:
        """Whether ``ensure`` for this coverage would succeed right now."""
        t = self._tables.get(request_id)
        if t is None or tokens > self.max_seq:
            return False
        need = self.blocks_for(tokens) - t.num_blocks
        return need <= len(self._free_blocks)

    # -- allocation ----------------------------------------------------------

    def alloc(self, request_id, prompt_len: int) -> BlockTable:
        """Admit a request: claim a slot and the prompt's blocks.

        Raises :class:`PoolCapacityError` when the prompt can never fit
        (``prompt_len >= max_seq`` leaves no position for generation) or
        the free list cannot cover it now; :class:`PoolError` on protocol
        misuse (already-allocated id, no free slot)."""
        if request_id in self._tables:
            raise PoolError(f"request {request_id!r} is already allocated")
        if not self.fits(prompt_len):
            raise PoolCapacityError(
                f"prompt of {prompt_len} tokens cannot be admitted into a "
                f"{self.max_seq}-position cache: at least one position must "
                f"remain for the first generated token")
        if not self._free_slots:
            raise PoolError("no free slot (call can_admit() before alloc())")
        need = self.blocks_for(prompt_len)
        if need > len(self._free_blocks):
            raise PoolCapacityError(
                f"pool out of blocks: need {need}, "
                f"free {len(self._free_blocks)}")
        slot = self._free_slots.pop(0)
        blocks = [self._free_blocks.pop(0) for _ in range(need)]
        table = BlockTable(request_id=request_id, slot=slot, blocks=blocks,
                           tokens=need * self.block_size)
        self._tables[request_id] = table
        self.allocs += 1
        self.high_water_blocks = max(self.high_water_blocks,
                                     self.used_block_count)
        return table

    def ensure(self, request_id, tokens: int) -> BlockTable:
        """Grow the request's grant to cover ``tokens`` cache positions
        (a decode step about to write position ``p`` needs ``p + 1``).
        No-op when already covered."""
        t = self._tables.get(request_id)
        if t is None:
            raise PoolError(f"unknown request {request_id!r}")
        if tokens > self.max_seq:
            raise PoolCapacityError(
                f"request {request_id!r} needs {tokens} positions but the "
                f"cache holds {self.max_seq}")
        need = self.blocks_for(tokens) - t.num_blocks
        if need <= 0:
            return t
        if need > len(self._free_blocks):
            raise PoolCapacityError(
                f"pool out of blocks growing request {request_id!r}: need "
                f"{need}, free {len(self._free_blocks)}")
        t.blocks.extend(self._free_blocks.pop(0) for _ in range(need))
        t.tokens = t.num_blocks * self.block_size
        self.high_water_blocks = max(self.high_water_blocks,
                                     self.used_block_count)
        return t

    def free(self, request_id) -> int:
        """Release the request's slot and blocks; returns the block count.
        A second free of the same id raises (double-free guard)."""
        t = self._tables.pop(request_id, None)
        if t is None:
            raise PoolError(f"double free / unknown request {request_id!r}")
        self._free_slots.append(t.slot)
        self._free_slots.sort()
        self._free_blocks.extend(t.blocks)
        self.frees += 1
        return t.num_blocks

    def table(self, request_id) -> BlockTable:
        try:
            return self._tables[request_id]
        except KeyError:
            raise PoolError(f"unknown request {request_id!r}") from None

    # -- invariants ----------------------------------------------------------

    def check(self) -> None:
        """Assert the free-list invariants (tests call this after churn):
        slots and blocks are conserved, never double-granted."""
        granted = [b for t in self._tables.values() for b in t.blocks]
        assert len(granted) + len(self._free_blocks) == self.num_blocks, \
            "block leak/duplication"
        assert len(set(granted)) == len(granted), "block double-grant"
        assert not (set(granted) & set(self._free_blocks)), \
            "block simultaneously granted and free"
        slots = [t.slot for t in self._tables.values()]
        assert len(slots) + len(self._free_slots) == self.num_slots, \
            "slot leak/duplication"
        assert len(set(slots)) == len(slots), "slot double-grant"

    def stats(self) -> Dict[str, int]:
        return {"num_slots": self.num_slots, "num_blocks": self.num_blocks,
                "free_slots": len(self._free_slots),
                "free_blocks": len(self._free_blocks),
                "used_blocks": self.used_block_count,
                "allocs": self.allocs, "frees": self.frees,
                "high_water_blocks": self.high_water_blocks}
