"""KV-cache block pool: the serve engine's memory manager.

In **paged** mode (the production path) the pool's blocks ARE the
physical KV storage: the cache arrays are block-major
``(num_blocks, block_size, ...)`` pages (see
:func:`repro.serve.decode.init_paged_caches`) and each request addresses
its sequence through its :class:`BlockTable` — a list of physical block
ids.  That turns the pool from accounting into a real memory manager:

* **refcounts** — a physical block may appear in several tables.  It is
  freed only when the last table drops it.
* **prefix sharing** — completed blocks are *registered* under the exact
  token prefix they hold (``tuple(tokens[:end])`` — chained content keys,
  collision-free).  Admission walks the new prompt block-by-block through
  the registry and maps matching resident blocks instead of recomputing
  their K/V; the final *partial* prompt block is registered too (once its
  prefill completes), so even prompts that are not block-multiples share
  fully.
* **copy-on-write** — a decode write into a block with refcount > 1 forks
  it first: :meth:`advance` remaps the writer's table entry onto a fresh
  block and reports the ``(src, dst)`` pair for the engine to device-copy
  before the pass.
* **spill accounting** — preemption frees the victim's blocks (its page
  contents travel to the host with the request); re-admission through
  :meth:`alloc_resume` grants fresh private blocks to upload into —
  copy-free resume, no teacher-forced recompute.

In **dense** mode (``serve.engine`` with ``paged=False``) the same pool
runs with every refcount at 1 and no registry — the original
accounting-only behavior over slot-major cache rows.

Blocks are ``block_size`` tokens each and come from one global free list
(``num_blocks`` total).  ``num_blocks`` may be *smaller* than
``num_slots × blocks_per_slot`` — oversubscription: more concurrent slots
than worst-case full-length sequences, the standard serving trade.  With
sharing, capacity math changes: admission needs free blocks only for the
prompt blocks **not** found in the registry, so a shared-prefix workload
admits far more concurrent sequences at the same ``num_blocks`` budget.

Capacity errors are **typed and loud**: a request whose prompt already
fills every cache position (``prompt_len >= max_seq`` — no position left
for even one generated token) raises :class:`PoolCapacityError` at
admission instead of silently letting ``decode_step`` clamp its cache
write into the last position (the old out-of-range bug).

Placement of the backing cache arrays onto a device mesh goes through the
existing dist-layer rules — :func:`repro.dist.sharding.kv_pool_shardings`
(block-major rules for paged leaves, decode rules for slot rows).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple


class PoolError(RuntimeError):
    """Caller bug against the pool protocol (double alloc, double free,
    unknown request) — deliberately not a capacity signal."""


class PoolCapacityError(PoolError):
    """The request can not be granted the cache positions it needs —
    either ever (prompt fills the whole cache) or right now (free list
    exhausted and the caller asked for a hard allocation)."""


@dataclasses.dataclass
class BlockTable:
    """One request's allocation: its slot plus the granted block ids."""
    request_id: object
    slot: int
    blocks: List[int]
    tokens: int                       # cache positions covered by `blocks`
    shared_tokens: int = 0            # prompt positions mapped from registry
    registered_full: int = 0          # full blocks already registered

    @property
    def num_blocks(self) -> int:
        return len(self.blocks)


class KVBlockPool:
    """Refcounted fixed-size-block free list with prefix sharing.

    ``num_slots`` is the concurrency bound (batch rows), ``max_seq`` the
    per-slot position capacity, ``block_size`` the grant granularity, and
    ``num_blocks`` the global token-memory budget (defaults to the
    un-oversubscribed ``num_slots * ceil(max_seq / block_size)``).
    """

    def __init__(self, num_slots: int, max_seq: int, block_size: int = 16,
                 num_blocks: Optional[int] = None):
        if num_slots < 1 or max_seq < 2 or block_size < 1:
            raise ValueError(
                f"need num_slots >= 1, max_seq >= 2, block_size >= 1; got "
                f"{num_slots}/{max_seq}/{block_size}")
        self.num_slots = int(num_slots)
        self.max_seq = int(max_seq)
        self.block_size = int(block_size)
        self.blocks_per_slot = math.ceil(self.max_seq / self.block_size)
        self.num_blocks = (int(num_blocks) if num_blocks is not None
                           else self.num_slots * self.blocks_per_slot)
        if self.num_blocks < self.blocks_per_slot:
            raise ValueError(
                f"num_blocks={self.num_blocks} cannot hold even one "
                f"full-length request ({self.blocks_per_slot} blocks)")
        self._free_slots: List[int] = list(range(self.num_slots))
        self._free_blocks: List[int] = list(range(self.num_blocks))
        self._tables: Dict[object, BlockTable] = {}
        self._refcount: Dict[int, int] = {}
        # content-keyed prefix registry: exact token tuple -> block id,
        # plus the reverse map for O(keys-per-block) cleanup on release
        self._registry: Dict[Tuple[int, ...], int] = {}
        self._block_keys: Dict[int, List[Tuple[int, ...]]] = {}
        # lifetime stats (bench / fairness table surfacing)
        self.allocs = 0
        self.frees = 0
        self.high_water_blocks = 0
        self.shared_hits = 0          # admissions that mapped >= 1 block
        self.shared_tokens_reused = 0
        self.cow_forks = 0

    # -- capacity queries ----------------------------------------------------

    def blocks_for(self, tokens: int) -> int:
        return max(1, math.ceil(tokens / self.block_size))

    def fits(self, prompt_len: int) -> bool:
        """Whether a prompt can *ever* be served: it must leave at least
        one cache position for the first generated token's KV write."""
        return 1 <= prompt_len < self.max_seq

    @property
    def free_slot_count(self) -> int:
        return len(self._free_slots)

    @property
    def free_block_count(self) -> int:
        return len(self._free_blocks)

    @property
    def used_block_count(self) -> int:
        return self.num_blocks - len(self._free_blocks)

    def can_admit(self, prompt_len: int) -> bool:
        """Admission predicate: a free slot and enough free blocks to
        cover the prompt (decode growth is granted block-by-block)."""
        return (self.fits(prompt_len) and bool(self._free_slots)
                and len(self._free_blocks) >= self.blocks_for(prompt_len))

    def can_admit_shared(self, prompt: Sequence[int]) -> bool:
        """Admission predicate under prefix sharing: free blocks are only
        needed for the prompt blocks the registry cannot map."""
        if not (self.fits(len(prompt)) and self._free_slots):
            return False
        shared_blocks, _ = self.match_prefix(prompt)
        fresh = self.blocks_for(len(prompt)) - len(shared_blocks)
        return fresh <= len(self._free_blocks)

    def can_ensure(self, request_id, tokens: int) -> bool:
        """Whether ``ensure`` for this coverage would succeed right now."""
        t = self._tables.get(request_id)
        if t is None or tokens > self.max_seq:
            return False
        need = self.blocks_for(tokens) - t.num_blocks
        return need <= len(self._free_blocks)

    def can_resume(self, n_blocks: int) -> bool:
        """Whether a spilled request's pages could be re-granted now."""
        return bool(self._free_slots) and n_blocks <= len(self._free_blocks)

    # -- prefix registry -----------------------------------------------------

    def match_prefix(self, prompt: Sequence[int]
                     ) -> Tuple[List[int], int]:
        """Longest registered prefix of ``prompt``: the resident block ids
        covering it, and the number of prompt positions they hold.

        Full blocks chain by exact ``tuple(prompt[:(i+1)*block_size])``
        keys; after the chain breaks, an exact whole-prompt key may map
        the final partial block too (registered when its donor's prefill
        completed)."""
        prompt = tuple(int(t) for t in prompt)
        bs = self.block_size
        blocks: List[int] = []
        covered = 0
        while covered + bs <= len(prompt):
            b = self._registry.get(prompt[:covered + bs])
            if b is None:
                break
            blocks.append(b)
            covered += bs
        if 0 < len(prompt) - covered < bs:
            b = self._registry.get(prompt)
            if b is not None and b not in blocks:
                blocks.append(b)
                covered = len(prompt)
        return blocks, covered

    def commit(self, request_id, tokens: Sequence[int], complete: int,
               prompt_len: int = 0) -> None:
        """Register this request's finished block contents for sharing.

        ``complete`` is the number of leading positions whose K/V writes
        are final (a pass at ``pos`` completes position ``pos``, so the
        engine passes ``slot.pos`` after advancing).  Every fully covered
        block is registered under its exact token-prefix key; when the
        prompt does not end on a block boundary, the partial prompt-tail
        block is registered once under the whole-prompt key as soon as
        prefill completes (``complete >= prompt_len``) — positions past
        the prompt inside that block belong to this request's generation
        and are overwritten-before-read by any sharer."""
        t = self.table(request_id)
        bs = self.block_size
        nfull = min(complete // bs, t.num_blocks)
        for i in range(t.registered_full, nfull):
            key = tuple(int(x) for x in tokens[:(i + 1) * bs])
            self._register(key, t.blocks[i])
        t.registered_full = max(t.registered_full, nfull)
        if prompt_len % bs and complete >= prompt_len \
                and prompt_len // bs < t.num_blocks:
            key = tuple(int(x) for x in tokens[:prompt_len])
            self._register(key, t.blocks[prompt_len // bs])

    def _register(self, key: Tuple[int, ...], block: int) -> None:
        if key in self._registry:
            return                    # first donor wins; content identical
        self._registry[key] = block
        self._block_keys.setdefault(block, []).append(key)

    def _release_block(self, block: int) -> bool:
        """Drop one reference; on the last one, unregister and free.
        Returns True when the block actually returned to the free list."""
        n = self._refcount.get(block)
        if n is None:
            raise PoolError(f"release of untracked block {block}")
        if n > 1:
            self._refcount[block] = n - 1
            return False
        del self._refcount[block]
        for key in self._block_keys.pop(block, []):
            self._registry.pop(key, None)
        self._free_blocks.append(block)
        return True

    # -- allocation ----------------------------------------------------------

    def _claim_fresh(self, n: int) -> List[int]:
        blocks = [self._free_blocks.pop(0) for _ in range(n)]
        for b in blocks:
            self._refcount[b] = 1
        return blocks

    def alloc(self, request_id, prompt_len: int) -> BlockTable:
        """Admit a request: claim a slot and the prompt's blocks
        (no sharing — every block private).

        Raises :class:`PoolCapacityError` when the prompt can never fit
        (``prompt_len >= max_seq`` leaves no position for generation) or
        the free list cannot cover it now; :class:`PoolError` on protocol
        misuse (already-allocated id, no free slot)."""
        if request_id in self._tables:
            raise PoolError(f"request {request_id!r} is already allocated")
        if not self.fits(prompt_len):
            raise PoolCapacityError(
                f"prompt of {prompt_len} tokens cannot be admitted into a "
                f"{self.max_seq}-position cache: at least one position must "
                f"remain for the first generated token")
        if not self._free_slots:
            raise PoolError("no free slot (call can_admit() before alloc())")
        need = self.blocks_for(prompt_len)
        if need > len(self._free_blocks):
            raise PoolCapacityError(
                f"pool out of blocks: need {need}, "
                f"free {len(self._free_blocks)}")
        slot = self._free_slots.pop(0)
        table = BlockTable(request_id=request_id, slot=slot,
                           blocks=self._claim_fresh(need),
                           tokens=need * self.block_size)
        self._tables[request_id] = table
        self.allocs += 1
        self.high_water_blocks = max(self.high_water_blocks,
                                     self.used_block_count)
        return table

    def alloc_shared(self, request_id, prompt: Sequence[int]) -> BlockTable:
        """Admit a request, mapping every registry-matched prompt block
        instead of claiming fresh ones.  The returned table's
        ``shared_tokens`` tells the engine how much prefill to skip."""
        prompt = [int(t) for t in prompt]
        if request_id in self._tables:
            raise PoolError(f"request {request_id!r} is already allocated")
        if not self.fits(len(prompt)):
            raise PoolCapacityError(
                f"prompt of {len(prompt)} tokens cannot be admitted into a "
                f"{self.max_seq}-position cache: at least one position must "
                f"remain for the first generated token")
        if not self._free_slots:
            raise PoolError("no free slot (call can_admit_shared() first)")
        shared_blocks, shared_tokens = self.match_prefix(prompt)
        fresh = self.blocks_for(len(prompt)) - len(shared_blocks)
        if fresh > len(self._free_blocks):
            raise PoolCapacityError(
                f"pool out of blocks: need {fresh} fresh "
                f"(+{len(shared_blocks)} shared), "
                f"free {len(self._free_blocks)}")
        slot = self._free_slots.pop(0)
        for b in shared_blocks:
            self._refcount[b] += 1
        blocks = shared_blocks + self._claim_fresh(fresh)
        table = BlockTable(request_id=request_id, slot=slot, blocks=blocks,
                           tokens=len(blocks) * self.block_size,
                           shared_tokens=shared_tokens,
                           registered_full=shared_tokens // self.block_size)
        self._tables[request_id] = table
        self.allocs += 1
        if shared_blocks:
            self.shared_hits += 1
            self.shared_tokens_reused += shared_tokens
        self.high_water_blocks = max(self.high_water_blocks,
                                     self.used_block_count)
        return table

    def alloc_resume(self, request_id, n_blocks: int) -> BlockTable:
        """Re-admit a spilled request: a slot plus ``n_blocks`` fresh
        *private* blocks for the engine to upload the spilled pages into
        (uploaded content diverges from any registered prefix, so shared
        mapping is not safe here)."""
        if request_id in self._tables:
            raise PoolError(f"request {request_id!r} is already allocated")
        if not self._free_slots:
            raise PoolError("no free slot (call can_resume() first)")
        if n_blocks > len(self._free_blocks):
            raise PoolCapacityError(
                f"pool out of blocks resuming request {request_id!r}: need "
                f"{n_blocks}, free {len(self._free_blocks)}")
        slot = self._free_slots.pop(0)
        table = BlockTable(request_id=request_id, slot=slot,
                           blocks=self._claim_fresh(n_blocks),
                           tokens=n_blocks * self.block_size)
        self._tables[request_id] = table
        self.allocs += 1
        self.high_water_blocks = max(self.high_water_blocks,
                                     self.used_block_count)
        return table

    def ensure(self, request_id, tokens: int) -> BlockTable:
        """Grow the request's grant to cover ``tokens`` cache positions
        (a decode step about to write position ``p`` needs ``p + 1``).
        No-op when already covered."""
        t = self._tables.get(request_id)
        if t is None:
            raise PoolError(f"unknown request {request_id!r}")
        if tokens > self.max_seq:
            raise PoolCapacityError(
                f"request {request_id!r} needs {tokens} positions but the "
                f"cache holds {self.max_seq}")
        need = self.blocks_for(tokens) - t.num_blocks
        if need <= 0:
            return t
        if need > len(self._free_blocks):
            raise PoolCapacityError(
                f"pool out of blocks growing request {request_id!r}: need "
                f"{need}, free {len(self._free_blocks)}")
        t.blocks.extend(self._claim_fresh(need))
        t.tokens = t.num_blocks * self.block_size
        self.high_water_blocks = max(self.high_water_blocks,
                                     self.used_block_count)
        return t

    # -- decode-step granting (coverage growth + copy-on-write) --------------

    def _advance_needs(self, t: BlockTable, pos: int,
                       write: bool) -> Tuple[int, bool]:
        """(fresh blocks needed, whether the write needs a CoW fork) for a
        pass writing position ``pos``.  A grow covers ``pos`` with a fresh
        private block, so grow and fork are mutually exclusive."""
        grow = max(0, self.blocks_for(pos + 1) - t.num_blocks)
        if grow or not write:
            return grow, False
        fork = self._refcount[t.blocks[pos // self.block_size]] > 1
        return (1 if fork else 0), fork

    def can_advance(self, request_id, pos: int, write: bool = True) -> bool:
        """Whether a pass writing position ``pos`` can be granted now
        (coverage growth plus a possible copy-on-write fork)."""
        t = self._tables.get(request_id)
        if t is None or pos + 1 > self.max_seq:
            return False
        need, _ = self._advance_needs(t, pos, write)
        return need <= len(self._free_blocks)

    def advance(self, request_id, pos: int,
                write: bool = True) -> Optional[Tuple[int, int]]:
        """Grant everything a pass writing position ``pos`` needs: grow
        coverage to ``pos + 1`` and copy-on-write-fork the target block if
        it is shared.  Returns the ``(src, dst)`` block pair when a fork
        happened (the engine device-copies the page before the pass),
        else None.  Raises :class:`PoolCapacityError` when the free list
        cannot cover it."""
        t = self.table(request_id)
        if pos + 1 > self.max_seq:
            raise PoolCapacityError(
                f"request {request_id!r} needs position {pos} but the "
                f"cache holds {self.max_seq}")
        need, fork = self._advance_needs(t, pos, write)
        if need > len(self._free_blocks):
            raise PoolCapacityError(
                f"pool out of blocks advancing request {request_id!r}: "
                f"need {need}, free {len(self._free_blocks)}")
        if fork:
            i = pos // self.block_size
            src = t.blocks[i]
            dst = self._claim_fresh(1)[0]
            self._release_block(src)
            t.blocks[i] = dst
            self.cow_forks += 1
            self.high_water_blocks = max(self.high_water_blocks,
                                         self.used_block_count)
            return src, dst
        if need:
            self.ensure(request_id, pos + 1)
        return None

    # -- release -------------------------------------------------------------

    def free(self, request_id) -> int:
        """Release the request's slot and drop one reference on each of
        its blocks; returns how many blocks actually returned to the free
        list (shared blocks survive under their other tables).  A second
        free of the same id raises (double-free guard)."""
        t = self._tables.pop(request_id, None)
        if t is None:
            raise PoolError(f"double free / unknown request {request_id!r}")
        self._free_slots.append(t.slot)
        self._free_slots.sort()
        freed = sum(self._release_block(b) for b in t.blocks)
        self.frees += 1
        return freed

    def table(self, request_id) -> BlockTable:
        try:
            return self._tables[request_id]
        except KeyError:
            raise PoolError(f"unknown request {request_id!r}") from None

    # -- invariants ----------------------------------------------------------

    def check(self) -> None:
        """Assert the pool invariants (the engine runs this every tick
        under ``debug_invariants``; tests call it after churn):

        * free-list conservation — every block is either granted (to >= 1
          table) or free, never both, never duplicated in the free list;
        * refcount exactness — a mapped block's refcount equals the
          number of tables holding it and is >= 1;
        * no double-grant — a block appears at most once per table, a
          slot in at most one table;
        * registry hygiene — registered keys point only at live granted
          blocks, consistent with the reverse map."""
        granted: Dict[int, int] = {}
        for t in self._tables.values():
            assert len(set(t.blocks)) == len(t.blocks), \
                f"table {t.request_id!r} holds a block twice"
            for b in t.blocks:
                granted[b] = granted.get(b, 0) + 1
        assert len(set(self._free_blocks)) == len(self._free_blocks), \
            "double-free: duplicate block in free list"
        assert not (set(granted) & set(self._free_blocks)), \
            "block simultaneously granted and free"
        assert len(granted) + len(self._free_blocks) == self.num_blocks, \
            "block leak: granted + free != total"
        assert granted == self._refcount, \
            f"refcount drift: {self._refcount} vs tables {granted}"
        assert all(n >= 1 for n in granted.values()), \
            "mapped block with refcount < 1"
        for key, b in self._registry.items():
            assert b in granted, f"registry key maps freed block {b}"
            assert key in self._block_keys.get(b, []), \
                "registry/reverse-map drift"
        for b, keys in self._block_keys.items():
            for key in keys:
                assert self._registry.get(key) == b, \
                    "reverse-map/registry drift"
        slots = [t.slot for t in self._tables.values()]
        assert len(slots) + len(self._free_slots) == self.num_slots, \
            "slot leak/duplication"
        assert len(set(slots)) == len(slots), "slot double-grant"

    def stats(self) -> Dict[str, int]:
        return {"num_slots": self.num_slots, "num_blocks": self.num_blocks,
                "free_slots": len(self._free_slots),
                "free_blocks": len(self._free_blocks),
                "used_blocks": self.used_block_count,
                "allocs": self.allocs, "frees": self.frees,
                "high_water_blocks": self.high_water_blocks,
                "shared_hits": self.shared_hits,
                "shared_tokens_reused": self.shared_tokens_reused,
                "cow_forks": self.cow_forks,
                "registered_prefixes": len(self._registry)}
