"""Multi-tenant request scheduler: weighted fair queueing with a
starvation bound and per-tenant token budgets.

The engine asks this scheduler *which request to admit next* whenever a
slot frees up.  The policy is stride scheduling (virtual-time WFQ): each
tenant carries a virtual finish time ``vtime``; admitting one of its
requests advances ``vtime`` by ``cost / weight`` where ``cost`` is the
request's token footprint (prompt + max_new_tokens).  The tenant with the
smallest ``vtime`` among those with pending, admissible work wins — so
over a busy interval tenants receive token throughput proportional to
their weights, regardless of arrival order or request sizes.

Two production guards sit on top of the pure policy:

* **Starvation bound** — a tenant whose head-of-queue request has been
  passed over ``starvation_bound`` admission rounds is served next
  unconditionally, capping worst-case queueing delay for low-weight
  tenants (weights bound *rates*, not *waits*; this bounds waits).
* **Token budgets** — ``Tenant.token_budget`` caps a tenant's total
  in-flight token footprint; a tenant at budget is skipped (without
  aging the starvation counter — it is throttled, not starved) until
  releases bring it back under.

Preempted requests re-enter at the *front* of their tenant queue via
``requeue_front`` and their cost is not double-charged: the vtime advance
happened at first admission, and re-admission of a previously charged
request is free.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

_ids = itertools.count()

DEFAULT_TENANT = "default"


@dataclasses.dataclass
class Request:
    """One generation request as the scheduler and engine track it."""
    prompt: Sequence[int]
    max_new_tokens: int
    tenant: str = DEFAULT_TENANT
    id: int = dataclasses.field(default_factory=lambda: next(_ids))
    submit_time: float = dataclasses.field(default_factory=time.monotonic)
    # Filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    truncated: bool = False
    preemptions: int = 0
    # Tokens to teacher-force on (re)admission beyond the prompt — set by
    # recompute preemption (dense path) so generation resumes
    # bit-identically.
    resume_tokens: List[int] = dataclasses.field(default_factory=list)
    # Paged copy-free preemption payload (engine's _Spill: host copies of
    # the request's KV pages) — re-admission remaps and uploads instead
    # of recomputing the prefill.
    spill: Optional[object] = dataclasses.field(default=None, repr=False)

    @property
    def cost(self) -> int:
        return len(self.prompt) + self.max_new_tokens

    @property
    def done(self) -> bool:
        return self.finish_time is not None

    @property
    def latency(self) -> Optional[float]:
        return (None if self.finish_time is None
                else self.finish_time - self.submit_time)

    @property
    def ttft(self) -> Optional[float]:
        return (None if self.first_token_time is None
                else self.first_token_time - self.submit_time)


@dataclasses.dataclass
class Tenant:
    """A traffic class: relative weight plus an optional cap on total
    in-flight token footprint."""
    name: str
    weight: float = 1.0
    token_budget: Optional[int] = None

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"tenant {self.name!r}: weight must be > 0")


@dataclasses.dataclass
class _TenantState:
    tenant: Tenant
    queue: Deque[Request] = dataclasses.field(default_factory=deque)
    vtime: float = 0.0
    in_flight_tokens: int = 0
    wait_rounds: int = 0            # admission rounds passed over while ready
    admitted: int = 0
    served_tokens: int = 0
    charged: set = dataclasses.field(default_factory=set)


class FairScheduler:
    """Weighted-fair admission queue over named tenants."""

    def __init__(self, tenants: Optional[Sequence[Tenant]] = None,
                 starvation_bound: int = 8):
        if starvation_bound < 1:
            raise ValueError("starvation_bound must be >= 1")
        self.starvation_bound = int(starvation_bound)
        self._tenants: Dict[str, _TenantState] = {}
        self._vclock = 0.0
        for t in (tenants or [Tenant(DEFAULT_TENANT)]):
            self.add_tenant(t)

    def add_tenant(self, tenant: Tenant) -> None:
        if tenant.name in self._tenants:
            raise ValueError(f"duplicate tenant {tenant.name!r}")
        self._tenants[tenant.name] = _TenantState(tenant=tenant)

    @property
    def tenants(self) -> List[Tenant]:
        return [s.tenant for s in self._tenants.values()]

    # -- queue ops -----------------------------------------------------------

    def submit(self, request: Request) -> Request:
        try:
            st = self._tenants[request.tenant]
        except KeyError:
            raise KeyError(f"unknown tenant {request.tenant!r}; registered: "
                           f"{sorted(self._tenants)}") from None
        st.queue.append(request)
        return request

    def requeue_front(self, request: Request) -> None:
        """Put a preempted request back at the head of its tenant queue."""
        self._tenants[request.tenant].queue.appendleft(request)

    def pending(self) -> int:
        return sum(len(s.queue) for s in self._tenants.values())

    # -- admission -----------------------------------------------------------

    def _budget_ok(self, st: _TenantState, req: Request) -> bool:
        b = st.tenant.token_budget
        return b is None or st.in_flight_tokens + req.cost <= b

    def admit_next(self, predicate=None) -> Optional[Request]:
        """Pop the next request to admit, or None when nothing is
        admissible.  ``predicate(request)`` lets the caller veto on pool
        capacity; vetoed tenants still age toward the starvation bound
        (the scheduler passed them over), budget-capped ones do not."""
        ready: List[Tuple[_TenantState, Request]] = []
        for st in self._tenants.values():
            if not st.queue:
                continue
            req = st.queue[0]
            if not self._budget_ok(st, req):
                continue
            if predicate is not None and not predicate(req):
                st.wait_rounds += 1
                continue
            ready.append((st, req))
        if not ready:
            return None

        starved = [p for p in ready
                   if p[0].wait_rounds >= self.starvation_bound]
        pool = starved or ready
        st, req = min(pool, key=lambda p: (p[0].vtime, p[1].submit_time))
        for other, _ in ready:
            if other is not st:
                other.wait_rounds += 1
        st.wait_rounds = 0
        st.queue.popleft()

        if req.id not in st.charged:
            # Stride accounting: charge the request's footprint once.
            start = max(st.vtime, self._vclock)
            st.vtime = start + req.cost / st.tenant.weight
            self._vclock = start
            st.charged.add(req.id)
        st.in_flight_tokens += req.cost
        st.admitted += 1
        return req

    def release(self, request: Request, served_tokens: int = 0) -> None:
        """Return a request's in-flight footprint (finish or preemption)."""
        st = self._tenants[request.tenant]
        st.in_flight_tokens = max(0, st.in_flight_tokens - request.cost)
        st.served_tokens += served_tokens
        if request.done:
            st.charged.discard(request.id)

    # -- reporting -----------------------------------------------------------

    def fairness_table(self) -> List[Dict[str, object]]:
        rows = []
        for name in sorted(self._tenants):
            st = self._tenants[name]
            rows.append({
                "tenant": name,
                "weight": st.tenant.weight,
                "token_budget": st.tenant.token_budget,
                "queued": len(st.queue),
                "in_flight_tokens": st.in_flight_tokens,
                "admitted": st.admitted,
                "served_tokens": st.served_tokens,
                "vtime": round(st.vtime, 3),
                "wait_rounds": st.wait_rounds,
            })
        return rows
