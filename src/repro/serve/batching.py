"""Continuous-batching slot state and padded batch assembly.

The engine owns ``max_batch`` cache rows (*slots*) plus one scratch row.
Each active request occupies one slot; at every engine step the set of
slots that should advance is gathered into a fixed-width ``(idx, tokens,
pos)`` triple — inactive lanes padded onto the scratch row — so the
jitted decode step compiles exactly once regardless of how many requests
are in flight.

A slot's lifecycle is position-driven.  ``tokens`` holds the prompt plus
everything generated (or teacher-forced on resume); ``pos`` is the next
cache position to process.  A pass at position ``p`` feeds ``tokens[p]``,
writes the KV cache at ``p``, and yields the model's prediction for
``p + 1``:

* ``p + 1 < prompt_len`` → **prefill**: the prediction is discarded,
  the next prompt token is teacher-forced.  (Resume tokens from a
  preemption extend this teacher-forced region past the prompt.)
* otherwise → **decode**: the prediction is appended — the pass at
  ``p = prompt_len - 1`` emits the request's first generated token,
  which is what TTFT clocks.

A request finishes when it has ``max_new_tokens`` generated tokens, or is
*truncated* when its next write would need cache position ``max_seq``
(the pool's :meth:`~repro.serve.pool.KVBlockPool.fits` admission check
guarantees at least one generated token before this can trigger).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.scheduler import Request


# write_start sentinel for idle lanes: no position ever reaches it, so the
# paged step's masked scatter drops every idle-lane write.
NEVER_WRITE = 1 << 30


@dataclasses.dataclass
class SlotState:
    """One admitted request bound to a cache row."""
    slot: int
    request: Request
    tokens: List[int]                 # prompt + teacher-forced + generated
    prompt_len: int                   # teacher-forced prefix length
    target_len: int                   # len == done (prompt + max_new)
    pos: int = 0                      # next cache position to process
    stalled: bool = False             # pool couldn't grow this step
    # Paged prefix sharing: cache writes at pos < write_start are
    # suppressed — those positions live in blocks shared with the donor
    # request and already hold bit-identical K/V.
    write_start: int = 0
    # Paged prefix registration bookkeeping (engine-owned).
    registered_partial: bool = False

    @classmethod
    def admit(cls, slot: int, request: Request,
              shared_tokens: int = 0) -> "SlotState":
        forced = list(request.prompt) + list(request.resume_tokens)
        # With `shared_tokens` prompt positions mapped from already-resident
        # blocks, prefill skips to re-running only the last shared position
        # (recovering its logits without re-writing its KV) — the pass at
        # pos = shared_tokens - 1 behaves exactly as it would have in a
        # from-scratch prefill, so the stream stays bit-exact.
        shared = max(0, min(int(shared_tokens), len(forced)))
        return cls(slot=slot, request=request, tokens=list(forced),
                   prompt_len=len(forced),
                   target_len=len(request.prompt) + request.max_new_tokens,
                   pos=max(0, shared - 1), write_start=shared)

    @classmethod
    def resume(cls, slot: int, request: Request, *, tokens: Sequence[int],
               pos: int, prompt_len: int, target_len: int) -> "SlotState":
        """Rebind a spill-preempted request: its pages were re-uploaded, so
        decoding continues from the exact position it stopped at — no
        teacher-forced recompute."""
        return cls(slot=slot, request=request, tokens=list(tokens),
                   prompt_len=prompt_len, target_len=target_len, pos=pos)

    @property
    def in_prefill(self) -> bool:
        return self.pos < self.prompt_len - 1

    @property
    def generated(self) -> List[int]:
        return self.tokens[len(self.request.prompt):]

    @property
    def num_generated(self) -> int:
        return len(self.tokens) - len(self.request.prompt)

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.target_len

    def needs_tokens(self) -> int:
        """Cache positions a pass at the current ``pos`` requires."""
        return self.pos + 1

    def apply(self, next_token: int, max_seq: int) -> bool:
        """Account one completed pass at ``self.pos``; True when the pass
        emitted (appended) a generated token — the first such pass per
        request is what TTFT clocks."""
        appended = False
        if self.pos + 1 >= self.prompt_len and len(self.tokens) < self.target_len:
            self.tokens.append(int(next_token))
            appended = True
        self.pos += 1
        if not self.done and self.pos >= max_seq:
            # No cache position left for the next write: hard stop.
            self.request.truncated = True
            self.target_len = len(self.tokens)
        return appended


def assemble(slots: Sequence[SlotState], max_batch: int,
             scratch_slot: int) -> Optional[Tuple[np.ndarray, np.ndarray,
                                                  np.ndarray, List[SlotState]]]:
    """Build the fixed-width step arrays for the slots that advance now.

    Returns ``(idx, tokens, pos, stepped)`` with all arrays of length
    ``max_batch`` — unused lanes point at ``scratch_slot`` (duplicate
    scatter writes there are benign: every lane writes the same garbage
    row) — or None when nothing advances this step.
    """
    stepped = [s for s in slots if not s.done and not s.stalled]
    if not stepped:
        return None
    idx = np.full((max_batch,), scratch_slot, dtype=np.int32)
    tok = np.zeros((max_batch,), dtype=np.int32)
    pos = np.zeros((max_batch,), dtype=np.int32)
    for lane, s in enumerate(stepped):
        idx[lane] = s.slot
        tok[lane] = s.tokens[s.pos]
        pos[lane] = s.pos
    return idx, tok, pos, stepped


def assemble_paged(slots: Sequence[SlotState], max_batch: int,
                   scratch_slot: int, blocks_per_slot: int, blocks_of
                   ) -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray,
                                       np.ndarray, np.ndarray,
                                       List[SlotState]]]:
    """The paged analogue of :func:`assemble`: adds the padded fixed-width
    block-table array and the per-lane first writable position.

    Returns ``(idx, table, tok, pos, write_start, stepped)`` — ``idx``
    still points idle lanes at the scratch row (the *gather* of slot-major
    recurrent rows must stay in bounds; the engine's scatter drops them),
    ``table`` is (max_batch, blocks_per_slot) physical block ids padded
    with 0 (idle lanes and positions past a request's grant are masked
    reads / suppressed writes), and ``write_start`` is ``NEVER_WRITE`` on
    idle lanes so the single masked page scatter drops them."""
    stepped = [s for s in slots if not s.done and not s.stalled]
    if not stepped:
        return None
    idx = np.full((max_batch,), scratch_slot, dtype=np.int32)
    table = np.zeros((max_batch, blocks_per_slot), dtype=np.int32)
    tok = np.zeros((max_batch,), dtype=np.int32)
    pos = np.zeros((max_batch,), dtype=np.int32)
    wstart = np.full((max_batch,), NEVER_WRITE, dtype=np.int32)
    for lane, s in enumerate(stepped):
        idx[lane] = s.slot
        blocks = blocks_of(s)
        table[lane, :len(blocks)] = blocks
        tok[lane] = s.tokens[s.pos]
        pos[lane] = s.pos
        wstart[lane] = s.write_start
    return idx, table, tok, pos, wstart, stepped
