"""Serve engine: continuous batching over a slot-pooled KV cache.

:class:`ServeEngine` is the production serving path.  It owns
``max_batch`` cache rows (*slots*) plus one scratch row, a
:class:`~repro.serve.pool.KVBlockPool` accounting for the cache
positions those rows hold, and a
:class:`~repro.serve.scheduler.FairScheduler` deciding which tenant's
request gets the next free slot.  Every engine step:

1. **evict** — finished slots release their pool blocks and stamp
   latency/TTFT on their :class:`~repro.serve.scheduler.Request`;
2. **admit** — the fair scheduler fills freed slots (chunked prefill:
   new prompts are teacher-forced through the same decode step, so
   admission needs no separate prefill kernel and a long prompt never
   blocks the running decodes);
3. **advance** — every live slot that the pool can grow moves one
   position through ONE fixed-shape jitted decode pass (slots gather
   their cache rows, step at per-row positions, scatter back; idle
   lanes pad onto the scratch row, so the step compiles exactly once);
4. **preempt** — if nothing could advance (pool exhausted), the
   youngest request is returned to its tenant queue with its generated
   tokens as teacher-forced resume state (recompute preemption).

Kernel schedules come from the cache index via
:func:`repro.sched.lowering.schedule_plan` (re-exported here) —
nearest-bucket pure lookups at construction time, **zero**
autotune/``Machine.run`` on the serve path.

The module-level :func:`generate` stays as the one-shot, jit-able
static-batch convenience wrapper (one ``lax.scan`` over the same
``decode_step``); ``ServeEngine.generate`` is its engine-backed
equivalent.  Under greedy decoding the two are bit-exact per request
for batch-independent (non-MoE-capacity) configs — see
``tests/test_serve_engine.py``.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dist import sharding as shd
from repro.sched.cache import ScheduleCache
from repro.sched.lowering import schedule_plan  # noqa: F401  (serve-facing API)
from repro.serve.batching import (NEVER_WRITE, SlotState, assemble,
                                  assemble_paged)
from repro.serve.decode import (decode_step, init_caches, init_paged_caches,
                                paged_cache_kinds, paged_decode_step)
from repro.serve.pool import KVBlockPool, PoolCapacityError, PoolError  # noqa: F401
from repro.serve.scheduler import (DEFAULT_TENANT, FairScheduler, Request,
                                   Tenant)

# One compiled (step, reset) pair per (config, mesh[, paged geometry]):
# engines in a sweep share tracing/compilation instead of re-jitting per
# instance.
_STEP_FNS: Dict = {}


def _cfg_key(cfg: ModelConfig) -> str:
    return json.dumps(dataclasses.asdict(cfg), sort_keys=True, default=str)


def _step_fns(cfg: ModelConfig, mesh):
    key = (_cfg_key(cfg), None if mesh is None else id(mesh))
    if key not in _STEP_FNS:
        def step(params, caches, idx, tok, pos):
            # Gather the advancing rows, step them at their own positions,
            # scatter back.  Idle lanes (gathered from the scratch row) are
            # routed to an out-of-range row and dropped — ONE masked
            # scatter, no duplicate scratch-row writes to race under
            # donated buffers.
            rows = jax.tree.map(lambda a: a[idx], caches)
            logits, new_rows = decode_step(params, rows, tok[:, None], pos,
                                           cfg, mesh=mesh)
            scratch = jax.tree.leaves(caches)[0].shape[0] - 1
            sidx = jnp.where(idx == scratch, scratch + 1, idx)
            caches = jax.tree.map(
                lambda a, r: a.at[sidx].set(r.astype(a.dtype), mode="drop"),
                caches, new_rows)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

        def reset(caches, idx):
            # Zero rows for newly admitted requests: attention masks hide
            # a previous occupant's KV, but SSM/conv recurrent state would
            # otherwise leak across requests.
            return jax.tree.map(lambda a: a.at[idx].set(0), caches)

        _STEP_FNS[key] = (jax.jit(step), jax.jit(reset))
    return _STEP_FNS[key]


def _paged_step_fns(cfg: ModelConfig, mesh, max_seq: int):
    """(step, reset) for the paged path.  ``"paged"`` cache entries pass
    through whole (lanes address them via the block table); ``"slot"``
    entries (recurrent state) gather/scatter by slot exactly as the dense
    path — with idle lanes dropped by the same out-of-range trick."""
    key = (_cfg_key(cfg), None if mesh is None else id(mesh),
           "paged", int(max_seq))
    if key not in _STEP_FNS:
        kinds = paged_cache_kinds(cfg)

        def step(params, caches, idx, table, tok, pos, wstart):
            write_mask = pos >= wstart
            rows = [jax.tree.map(lambda a: a[idx], c) if kind == "slot"
                    else c for c, kind in zip(caches, kinds)]
            logits, new = paged_decode_step(params, rows, table, tok[:, None],
                                            pos, write_mask, cfg, max_seq,
                                            mesh=mesh)
            out = []
            for c, n, kind in zip(caches, new, kinds):
                if kind == "slot":
                    scratch = jax.tree.leaves(c)[0].shape[0] - 1
                    sidx = jnp.where(idx == scratch, scratch + 1, idx)
                    out.append(jax.tree.map(
                        lambda a, r: a.at[sidx].set(r.astype(a.dtype),
                                                    mode="drop"), c, n))
                else:
                    out.append(n)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), out

        def reset(caches, idx):
            # Only recurrent slot rows need zeroing on admission; page
            # contents are never read unmasked before being written.
            return [jax.tree.map(lambda a: a.at[idx].set(0), c)
                    if kind == "slot" else c
                    for c, kind in zip(caches, kinds)]

        _STEP_FNS[key] = (jax.jit(step), jax.jit(reset))
    return _STEP_FNS[key]


@dataclasses.dataclass
class _Spill:
    """Host-side copy of a preempted request's KV pages (+ recurrent slot
    rows) — the payload of copy-free preemption.  Travels on
    ``Request.spill`` through the scheduler queue; re-admission allocates
    ``n_blocks`` fresh blocks and uploads ``data`` into them, so decoding
    resumes at ``pos`` bit-exactly with zero recompute."""
    tokens: List[int]
    pos: int
    prompt_len: int
    target_len: int
    n_blocks: int
    data: List


class ServeEngine:
    """Continuous-batching multi-tenant serving over ``decode_step``.

    Construct through :meth:`from_config` — the single supported path::

        engine = ServeEngine.from_config(cfg, schedule_cache=cache,
                                         max_batch=8, max_seq=256)
        req = engine.submit(prompt_tokens, max_new_tokens=64, tenant="a")
        engine.run()            # or engine.step() per tick under a loadgen
        req.output              # generated tokens; req.ttft / req.latency

    ``admission="gang"`` degrades the engine to static batching (admit
    only into an idle engine, wait for the whole gang to finish) — the
    baseline ``bench_serve.py`` compares continuous batching against.
    """

    def __init__(self, cfg: ModelConfig, *, params: Optional[Dict] = None,
                 max_batch: int = 8, max_seq: int = 128,
                 block_size: int = 16, kv_blocks: Optional[int] = None,
                 tenants: Optional[Sequence[Tenant]] = None,
                 starvation_bound: int = 8, prefill_chunk: int = 4,
                 admission: str = "continuous",
                 paged: bool = False, share_prefix: bool = True,
                 debug_invariants: bool = False,
                 schedule_cache: Optional[Union[ScheduleCache, str]] = None,
                 on_missing: str = "baseline",
                 mesh=None, rng_seed: int = 0):
        if cfg.family == "encdec":
            raise ValueError("ServeEngine serves decoder-only families; "
                             "use examples/serve_decode.py for enc-dec")
        if admission not in ("continuous", "gang"):
            raise ValueError(f"admission must be 'continuous' or 'gang', "
                             f"got {admission!r}")
        if max_batch < 1 or prefill_chunk < 0:
            raise ValueError("need max_batch >= 1 and prefill_chunk >= 0")
        self.cfg = cfg
        self.max_batch = int(max_batch)
        self.max_seq = int(max_seq)
        self.scratch_slot = self.max_batch          # extra padded cache row
        self.admission = admission
        self.prefill_chunk = int(prefill_chunk)
        self.mesh = mesh
        self.paged = bool(paged)
        self.debug_invariants = bool(debug_invariants)
        # Prefix sharing needs the cache content at a position to be a pure
        # function of the token prefix — true for attention/MLA pages,
        # false for recurrent state (ssm/hybrid carry per-request rows).
        self.share_prefix = (self.paged and bool(share_prefix)
                             and cfg.family in ("dense", "moe"))

        self.pool = KVBlockPool(self.max_batch, self.max_seq,
                                block_size=block_size, num_blocks=kv_blocks)
        self.scheduler = FairScheduler(tenants,
                                       starvation_bound=starvation_bound)

        if params is None:
            from repro.models import lm
            params = lm.init_model(cfg, jax.random.PRNGKey(rng_seed))
        self.params = params
        if self.paged:
            self._kinds = paged_cache_kinds(cfg)
            self.caches = init_paged_caches(cfg, self.pool.num_blocks,
                                            self.pool.block_size,
                                            self.max_batch)
        else:
            self._kinds = None
            self.caches = init_caches(cfg, self.max_batch + 1, self.max_seq)
        if mesh is not None:
            from repro.models import lm
            self.params = jax.device_put(
                self.params, shd.param_shardings(lm.model_spec(cfg), mesh))
            self.caches = jax.device_put(
                self.caches, shd.kv_pool_shardings(cfg, self.caches, mesh,
                                                   kinds=self._kinds))
        if self.paged:
            self._step_fn, self._reset_fn = _paged_step_fns(cfg, mesh,
                                                            self.max_seq)
        else:
            self._step_fn, self._reset_fn = _step_fns(cfg, mesh)

        if isinstance(schedule_cache, str):
            schedule_cache = ScheduleCache(schedule_cache)
        self.schedule_cache = schedule_cache
        if schedule_cache is not None:
            # Lazy import: launch.specs imports repro.serve at module load.
            from repro.launch.specs import kernel_fleet
            # on_missing="baseline" (default): kernels with missing/corrupt
            # cached schedules degrade to the -O3 baseline (None plan
            # entries, counted below); "raise" refuses to start degraded
            self.plan = schedule_plan(kernel_fleet(cfg), cache=schedule_cache,
                                      on_missing=on_missing)
        else:
            self.plan = {}

        self._active: List[SlotState] = []
        self.finished: List[Request] = []
        self.counters = {"engine_steps": 0, "passes": 0, "lane_tokens": 0,
                         "admissions": 0, "stalls": 0, "preemptions": 0,
                         "truncations": 0, "max_active": 0,
                         "prefix_hits": 0, "cow_forks": 0,
                         "preempt_spills": 0, "resume_uploads": 0,
                         "schedule_fallbacks": sum(
                             1 for art in self.plan.values() if art is None)}

    @classmethod
    def from_config(cls, cfg: ModelConfig, **kwargs) -> "ServeEngine":
        """The one constructor path (see class docstring for the knobs)."""
        return cls(cfg, **kwargs)

    # -- request intake ------------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               tenant: str = DEFAULT_TENANT) -> Request:
        """Queue a request.  Raises :class:`PoolCapacityError` immediately
        when the prompt can never be served (``len(prompt) >= max_seq``
        leaves no cache position for even one generated token — the old
        silent out-of-range cache write, now a typed admission error)."""
        prompt = [int(t) for t in prompt]
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if not self.pool.fits(len(prompt)):
            raise PoolCapacityError(
                f"prompt of {len(prompt)} tokens can never be admitted: "
                f"max_seq={self.max_seq} needs len(prompt) < max_seq so the "
                f"first generated token has a cache position")
        req = Request(prompt=prompt, max_new_tokens=int(max_new_tokens),
                      tenant=tenant)
        budget = next((t.token_budget for t in self.scheduler.tenants
                       if t.name == tenant), None)
        if budget is not None and req.cost > budget:
            raise ValueError(
                f"request cost {req.cost} exceeds tenant {tenant!r} token "
                f"budget {budget}; it could never be admitted")
        return self.scheduler.submit(req)

    # -- the serve loop ------------------------------------------------------

    def step(self) -> int:
        """One engine tick: evict, admit, advance, preempt-on-stall.
        Returns the number of slot advances made.

        Prefill is folded into the decode passes (chunked admission): a
        tick runs one full-width pass, plus up to ``prefill_chunk`` more
        while any slot is still teacher-forcing its prompt — every pass
        advances *all* eligible slots, so prompt catch-up never drops
        lane occupancy and never stalls the running decodes."""
        self._evict()
        self._admit()
        self.counters["max_active"] = max(self.counters["max_active"],
                                          len(self._active))
        for s in self._active:
            s.stalled = False
        advanced = 0
        for _ in range(1 + self.prefill_chunk):
            n = self._pass()
            advanced += n
            if n == 0 or not any(s.in_prefill and not s.done
                                 and not s.stalled for s in self._active):
                break
        self._evict()
        if advanced == 0 and self._active:
            self._preempt_youngest()
        self.counters["engine_steps"] += 1
        if self.debug_invariants:
            self.pool.check()
        return advanced

    def run(self, max_steps: int = 1_000_000) -> List[Request]:
        """Drain every queued/active request; returns finished requests
        in completion order."""
        while self._active or self.scheduler.pending():
            if max_steps <= 0:
                raise RuntimeError(
                    f"serve loop did not drain: {len(self._active)} active, "
                    f"{self.scheduler.pending()} pending")
            self.step()
            max_steps -= 1
        return list(self.finished)

    def generate(self, prompt: Sequence[int], max_new_tokens: int,
                 tenant: str = DEFAULT_TENANT) -> List[int]:
        """One-shot convenience over the engine: submit, drain, return
        ``prompt + generated`` (the engine-side equivalent of the
        module-level static-batch :func:`generate`)."""
        req = self.submit(prompt, max_new_tokens, tenant)
        self.run()
        return list(req.prompt) + list(req.output)

    # -- internals -----------------------------------------------------------

    def _admissible(self, req: Request) -> bool:
        if not self.paged:
            return self.pool.can_admit(
                len(req.prompt) + len(req.resume_tokens))
        if req.spill is not None:
            # Re-granting just the spilled pages is not enough: the request
            # must also be able to grow into its next write position, or a
            # resume under pressure re-creates the stall that spilled it
            # (resume → everyone blocked → preempt youngest → resume …).
            return self.pool.can_resume(
                self.pool.blocks_for(req.spill.pos + 1))
        if self.share_prefix:
            return self.pool.can_admit_shared(req.prompt)
        return self.pool.can_admit(len(req.prompt))

    def _admit(self) -> None:
        if self.admission == "gang" and self._active:
            return           # static batching: wait for the gang to finish
        fresh: List[int] = []
        resumed = []
        while len(self._active) < self.max_batch:
            req = self.scheduler.admit_next(predicate=self._admissible)
            if req is None:
                break
            if self.paged and req.spill is not None:
                table = self.pool.alloc_resume(req.id, req.spill.n_blocks)
                self._active.append(SlotState.resume(
                    table.slot, req, tokens=req.spill.tokens,
                    pos=req.spill.pos, prompt_len=req.spill.prompt_len,
                    target_len=req.spill.target_len))
                resumed.append((table, req.spill))
                req.spill = None
            elif self.paged and self.share_prefix:
                table = self.pool.alloc_shared(req.id, req.prompt)
                if table.shared_tokens:
                    self.counters["prefix_hits"] += 1
                self._active.append(SlotState.admit(
                    table.slot, req, shared_tokens=table.shared_tokens))
            elif self.paged:
                table = self.pool.alloc(req.id, len(req.prompt))
                self._active.append(SlotState.admit(table.slot, req))
            else:
                table = self.pool.alloc(
                    req.id, len(req.prompt) + len(req.resume_tokens))
                self._active.append(SlotState.admit(table.slot, req))
            fresh.append(table.slot)
            self.counters["admissions"] += 1
        if fresh:
            idx = np.full((self.max_batch,), self.scratch_slot, np.int32)
            idx[:len(fresh)] = fresh
            self.caches = self._reset_fn(self.caches, jnp.asarray(idx))
        for table, spill in resumed:
            self._upload_spill(table, spill)
            self.counters["resume_uploads"] += 1

    def _pass(self) -> int:
        cand: List[SlotState] = []
        forks: List = []
        for s in self._active:
            if s.done or s.stalled:
                continue
            if self.paged:
                write = s.pos >= s.write_start
                if self.pool.can_advance(s.request.id, s.pos, write=write):
                    pair = self.pool.advance(s.request.id, s.pos, write=write)
                    if pair is not None:
                        forks.append(pair)
                    cand.append(s)
                else:
                    s.stalled = True
                    self.counters["stalls"] += 1
            elif self.pool.can_ensure(s.request.id, s.needs_tokens()):
                self.pool.ensure(s.request.id, s.needs_tokens())
                cand.append(s)
            else:
                s.stalled = True
                self.counters["stalls"] += 1
        if forks:
            self._copy_blocks(forks)
        if self.paged:
            asm = assemble_paged(
                cand, self.max_batch, self.scratch_slot,
                self.pool.blocks_per_slot,
                lambda s: self.pool.table(s.request.id).blocks)
            if asm is None:
                return 0
            idx, table, tok, pos, wstart, stepped = asm
            nxt, self.caches = self._step_fn(
                self.params, self.caches, jnp.asarray(idx),
                jnp.asarray(table), jnp.asarray(tok), jnp.asarray(pos),
                jnp.asarray(wstart))
        else:
            asm = assemble(cand, self.max_batch, self.scratch_slot)
            if asm is None:
                return 0
            idx, tok, pos, stepped = asm
            nxt, self.caches = self._step_fn(
                self.params, self.caches, jnp.asarray(idx), jnp.asarray(tok),
                jnp.asarray(pos))
        nxt = np.asarray(nxt)
        now = time.monotonic()
        for lane, s in enumerate(stepped):
            appended = s.apply(int(nxt[lane]), self.max_seq)
            if appended and s.request.first_token_time is None:
                s.request.first_token_time = now
            if s.request.truncated:
                self.counters["truncations"] += 1
            if self.share_prefix:
                self.pool.commit(s.request.id, s.tokens, s.pos,
                                 prompt_len=s.prompt_len)
        self.counters["passes"] += 1
        self.counters["lane_tokens"] += len(stepped)
        return len(stepped)

    def _copy_blocks(self, forks: List) -> None:
        """Apply copy-on-write forks: device-copy each ``src`` page onto
        its ``dst`` before this pass writes into it."""
        src = jnp.asarray([a for a, _ in forks])
        dst = jnp.asarray([b for _, b in forks])
        self.caches = [
            jax.tree.map(lambda a: a.at[dst].set(a[src]), c)
            if kind == "paged" else c
            for c, kind in zip(self.caches, self._kinds)]
        self.counters["cow_forks"] += len(forks)

    def _spill(self, victim: SlotState) -> "_Spill":
        """Copy the victim's pages (and recurrent slot rows) to host
        memory so preemption frees its device blocks without losing the
        computed KV — resume is a remap + upload, not a recompute."""
        t = self.pool.table(victim.request.id)
        ids = jnp.asarray(t.blocks)
        data = []
        for c, kind in zip(self.caches, self._kinds):
            if kind == "paged":
                data.append(jax.tree.map(lambda a: np.asarray(a[ids]), c))
            else:
                data.append(jax.tree.map(
                    lambda a: np.asarray(a[victim.slot]), c))
        return _Spill(tokens=list(victim.tokens), pos=victim.pos,
                      prompt_len=victim.prompt_len,
                      target_len=victim.target_len,
                      n_blocks=t.num_blocks, data=data)

    def _upload_spill(self, table, spill: "_Spill") -> None:
        ids = jnp.asarray(table.blocks)
        self.caches = [
            jax.tree.map(lambda a, h: a.at[ids].set(jnp.asarray(h, a.dtype)),
                         c, d)
            if kind == "paged" else
            jax.tree.map(lambda a, h: a.at[table.slot].set(
                jnp.asarray(h, a.dtype)), c, d)
            for c, d, kind in zip(self.caches, spill.data, self._kinds)]

    def _evict(self) -> None:
        done = [s for s in self._active if s.done]
        if not done:
            return
        now = time.monotonic()
        for s in done:
            req = s.request
            req.output = list(s.generated)
            req.finish_time = now
            self.scheduler.release(req, served_tokens=s.num_generated)
            self.pool.free(req.id)
            self._active.remove(s)
            self.finished.append(req)

    def _preempt_youngest(self) -> None:
        victim = max(self._active,
                     key=lambda s: (s.request.submit_time, s.request.id))
        req = victim.request
        generated = list(victim.generated)
        if self.paged:
            if self.pool.blocks_for(victim.pos + 1) > self.pool.num_blocks:
                # It could never advance even owning the whole pool:
                # finish it truncated rather than starve the queue.
                self._active.remove(victim)
                self.pool.free(req.id)
                req.truncated = True
                req.output = generated
                req.finish_time = time.monotonic()
                self.scheduler.release(req, served_tokens=len(generated))
                self.finished.append(req)
                self.counters["truncations"] += 1
                return
            # Copy-free preemption: spill the pages block-by-block, free
            # the device blocks, resume later by remap + upload — no
            # teacher-forced recompute of the prefill.
            req.spill = self._spill(victim)
            self._active.remove(victim)
            self.pool.free(req.id)
            req.preemptions += 1
            self.scheduler.release(req, served_tokens=0)
            self.scheduler.requeue_front(req)
            self.counters["preemptions"] += 1
            self.counters["preempt_spills"] += 1
            return
        self._active.remove(victim)
        self.pool.free(req.id)
        if len(req.prompt) + len(generated) >= self.max_seq:
            # Resuming would need the whole cache for teacher-forcing:
            # finish it truncated rather than starve the queue.
            req.truncated = True
            req.output = generated
            req.finish_time = time.monotonic()
            self.scheduler.release(req, served_tokens=len(generated))
            self.finished.append(req)
            self.counters["truncations"] += 1
            return
        req.resume_tokens = generated
        req.preemptions += 1
        self.scheduler.release(req, served_tokens=0)
        self.scheduler.requeue_front(req)
        self.counters["preemptions"] += 1

    # -- reporting -----------------------------------------------------------

    @property
    def active(self) -> int:
        return len(self._active)

    def kv_bytes_allocated(self) -> int:
        """Device bytes backing the KV cache pytree.  Paged mode scales
        with ``kv_blocks × block_size``; dense mode with
        ``(max_batch + 1) × max_seq`` regardless of occupancy — the
        memory-proportionality win the paged layout exists for."""
        return int(sum(leaf.size * jnp.dtype(leaf.dtype).itemsize
                       for leaf in jax.tree.leaves(self.caches)))

    def peak_kv_bytes(self) -> int:
        """High-water KV footprint actually addressed: paged mode scales
        the page bytes by the pool's high-water block count; dense mode
        pins the full allocation from construction."""
        if not self.paged:
            return self.kv_bytes_allocated()
        paged_bytes = slot_bytes = 0
        for c, kind in zip(self.caches, self._kinds):
            n = sum(leaf.size * jnp.dtype(leaf.dtype).itemsize
                    for leaf in jax.tree.leaves(c))
            if kind == "paged":
                paged_bytes += n
            else:
                slot_bytes += n
        frac = self.pool.high_water_blocks / max(1, self.pool.num_blocks)
        return int(paged_bytes * frac + slot_bytes)

    def stats(self) -> Dict[str, object]:
        c = dict(self.counters)
        c["lane_utilization"] = (
            c["lane_tokens"] / (c["passes"] * self.max_batch)
            if c["passes"] else 0.0)
        c["kv_bytes_allocated"] = self.kv_bytes_allocated()
        c["peak_kv_bytes"] = self.peak_kv_bytes()
        return {"engine": c, "pool": self.pool.stats(),
                "tenants": self.scheduler.fairness_table()}

    def plan_summary(self) -> List[str]:
        """``kernel@bucket [target]: state`` lines for the resolved plan."""
        lines = []
        for key, art in sorted(self.plan.items(), key=str):
            name, bucket = key if isinstance(key, tuple) else (key, "default")
            label = name if bucket == "default" else f"{name}@{bucket}"
            if art is not None:
                target = art.target or "-"
                lines.append(f"{label} [{target}]: {art.speedup:.3f}x "
                             f"({art.optimized_cycles:.0f} cycles)")
            else:
                lines.append(f"{label}: not optimized (-O3 baseline)")
        return lines


def generate(params: Dict, cfg: ModelConfig, prompt: jax.Array,
             max_new_tokens: int, max_seq: Optional[int] = None,
             mesh=None) -> jax.Array:
    """One-shot static-batch convenience: (B, P) int32 prompt ->
    (B, P + max_new_tokens) greedy tokens in a single jit-able
    ``lax.scan`` over :func:`repro.serve.decode.decode_step`.

    This is the documented convenience wrapper for "run these B prompts
    to completion, nothing else going on" — benchmark cells and tests.
    Anything resembling a service (requests arriving over time, mixed
    lengths, tenants) belongs on :class:`ServeEngine`, which drives the
    *same* decode step per-row and matches this function token-for-token
    under greedy decoding (pass the engine's ``max_seq`` here so cache
    geometry — and hence float summation order — is identical).

    With ``mesh`` given, params and caches are placed by the dist-layer
    rules before the token loop, so the scanned decode step runs sharded
    (head-sharded KV for GQA, sequence-sharded for MQA/long-context)."""
    B, P = prompt.shape
    total = P + max_new_tokens
    max_seq = max_seq or total
    caches = init_caches(cfg, B, max_seq)
    if mesh is not None:
        from repro.models import encdec, lm
        model = encdec if cfg.family == "encdec" else lm
        params = jax.device_put(
            params, shd.param_shardings(model.model_spec(cfg), mesh))
        caches = jax.device_put(
            caches, shd.decode_cache_shardings(cfg, caches, mesh))
        prompt = jax.device_put(
            prompt, jax.sharding.NamedSharding(
                mesh, shd.batch_spec(mesh, B)))
    tokens0 = jnp.concatenate(
        [prompt, jnp.zeros((B, max_new_tokens), jnp.int32)], axis=1)

    def body(carry, pos):
        tokens, caches = carry
        tok = jax.lax.dynamic_slice_in_dim(tokens, pos, 1, axis=1)
        logits, caches = decode_step(params, caches, tok, pos, cfg, mesh=mesh)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # teacher-force inside the prompt, write greedy tokens after it
        write_pos = pos + 1
        keep = write_pos < P
        cur = jax.lax.dynamic_slice_in_dim(tokens, jnp.minimum(write_pos,
                                                               total - 1),
                                           1, axis=1)[:, 0]
        val = jnp.where(keep, cur, nxt)
        tokens = jax.lax.dynamic_update_slice_in_dim(
            tokens, val[:, None], jnp.minimum(write_pos, total - 1), axis=1)
        return (tokens, caches), None

    (tokens, _), _ = jax.lax.scan(body, (tokens0, caches),
                                  jnp.arange(total - 1))
    return tokens
