"""Batched serving driver: greedy generation over the decode step.

The prompt is teacher-forced through the same decode path (correct and
simple — production prefill lives in the forward pass; see launch/specs.py
prefill cells), then continuation tokens are sampled greedily.  The whole
token loop is one lax.scan, so serving compiles to a single program.
"""

from __future__ import annotations

from typing import Dict, Iterable, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import sharding as shd
from repro.sched.cache import (DEFAULT_CACHE_DIR, TARGET, Artifact,
                               ScheduleCache)
from repro.sched.lowering import resolve_schedule
from repro.sched.scenario import MachineTarget, Scenario
from repro.serve.decode import decode_step, init_caches

FleetItem = Union[str, Tuple[str, Optional[Scenario]]]


def schedule_plan(kernel_names: Iterable[FleetItem],
                  cache_dir: str = DEFAULT_CACHE_DIR,
                  target: Union[str, MachineTarget] = TARGET,
                  cache: Optional[ScheduleCache] = None,
                  scenario: Optional[Scenario] = None
                  ) -> Dict[Union[str, Tuple[str, str]], Optional[Artifact]]:
    """Deploy-time schedule lookup for the engine's kernel fleet.

    ``kernel_names`` takes bare registry names (legacy: keys are the
    names, resolved at ``scenario`` — the engine's current traffic point,
    or the default bucket when ``None``) and/or the ``(kernel, scenario)``
    pairs :func:`repro.launch.specs.kernel_fleet` yields (keys are
    ``(name, bucket)``, one resolution per workload the model serves).

    Every resolution goes through the
    :func:`repro.sched.lowering.resolve_schedule` dispatch shim: nearest
    tuned scenario bucket, pure index lookup — **no** autotune and no
    machine execution at serve time (the paper's §4.2 search/deploy
    split).  ``None`` marks a kernel that was never optimized (it serves
    the -O3 baseline).  An unreadable/unknown-version cache raises loudly
    rather than silently degrading a production rollout.
    """
    sc = cache if cache is not None else ScheduleCache(cache_dir, target)
    plan: Dict[Union[str, Tuple[str, str]], Optional[Artifact]] = {}
    for item in kernel_names:
        if isinstance(item, str):
            plan[item] = resolve_schedule(sc, item, scenario)
        else:
            name, scen = item
            key = (name, scen.bucket if scen is not None else "default")
            plan[key] = resolve_schedule(sc, name, scen)
    return plan


def generate(params: Dict, cfg: ModelConfig, prompt: jax.Array,
             max_new_tokens: int, max_seq: Optional[int] = None,
             mesh=None) -> jax.Array:
    """prompt: (B, P) int32 -> (B, P + max_new_tokens) greedy tokens.

    With ``mesh`` given, params and caches are placed by the dist-layer
    rules before the token loop, so the scanned decode step runs sharded
    (head-sharded KV for GQA, sequence-sharded for MQA/long-context)."""
    B, P = prompt.shape
    total = P + max_new_tokens
    max_seq = max_seq or total
    caches = init_caches(cfg, B, max_seq)
    if mesh is not None:
        from repro.models import encdec, lm
        model = encdec if cfg.family == "encdec" else lm
        params = jax.device_put(
            params, shd.param_shardings(model.model_spec(cfg), mesh))
        caches = jax.device_put(
            caches, shd.decode_cache_shardings(cfg, caches, mesh))
        prompt = jax.device_put(
            prompt, jax.sharding.NamedSharding(
                mesh, shd.batch_spec(mesh, B)))
    tokens0 = jnp.concatenate(
        [prompt, jnp.zeros((B, max_new_tokens), jnp.int32)], axis=1)

    def body(carry, pos):
        tokens, caches = carry
        tok = jax.lax.dynamic_slice_in_dim(tokens, pos, 1, axis=1)
        logits, caches = decode_step(params, caches, tok, pos, cfg, mesh=mesh)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # teacher-force inside the prompt, write greedy tokens after it
        write_pos = pos + 1
        keep = write_pos < P
        cur = jax.lax.dynamic_slice_in_dim(tokens, jnp.minimum(write_pos,
                                                               total - 1),
                                           1, axis=1)[:, 0]
        val = jnp.where(keep, cur, nxt)
        tokens = jax.lax.dynamic_update_slice_in_dim(
            tokens, val[:, None], jnp.minimum(write_pos, total - 1), axis=1)
        return (tokens, caches), None

    (tokens, _), _ = jax.lax.scan(body, (tokens0, caches),
                                  jnp.arange(total - 1))
    return tokens
