"""Synthetic-traffic load generator for the serve engine.

Produces a Poisson-arrival trace (exponential inter-arrival gaps at an
offered QPS) with mixed prompt/output length distributions across N
weighted tenants, then drives a :class:`~repro.serve.engine.ServeEngine`
against the wall clock: requests are submitted when their arrival time
comes due, the engine ticks in between, and the engine's own
submit/first-token/finish timestamps yield p50/p99 end-to-end latency,
TTFT, and delivered tokens/s vs the offered rate.

Everything is seeded — the same :class:`TrafficConfig` replays the same
trace (same prompts, same lengths, same arrival offsets), so an A/B run
(continuous vs gang admission, plans on vs off) sees identical offered
load and differs only in the engine under test.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve.scheduler import Request


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """One synthetic workload: Poisson arrivals at ``qps`` with uniform
    prompt/output length mixes over ``n_tenants`` round-robin tenants.

    With ``prefix_tokens > 0`` every prompt starts with one of
    ``prefix_groups`` shared system-prompt prefixes, chosen per request
    from a Zipf-like distribution (group ``g`` has weight
    ``1 / (g + 1) ** prefix_zipf``) — the hot group dominates, which is
    what makes paged prefix sharing pay off.  Prefix material comes from
    a *separate* rng stream seeded from ``seed``, so a config with
    ``prefix_tokens=0`` replays token-for-token the same trace it did
    before this knob existed."""
    qps: float = 8.0
    n_requests: int = 32
    n_tenants: int = 2
    prompt_len: tuple = (4, 24)          # inclusive uniform range
    output_len: tuple = (4, 24)
    vocab: int = 256
    seed: int = 0
    prefix_tokens: int = 0               # shared prefix length (0 = off)
    prefix_groups: int = 4               # distinct shared prefixes
    prefix_zipf: float = 1.5             # group popularity skew

    def __post_init__(self):
        if self.qps <= 0 or self.n_requests < 1 or self.n_tenants < 1:
            raise ValueError("need qps > 0, n_requests >= 1, n_tenants >= 1")
        if self.prefix_tokens < 0 or self.prefix_groups < 1:
            raise ValueError("need prefix_tokens >= 0, prefix_groups >= 1")


@dataclasses.dataclass
class Arrival:
    at: float                            # seconds from trace start
    tenant: str
    prompt: List[int]
    max_new_tokens: int


def poisson_trace(traffic: TrafficConfig,
                  tenant_names: Optional[Sequence[str]] = None
                  ) -> List[Arrival]:
    """The deterministic arrival list for a traffic config."""
    rng = np.random.default_rng(traffic.seed)
    names = (list(tenant_names) if tenant_names is not None
             else [f"t{i}" for i in range(traffic.n_tenants)])
    prefixes: List[List[int]] = []
    groups = None
    if traffic.prefix_tokens:
        # Separate stream: adding/removing the prefix knob must not
        # perturb the base trace (arrival gaps, lengths, suffix tokens).
        prng = np.random.default_rng((traffic.seed, 0x5E1F))
        prefixes = [prng.integers(0, traffic.vocab, size=traffic.prefix_tokens,
                                  dtype=np.int32).tolist()
                    for _ in range(traffic.prefix_groups)]
        w = 1.0 / (np.arange(traffic.prefix_groups) + 1.0) ** traffic.prefix_zipf
        groups = prng.choice(traffic.prefix_groups,
                             size=traffic.n_requests, p=w / w.sum())
    arrivals, t = [], 0.0
    for i in range(traffic.n_requests):
        t += float(rng.exponential(1.0 / traffic.qps))
        plen = int(rng.integers(traffic.prompt_len[0],
                                traffic.prompt_len[1] + 1))
        olen = int(rng.integers(traffic.output_len[0],
                                traffic.output_len[1] + 1))
        prompt = rng.integers(0, traffic.vocab, size=plen,
                              dtype=np.int32).tolist()
        if prefixes:
            prompt = prefixes[int(groups[i])] + prompt
        arrivals.append(Arrival(at=t, tenant=names[i % len(names)],
                                prompt=prompt, max_new_tokens=olen))
    return arrivals


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


def run_load(engine, traffic: TrafficConfig, *, pace: bool = True
             ) -> Dict[str, object]:
    """Drive the engine with the trace; returns the measured report.

    ``pace=True`` replays arrivals against the wall clock (the engine
    idles if it outruns the offered rate — what a latency-vs-QPS sweep
    wants).  ``pace=False`` submits each arrival as soon as its time is
    *reached or passed* by busy stepping, never sleeping — saturation
    throughput on slow hosts/CI."""
    trace = poisson_trace(traffic,
                          [t.name for t in engine.scheduler.tenants]
                          if engine.scheduler.tenants else None)
    t0 = time.monotonic()
    pending = list(trace)
    requests: List[Request] = []
    while pending or engine.active or engine.scheduler.pending():
        now = time.monotonic() - t0
        while pending and pending[0].at <= now:
            a = pending.pop(0)
            requests.append(engine.submit(a.prompt, a.max_new_tokens,
                                          tenant=a.tenant))
        advanced = engine.step()
        if pending and not engine.active and not engine.scheduler.pending():
            if pace and advanced == 0:
                time.sleep(min(0.002, max(0.0, pending[0].at - now)))
            elif not pace:
                # jump the clock: submit the next arrival immediately
                pending[0] = dataclasses.replace(
                    pending[0], at=time.monotonic() - t0)
    wall = time.monotonic() - t0

    lat = [r.latency for r in requests if r.latency is not None]
    ttft = [r.ttft for r in requests if r.ttft is not None]
    toks = sum(len(r.output) for r in requests)
    return {
        "offered_qps": traffic.qps,
        "n_requests": len(requests),
        "completed": sum(r.done for r in requests),
        "truncated": sum(r.truncated for r in requests),
        "wall_s": wall,
        "tokens": toks,
        "tokens_per_s": toks / wall if wall > 0 else float("nan"),
        "latency_p50_s": _pct(lat, 50),
        "latency_p99_s": _pct(lat, 99),
        "ttft_p50_s": _pct(ttft, 50),
        "ttft_p99_s": _pct(ttft, 99),
        "stats": engine.stats(),
    }
