from repro.optim.adamw import Optimizer, adam, adamw, sgd_momentum
from repro.optim.schedule import (constant_schedule, cosine_schedule,
                                  linear_warmup_cosine, linear_schedule)

__all__ = [
    "Optimizer", "adam", "adamw", "sgd_momentum",
    "constant_schedule", "cosine_schedule", "linear_warmup_cosine",
    "linear_schedule",
]
