"""Learning-rate schedules (step -> lr), pure functions of a jnp step."""

from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_schedule(lr: float, total_steps: int, end_frac: float = 0.0):
    def f(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return lr * ((1.0 - t) + t * end_frac)
    return f


def cosine_schedule(lr: float, total_steps: int, min_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return lr * (min_frac + (1.0 - min_frac) * cos)
    return f


def linear_warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                         min_frac: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = lr * s / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = lr * (min_frac + (1.0 - min_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup_steps, warm, cos)
    return f
