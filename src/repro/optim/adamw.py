"""Optimizers built from scratch (the container has no optax).

Functional contract mirroring optax so the training loop and PPO share one
interface::

    opt = adamw(lr_schedule, weight_decay=0.1)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

All moments are kept in float32 regardless of parameter dtype (mixed
precision training keeps bf16 params + f32 optimizer state).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.utils.tree import clip_by_global_norm


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: object
    nu: object


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, new_state)
    # clip threshold applied inside ``update`` (None = no clipping).
    # Exposed so distributed steps whose gradient shards live on different
    # devices (the shard_map pipeline step) can apply the clip against the
    # *global* norm — ``update``'s own clip only sees the local shard.
    max_grad_norm: Optional[float] = None


def _as_schedule(lr) -> Callable[[jnp.ndarray], jnp.ndarray]:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, max_grad_norm: Optional[float] = None,
          mask: Optional[Callable] = None) -> Optimizer:
    """AdamW with decoupled weight decay and optional global-norm clipping.

    ``mask(params)`` returns a pytree of bools selecting parameters that
    receive weight decay (convention: 2D+ weights yes, biases/norm scales no).
    """
    sched = _as_schedule(lr)

    def init(params):
        f32 = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamState(step=jnp.zeros((), jnp.int32),
                         mu=jax.tree.map(f32, params),
                         nu=jax.tree.map(f32, params))

    def update(grads, state, params):
        step = state.step + 1
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, g32)
        t = step.astype(jnp.float32)
        mu_hat = jax.tree.map(lambda m: m / (1 - b1 ** t), mu)
        nu_hat = jax.tree.map(lambda v: v / (1 - b2 ** t), nu)
        lr_t = sched(step)
        if weight_decay:
            wd_mask = (mask(params) if mask is not None
                       else jax.tree.map(lambda p: p.ndim >= 2, params))
            upd = jax.tree.map(
                lambda m, v, p, use_wd: (-lr_t * (m / (jnp.sqrt(v) + eps)
                                                  + weight_decay * jnp.where(use_wd, 1.0, 0.0)
                                                  * p.astype(jnp.float32))).astype(p.dtype),
                mu_hat, nu_hat, params, wd_mask)
        else:
            upd = jax.tree.map(
                lambda m, v, p: (-lr_t * m / (jnp.sqrt(v) + eps)).astype(p.dtype),
                mu_hat, nu_hat, params)
        return upd, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update, max_grad_norm=max_grad_norm)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-5,
         max_grad_norm: Optional[float] = None) -> Optimizer:
    """Plain Adam with the PPO-standard eps=1e-5 (37-details study)."""
    return adamw(lr, b1=b1, b2=b2, eps=eps, weight_decay=0.0,
                 max_grad_norm=max_grad_norm)


def sgd_momentum(lr, momentum: float = 0.9) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return AdamState(step=jnp.zeros((), jnp.int32),
                         mu=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
                         nu=None)

    def update(grads, state, params):
        step = state.step + 1
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state.mu, grads)
        lr_t = sched(step)
        upd = jax.tree.map(lambda m, p: (-lr_t * m).astype(p.dtype), mu, params)
        return upd, AdamState(step=step, mu=mu, nu=None)

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)
