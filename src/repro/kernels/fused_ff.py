"""Fused LLaMA feed-forward front half: silu(x@Wg) * (x@Wu) — Pallas TPU
kernel (paper Table 2 "fused_ff").  Two f32 accumulators live in VMEM; the
SwiGLU epilogue fuses on the final K step."""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

from repro.sched.spec import KernelSpec, TileIO


def _kernel(x_ref, wg_ref, wu_ref, o_ref, accg_ref, accu_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    x = x_ref[...].astype(jnp.float32)
    accg_ref[...] += jnp.dot(x, wg_ref[...].astype(jnp.float32),
                             preferred_element_type=jnp.float32)
    accu_ref[...] += jnp.dot(x, wu_ref[...].astype(jnp.float32),
                             preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        g = accg_ref[...]
        o_ref[...] = (g * jax.lax.logistic(g) * accu_ref[...]).astype(o_ref.dtype)


def fused_ff(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, *,
             bm: int = 128, bn: int = 128, bk: int = 128,
             interpret: bool = False) -> jax.Array:
    m, k = x.shape
    _, n = w_gate.shape
    assert w_gate.shape == w_up.shape == (k, n)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="fused_ff",
    )(x, w_gate, w_up)


def make_spec(cfg: Dict) -> KernelSpec:
    bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]

    def tile_fn(x, wg, wu):
        return jnp.dot(x, wg), jnp.dot(x, wu)

    def epilogue_fn(g, u):
        return (jax.nn.silu(g) * u,)

    return KernelSpec(
        name="fused_ff",
        tile_fn=tile_fn,
        epilogue_fn=epilogue_fn,
        inputs=[TileIO("x", (bm, bk)), TileIO("wg", (bk, bn)),
                TileIO("wu", (bk, bn))],
        outputs=[TileIO("h", (bm, bn))],
        steps=3,
        accumulate=True,
        config=dict(cfg),
        flops_per_step=4 * bm * bn * bk,
    )


CONFIGS = [
    {"bm": 128, "bn": 128, "bk": 128},
    {"bm": 128, "bn": 128, "bk": 64},
    {"bm": 64, "bn": 256, "bk": 64},
    {"bm": 256, "bn": 128, "bk": 64},
]
