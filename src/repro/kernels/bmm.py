"""Batch matrix multiplication — Pallas TPU kernel (paper Table 2 "bmm";
the kernel whose §5.7.2 predicated-slot move the paper traces)."""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

from repro.sched.spec import KernelSpec, TileIO


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[0].astype(jnp.float32),
                            b_ref[0].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def bmm(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
        bk: int = 128, interpret: bool = False) -> jax.Array:
    B, m, k = a.shape
    _, k2, n = b.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (B, m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda b_, i, j, kk: (b_, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda b_, i, j, kk: (b_, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda b_, i, j, kk: (b_, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="bmm",
    )(a, b)


def make_spec(cfg: Dict) -> KernelSpec:
    bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
    return KernelSpec(
        name="bmm",
        tile_fn=lambda a, b: (jnp.dot(a, b),),
        inputs=[TileIO("a", (bm, bk)), TileIO("b", (bk, bn))],
        outputs=[TileIO("y", (bm, bn))],
        steps=3,
        accumulate=True,
        config=dict(cfg),
        flops_per_step=2 * bm * bn * bk,
    )


CONFIGS = [
    {"bm": 128, "bn": 128, "bk": 128},
    {"bm": 128, "bn": 128, "bk": 64},
    {"bm": 64, "bn": 64, "bk": 128},
    {"bm": 256, "bn": 64, "bk": 64},
]
