"""Pallas TPU kernels for the paper's evaluated kernel set (Table 2) plus
the SSD chunk kernel for the assigned SSM architectures.

Each module ships: the ``pl.pallas_call`` kernel with explicit BlockSpec
VMEM tiling, a ``make_spec``/``CONFIGS`` pair for the schedule optimizer
(autotune space, §3.1), and a pure-jnp oracle in :mod:`repro.kernels.ref`.

``KERNELS`` is the registry the optimization session resolves kernel names
through; :func:`register_kernel` adds new entries (the built-in set below,
tests registering fixtures, downstream code registering its own kernels):

    from repro.kernels import register_kernel
    from repro.sched import KernelDef

    register_kernel(KernelDef("my_kernel", make_spec, CONFIGS))
    OptimizationSession().optimize(OptimizeRequest(kernel="my_kernel"))
"""

from typing import Dict

from repro.kernels import ref
from repro.sched.session import KernelDef

KERNELS: Dict[str, KernelDef] = {}


def register_kernel(kdef: KernelDef) -> KernelDef:
    """Register ``kdef`` under its name (last registration wins, so tests
    can shadow and restore entries).  Returns the definition, so it can be
    used as a decorator over ``KernelDef``-returning builders' results."""
    if not isinstance(kdef, KernelDef):
        raise TypeError(f"register_kernel expects a KernelDef, got {kdef!r}")
    KERNELS[kdef.name] = kdef
    return kdef


def unregister_kernel(name: str) -> None:
    """Remove a registry entry (test cleanup)."""
    KERNELS.pop(name, None)


def get_kernel(name: str) -> KernelDef:
    try:
        return KERNELS[name]
    except KeyError:
        raise KeyError(f"unknown kernel {name!r}; registered kernels: "
                       f"{sorted(KERNELS)}") from None


def _register_builtins():
    from repro.kernels import (bmm, flash_attention, fused_ff,
                               matmul_leakyrelu, rmsnorm, softmax, ssd)
    for kdef in (
        KernelDef("matmul_leakyrelu", matmul_leakyrelu.make_spec,
                  matmul_leakyrelu.CONFIGS, matmul_leakyrelu.matmul_leakyrelu,
                  ref.matmul_leakyrelu),
        KernelDef("fused_ff", fused_ff.make_spec, fused_ff.CONFIGS,
                  fused_ff.fused_ff, ref.fused_ff),
        KernelDef("bmm", bmm.make_spec, bmm.CONFIGS, bmm.bmm, ref.bmm),
        KernelDef("flash_attention", flash_attention.make_spec,
                  flash_attention.CONFIGS, flash_attention.flash_attention,
                  ref.flash_attention),
        KernelDef("softmax", softmax.make_spec, softmax.CONFIGS,
                  softmax.softmax, ref.softmax),
        KernelDef("rmsnorm", rmsnorm.make_spec, rmsnorm.CONFIGS,
                  rmsnorm.rmsnorm, ref.rmsnorm),
        KernelDef("ssd", ssd.make_spec, ssd.CONFIGS, ssd.ssd, None),
    ):
        register_kernel(kdef)


_register_builtins()

__all__ = ["KERNELS", "KernelDef", "get_kernel", "register_kernel",
           "unregister_kernel", "ref"]
