"""Pallas TPU kernels for the paper's evaluated kernel set (Table 2) plus
the SSD chunk kernel for the assigned SSM architectures.

Each module ships: the ``pl.pallas_call`` kernel with explicit BlockSpec
VMEM tiling, a ``make_spec``/``CONFIGS`` pair for the schedule optimizer
(autotune space, §3.1), and a pure-jnp oracle in :mod:`repro.kernels.ref`.
``KERNELS`` is the registry the CuAsmRL integration consumes.
"""

from repro.kernels import ref
from repro.sched.api import KernelDef


def _build_registry():
    from repro.kernels import (bmm, flash_attention, fused_ff,
                               matmul_leakyrelu, rmsnorm, softmax, ssd)
    return {
        "matmul_leakyrelu": KernelDef(
            "matmul_leakyrelu", matmul_leakyrelu.make_spec,
            matmul_leakyrelu.CONFIGS, matmul_leakyrelu.matmul_leakyrelu,
            ref.matmul_leakyrelu),
        "fused_ff": KernelDef(
            "fused_ff", fused_ff.make_spec, fused_ff.CONFIGS,
            fused_ff.fused_ff, ref.fused_ff),
        "bmm": KernelDef(
            "bmm", bmm.make_spec, bmm.CONFIGS, bmm.bmm, ref.bmm),
        "flash_attention": KernelDef(
            "flash_attention", flash_attention.make_spec,
            flash_attention.CONFIGS, flash_attention.flash_attention,
            ref.flash_attention),
        "softmax": KernelDef(
            "softmax", softmax.make_spec, softmax.CONFIGS,
            softmax.softmax, ref.softmax),
        "rmsnorm": KernelDef(
            "rmsnorm", rmsnorm.make_spec, rmsnorm.CONFIGS,
            rmsnorm.rmsnorm, ref.rmsnorm),
        "ssd": KernelDef(
            "ssd", ssd.make_spec, ssd.CONFIGS, ssd.ssd, None),
    }


KERNELS = _build_registry()

__all__ = ["KERNELS", "ref"]
