"""Pure-jnp oracles for every Pallas kernel (the allclose reference).

These are also the XLA fallback path used by the model stack on CPU and in
the dry-run (Pallas lowers for the TPU target; on this host the kernels are
validated in interpret mode against these functions).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_leakyrelu(a: jax.Array, b: jax.Array,
                     negative_slope: float = 0.01) -> jax.Array:
    """Fused GEMM + LeakyReLU epilogue (paper Table 2: mmLeakyReLu)."""
    y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    return jnp.where(y >= 0, y, negative_slope * y).astype(a.dtype)


def bmm(a: jax.Array, b: jax.Array) -> jax.Array:
    """Batch matrix multiplication (paper Table 2: bmm)."""
    return jnp.einsum("bmk,bkn->bmn", a.astype(jnp.float32),
                      b.astype(jnp.float32)).astype(a.dtype)


def fused_ff(x: jax.Array, w_gate: jax.Array, w_up: jax.Array) -> jax.Array:
    """Fused LLaMA-style feed-forward front half: silu(x@Wg) * (x@Wu)
    (paper Table 2: fused_ff)."""
    xf = x.astype(jnp.float32)
    g = jnp.dot(xf, w_gate.astype(jnp.float32))
    u = jnp.dot(xf, w_up.astype(jnp.float32))
    return (jax.nn.silu(g) * u).astype(x.dtype)


def softmax(x: jax.Array, axis: int = -1) -> jax.Array:
    """Numerically-stable row softmax (paper Table 2: softmax)."""
    xf = x.astype(jnp.float32)
    m = jnp.max(xf, axis=axis, keepdims=True)
    e = jnp.exp(xf - m)
    return (e / jnp.sum(e, axis=axis, keepdims=True)).astype(x.dtype)


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Root-mean-square layer normalization (paper Table 2: rmsnorm)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)
            ).astype(x.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, scale: float = None) -> jax.Array:
    """Exact attention oracle, (B, H, S, D) layout (paper Table 2:
    flash-attention)."""
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    qf = q.astype(jnp.float32) * scale
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, k.astype(jnp.float32))
    if causal:
        sq, sk = logits.shape[-2], logits.shape[-1]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_chunk(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
              chunk: int = 64) -> jax.Array:
    """Mamba-2 SSD (state-space duality) oracle: sequential scan semantics.

    x: (B, S, H, P) inputs; a: (B, S, H) log-decay (<=0); b,c: (B, S, G, N)
    input/output projections (G groups broadcast over H heads).
    Returns y: (B, S, H, P).
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    bf = jnp.repeat(b, rep, axis=2).astype(jnp.float32)   # (B,S,H,N)
    cf = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)

    def step(state, inp):
        xt, at, bt, ct = inp       # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(at)[..., None, None]               # (B,H,1,1)
        state = state * decay + xt[..., None] * bt[..., None, :]  # (B,H,P,N)
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    state0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(af, 1, 0),
          jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    _, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
