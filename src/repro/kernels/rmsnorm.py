"""Root-mean-square layer normalization — Pallas TPU kernel (paper Table 2
"rmsnorm", memory-bound class).  Row-block tiling; the gamma scale tile is
loop-invariant (loaded once), which is what feeds the analysis-pass denylist
in the TSASS lowering."""

from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.sched.scenario import Scenario, scenario_steps
from repro.sched.spec import KernelSpec, TileIO


def _kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, gamma: jax.Array, *, br: int = 8,
            eps: float = 1e-6, interpret: bool = False) -> jax.Array:
    rows, cols = x.shape
    assert gamma.shape == (cols,) and rows % br == 0
    g2 = gamma.reshape(1, cols)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, cols), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=interpret,
        name="rmsnorm",
    )(x, g2)


def make_spec(cfg: Dict, *, scenario: Optional[Scenario] = None
              ) -> KernelSpec:
    br, cols = cfg["br"], cfg["cols"]
    dtype = scenario.dtype if scenario is not None else "bf16"

    def tile_fn(x, g):
        var = jnp.mean(x * x, axis=-1, keepdims=True)
        return (x * jax.lax.rsqrt(var + 1e-6) * g,)

    return KernelSpec(
        name="rmsnorm",
        tile_fn=tile_fn,
        inputs=[TileIO("x", (br, cols), dtype=dtype),
                TileIO("g", (1, cols), dtype=dtype, invariant=True)],
        outputs=[TileIO("y", (br, cols), dtype=dtype)],
        steps=scenario_steps(scenario, br, default=4),
        accumulate=False,
        config=dict(cfg),
        flops_per_step=4 * br * cols,
    )


# paper configuration: rmsnorm on (1, 32, 4096, 64) -> rows=32*4096, cols=64;
# practical LLM widths included in the sweep
CONFIGS = [
    {"br": 8, "cols": 2048},
    {"br": 16, "cols": 2048},
    {"br": 8, "cols": 4096},
    {"br": 32, "cols": 1024},
]
