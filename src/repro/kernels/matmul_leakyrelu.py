"""Fused GEMM + LeakyReLU epilogue — Pallas TPU kernel (paper Table 2,
Fig. 6 "mmLeakyReLu"; the kernel whose §5.7.1 reuse-cache move the paper
traces).

MXU-aligned BlockSpec tiling with an f32 VMEM accumulator; the K grid
dimension is 'arbitrary' (sequential) so the accumulator persists across
K steps and the epilogue fires on the last one.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

from repro.sched.scenario import Scenario, scenario_steps
from repro.sched.spec import KernelSpec, TileIO


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, negative_slope: float, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.float32),
                            b_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        y = acc_ref[...]
        o_ref[...] = jnp.where(y >= 0, y, negative_slope * y).astype(o_ref.dtype)


def matmul_leakyrelu(a: jax.Array, b: jax.Array, *, bm: int = 128,
                     bn: int = 128, bk: int = 128,
                     negative_slope: float = 0.01,
                     interpret: bool = False) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (a.shape, b.shape, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, negative_slope=negative_slope, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="matmul_leakyrelu",
    )(a, b)


# ---------------------------------------------------------------------------
# schedule-optimizer integration
# ---------------------------------------------------------------------------

def make_spec(cfg: Dict, *, scenario: Optional[Scenario] = None
              ) -> KernelSpec:
    bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
    dtype = scenario.dtype if scenario is not None else "bf16"
    return KernelSpec(
        name="matmul_leakyrelu",
        tile_fn=lambda a, b: (jnp.dot(a, b),),
        epilogue_fn=lambda acc: (jnp.where(acc >= 0, acc, 0.01 * acc),),
        inputs=[TileIO("a", (bm, bk), dtype=dtype),
                TileIO("b", (bk, bn), dtype=dtype)],
        outputs=[TileIO("y", (bm, bn), dtype=dtype)],
        steps=scenario_steps(scenario, bm, default=3),
        accumulate=True,
        config=dict(cfg),
        flops_per_step=2 * bm * bn * bk,
    )


CONFIGS = [
    {"bm": 128, "bn": 128, "bk": 128},
    {"bm": 128, "bn": 128, "bk": 64},
    {"bm": 64, "bn": 128, "bk": 128},
    {"bm": 128, "bn": 256, "bk": 64},
    {"bm": 256, "bn": 128, "bk": 64},
]
