"""Mamba-2 SSD (state-space duality) chunk kernel — Pallas TPU.

Beyond the paper's kernel set: the hot kernel of the assigned mamba2/zamba2
architectures.  Chunked SSD: within-chunk work is a masked attention-like
matmul (MXU-friendly — the whole point of state-space *duality*), the
inter-chunk recurrence carries an (P, N) state in VMEM scratch across the
sequential chunk grid dimension.

Layout: heads fold into the batch grid axis.  x: (BH, S, P); a: (BH, S)
log-decay (<= 0); b, c: (BH, S, N).
"""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

from repro.sched.spec import KernelSpec, TileIO


def _kernel(x_ref, a_ref, b_ref, c_ref, o_ref, state_ref, *, chunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)            # (chunk, P)
    a = a_ref[0].astype(jnp.float32)            # (chunk,)
    b = b_ref[0].astype(jnp.float32)            # (chunk, N)
    c = c_ref[0].astype(jnp.float32)            # (chunk, N)

    seg = jnp.cumsum(a)                          # inclusive decay prefix
    total = seg[-1]

    # within-chunk: y_intra[t] = sum_{s<=t} e^{seg t - seg s} (c_t . b_s) x_s
    scores = jnp.dot(c, b.T, preferred_element_type=jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(seg[:, None] - seg[None, :])
    l_mat = jnp.where(rows >= cols, decay, 0.0)
    y_intra = jnp.dot(scores * l_mat, x, preferred_element_type=jnp.float32)

    # inter-chunk: y_inter[t] = e^{seg t} c_t . state_in
    state = state_ref[...]                       # (P, N)
    y_inter = jnp.exp(seg)[:, None] * jnp.dot(
        c, state.T, preferred_element_type=jnp.float32)

    o_ref[0] = (y_intra + y_inter).astype(o_ref.dtype)

    # state update: state' = e^{total} state + sum_s e^{total-seg s} x_s b_s^T
    w = jnp.exp(total - seg)[:, None]
    state_ref[...] = (jnp.exp(total) * state
                      + jnp.dot((x * w).T, b,
                                preferred_element_type=jnp.float32))


def ssd(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array, *,
        chunk: int = 64, interpret: bool = False) -> jax.Array:
    BH, S, P = x.shape
    N = b.shape[-1]
    assert S % chunk == 0 and a.shape == (BH, S)
    grid = (BH, S // chunk)
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, chunk), lambda h, j: (h, j)),
            pl.BlockSpec((1, chunk, N), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, chunk, N), lambda h, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda h, j: (h, j, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="ssd",
    )(x, a, b, c)


def make_spec(cfg: Dict) -> KernelSpec:
    chunk, p, n = cfg["chunk"], cfg["p"], cfg["n"]

    def tile_fn(x, a, b, c):
        # per-chunk SSD: intra-chunk masked matmul + state contribution
        seg = jnp.cumsum(a[:, 0])
        scores = jnp.dot(c, b.T)
        decay = jnp.exp(seg[:, None] - seg[None, :])
        y_intra = jnp.dot(scores * decay, x)
        state = jnp.dot((x * jnp.exp(seg[-1] - seg)[:, None]).T, b)
        y_inter = jnp.exp(seg)[:, None] * jnp.dot(c, state.T)
        return (y_intra + y_inter,)

    return KernelSpec(
        name="ssd",
        tile_fn=tile_fn,
        inputs=[TileIO("x", (chunk, p)), TileIO("a", (chunk, 1)),
                TileIO("b", (chunk, n)), TileIO("c", (chunk, n))],
        outputs=[TileIO("y", (chunk, p))],
        steps=3,
        accumulate=False,
        config=dict(cfg),
        flops_per_step=2 * chunk * chunk * (n + p) + 4 * chunk * n * p,
    )


CONFIGS = [
    {"chunk": 64, "p": 64, "n": 128},
    {"chunk": 128, "p": 64, "n": 128},
    {"chunk": 64, "p": 128, "n": 64},
]
