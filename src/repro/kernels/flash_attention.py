"""Flash attention — Pallas TPU kernel (paper Table 2 "flash-attention").

Online-softmax with running (max, sum, acc) carried in VMEM scratch across
the sequential KV grid dimension; causal blocks above the diagonal are
skipped.  The q tile is loop-invariant in the TSASS lowering (loaded once
per q block), matching the real kernel's structure.
"""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

from repro.sched.spec import KernelSpec, TileIO

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, nk: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        pl.when(j * bk <= (i + 1) * bq - 1)(_compute)
    else:
        _compute()

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    bq: int = 128, bk: int = 128, causal: bool = True,
                    scale: float = None,
                    interpret: bool = False) -> jax.Array:
    """(B, H, S, D) attention.  B and H fold into one parallel grid axis."""
    B, H, S, D = q.shape
    Sk = k.shape[2]
    assert S % bq == 0 and Sk % bk == 0
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, Sk, D)
    vf = v.reshape(B * H, Sk, D)
    grid = (B * H, S // bq, Sk // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, bq=bq, bk=bk,
                          nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="flash_attention",
    )(qf, kf, vf)
    return out.reshape(B, H, S, D)


def make_spec(cfg: Dict) -> KernelSpec:
    bq, bk, d = cfg["bq"], cfg["bk"], cfg["d"]

    def tile_fn(q, k, v):
        s = jnp.dot(q, k.T)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        ell = jnp.sum(p, axis=-1, keepdims=True)
        return (jnp.dot(p, v), ell)

    def epilogue_fn(acc, ell):
        return (acc / ell,)

    return KernelSpec(
        name="flash_attention",
        tile_fn=tile_fn,
        epilogue_fn=epilogue_fn,
        inputs=[TileIO("q", (bq, d), invariant=True),
                TileIO("k", (bk, d)), TileIO("v", (bk, d))],
        outputs=[TileIO("o", (bq, d))],
        steps=3,
        accumulate=True,
        config=dict(cfg),
        flops_per_step=4 * bq * bk * d,
    )


# paper configuration: B=1, n_head=4, seq=4096, d_head=32 (+ larger heads)
CONFIGS = [
    {"bq": 128, "bk": 128, "d": 64},
    {"bq": 128, "bk": 256, "d": 64},
    {"bq": 256, "bk": 128, "d": 64},
    {"bq": 128, "bk": 128, "d": 128},
]
