"""Dispatching wrappers: one call site per op, selectable implementation.

``impl="ref"`` is the pure-jnp oracle (XLA path — used by the model stack,
the dry-run and CPU training); ``impl="pallas"`` is the TPU kernel
(``interpret=True`` executes the kernel body on CPU for validation).
Model code calls these, so flipping a config flag swaps the backend per op.
"""

from __future__ import annotations

from typing import Literal


# canonical re-export: the kernels' CompilerParams drift shim (implemented
# in repro.compat, which imports no kernel modules — cycle-free)
from repro.compat import tpu_compiler_params  # noqa: F401
from repro.kernels import (bmm as _bmm_mod, flash_attention as _fa_mod,
                           fused_ff as _ff_mod,
                           matmul_leakyrelu as _mm_mod, ref,
                           rmsnorm as _rms_mod, softmax as _sm_mod,
                           ssd as _ssd_mod)

Impl = Literal["ref", "pallas", "pallas_interpret"]


def _interp(impl: Impl) -> bool:
    return impl == "pallas_interpret"


def matmul_leakyrelu(a, b, *, impl: Impl = "ref", **kw):
    if impl == "ref":
        return ref.matmul_leakyrelu(a, b, kw.get("negative_slope", 0.01))
    return _mm_mod.matmul_leakyrelu(a, b, interpret=_interp(impl), **kw)


def bmm(a, b, *, impl: Impl = "ref", **kw):
    if impl == "ref":
        return ref.bmm(a, b)
    return _bmm_mod.bmm(a, b, interpret=_interp(impl), **kw)


def fused_ff(x, w_gate, w_up, *, impl: Impl = "ref", **kw):
    if impl == "ref":
        return ref.fused_ff(x, w_gate, w_up)
    return _ff_mod.fused_ff(x, w_gate, w_up, interpret=_interp(impl), **kw)


def softmax(x, *, impl: Impl = "ref", **kw):
    if impl == "ref":
        return ref.softmax(x)
    return _sm_mod.softmax(x, interpret=_interp(impl), **kw)


def rmsnorm(x, gamma, *, impl: Impl = "ref", **kw):
    if impl == "ref":
        return ref.rmsnorm(x, gamma, kw.get("eps", 1e-6))
    return _rms_mod.rmsnorm(x, gamma, interpret=_interp(impl), **kw)


def flash_attention(q, k, v, *, impl: Impl = "ref", causal=True, **kw):
    if impl == "ref":
        return ref.flash_attention(q, k, v, causal=causal,
                                   scale=kw.get("scale"))
    return _fa_mod.flash_attention(q, k, v, causal=causal,
                                   interpret=_interp(impl), **kw)


def ssd(x, a, b, c, *, impl: Impl = "ref", **kw):
    """Flat-head layout: x (BH, S, P); a (BH, S); b, c (BH, S, N)."""
    if impl == "ref":
        y = ref.ssd_chunk(x[:, :, None, :], a[:, :, None],
                          b[:, :, None, :], c[:, :, None, :])
        return y[:, :, 0, :]
    return _ssd_mod.ssd(x, a, b, c, interpret=_interp(impl), **kw)
