"""Row softmax — Pallas TPU kernel (paper Table 2 "softmax", memory-bound
class; paper configuration n_rows=512, n_cols=4096)."""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.sched.scenario import Scenario, scenario_steps
from repro.sched.spec import KernelSpec, TileIO


def _kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


def softmax(x: jax.Array, *, br: int = 8,
            interpret: bool = False) -> jax.Array:
    rows, cols = x.shape
    assert rows % br == 0
    return pl.pallas_call(
        _kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=interpret,
        name="softmax",
    )(x)


def make_spec(cfg: Dict, *, scenario: Optional[Scenario] = None
              ) -> KernelSpec:
    br, cols = cfg["br"], cfg["cols"]
    dtype = scenario.dtype if scenario is not None else "bf16"

    def tile_fn(x):
        m = jnp.max(x, axis=-1, keepdims=True)
        e = jnp.exp(x - m)
        return (e / jnp.sum(e, axis=-1, keepdims=True),)

    return KernelSpec(
        name="softmax",
        tile_fn=tile_fn,
        inputs=[TileIO("x", (br, cols), dtype=dtype)],
        outputs=[TileIO("y", (br, cols), dtype=dtype)],
        steps=scenario_steps(scenario, br, default=4),
        accumulate=False,
        config=dict(cfg),
        flops_per_step=5 * br * cols,
    )


CONFIGS = [
    {"br": 8, "cols": 4096},
    {"br": 16, "cols": 4096},
    {"br": 32, "cols": 2048},
    {"br": 4, "cols": 8192},
]
