"""Logical-axis sharding rules: the single place that maps *logical* axis
names (on :class:`repro.nn.core.ParamSpec` leaves) and runtime tensors onto
*mesh* axes.

Mesh axes (see ``repro.launch.mesh``):

  ``pod``    — outermost data-parallel replica groups (multi-pod runs);
  ``data``   — within-pod data parallelism (batch, FSDP weight shards);
  ``model``  — tensor / expert parallelism;
  ``pipe``   — pipeline stages (``repro.dist.pipeline``).

Every rule degrades by *divisibility fallback*: a dimension that is not
divisible by its target mesh axis (or whose target axis is absent) is
replicated instead — the layer never produces an unlowerable spec, so the
same model code runs on a laptop mesh and the 512-chip production mesh.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# logical axis -> mesh axes to try, in preference order.  ``embed`` (the
# contraction dim of every matmul) shards over ``data`` — classic FSDP: the
# SPMD partitioner turns it into per-step all-gathers instead of resident
# replicas.  ``mlp``/``heads``/``vocab``/``experts`` shard over ``model``
# (tensor/expert parallelism).  ``layers`` is the scan dimension and stays
# replicated.
LOGICAL_RULES: Dict[str, Tuple[str, ...]] = {
    "embed": ("data",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "layers": (),
}


# ---------------------------------------------------------------------------
# mesh introspection (works on Mesh and AbstractMesh alike)
# ---------------------------------------------------------------------------

def axis_sizes(mesh) -> Dict[str, int]:
    """{axis name: size} for a concrete or abstract mesh."""
    return dict(mesh.shape)


def dp_axes(mesh):
    """The data-parallel mesh axes present on ``mesh``: ``("pod", "data")``,
    ``"data"``, or None.  Usable directly inside a PartitionSpec."""
    present = tuple(a for a in ("pod", "data") if a in axis_sizes(mesh))
    if not present:
        return None
    return present if len(present) > 1 else present[0]


def dp_size(mesh) -> int:
    sizes = axis_sizes(mesh)
    return sizes.get("pod", 1) * sizes.get("data", 1)


def model_size(mesh) -> int:
    return axis_sizes(mesh).get("model", 1)


def pipe_size(mesh) -> int:
    return axis_sizes(mesh).get("pipe", 1)


# ---------------------------------------------------------------------------
# parameter shardings
# ---------------------------------------------------------------------------

def spec_for_axes(axes: Sequence[Optional[str]], mesh,
                  shape: Optional[Sequence[int]] = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec on ``mesh``.

    A dimension is sharded over the first mesh axis in its rule that (a)
    exists on the mesh, (b) is not already used by an earlier dimension,
    and (c) divides the dimension size (when ``shape`` is given);
    otherwise it is replicated.
    """
    sizes = axis_sizes(mesh)
    used = set()
    out = []
    for i, name in enumerate(axes):
        choice = None
        for mesh_ax in LOGICAL_RULES.get(name, ()):
            n = sizes.get(mesh_ax)
            if n is None or mesh_ax in used:
                continue
            if shape is not None and shape[i] % n != 0:
                continue
            choice = mesh_ax
            used.add(mesh_ax)
            break
        out.append(choice)
    return P(*out)


def _is_param_spec(x) -> bool:
    # duck-typed so this module never imports repro.nn (no import cycles)
    return hasattr(x, "axes") and hasattr(x, "shape")


def param_shardings(spec_tree, mesh):
    """ParamSpec tree -> NamedSharding tree (same structure as the params
    ``init_params`` builds from the same spec tree)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_for_axes(s.axes, mesh, s.shape)),
        spec_tree, is_leaf=_is_param_spec)


def logical_specs(spec_tree, mesh):
    """Like :func:`param_shardings` but returning bare PartitionSpecs —
    usable with AbstractMesh (no devices) and as shard_map in/out specs."""
    return jax.tree.map(
        lambda s: spec_for_axes(s.axes, mesh, s.shape),
        spec_tree, is_leaf=_is_param_spec)


# ---------------------------------------------------------------------------
# shard_map pipeline-step specs (train.step.make_sharded_train_step)
# ---------------------------------------------------------------------------

# top-level parameter-tree keys whose leaves are stacked per-layer weights
# (leading dim = n_layers) that the pipeline step splits one block of
# contiguous layers per ``pipe`` rank.  Everything else is "glue" (embed,
# final norm, lm head) and stays replicated across the pipe axis.
STAGE_KEYS: Tuple[str, ...] = ("layers",)

# logical axes the explicit-TP pipeline step shards over ``model``: the
# attention head / MLP column dims whose partial projections the stage body
# reassembles with an in-stage psum (``repro.nn`` ``tp_axis`` paths).  No
# divisibility fallback here — ``make_sharded_train_step`` validates the
# dims eagerly, because a silently replicated leaf would make the stage's
# unconditional psum double-count.
TP_STAGE_AXES: Tuple[str, ...] = ("mlp", "heads", "kv_heads")


def _stage_leaf_spec(leaf, tp: bool) -> P:
    if not tp:
        return P("pipe")
    if not _is_param_spec(leaf):
        raise TypeError(
            "sharded_param_specs needs a ParamSpec tree (logical axes) to "
            "compose pipe with tensor parallelism; got a bare array leaf")
    parts = ["pipe" if i == 0 and ax == "layers"
             else ("model" if ax in TP_STAGE_AXES else None)
             for i, ax in enumerate(leaf.axes)]
    return P(*parts)


def sharded_param_specs(params_tree, stage_keys: Sequence[str] = STAGE_KEYS,
                        mesh=None):
    """PartitionSpec tree for the shard_map train step's parameters: stacked
    per-layer leaves shard their leading (layer) dim over ``pipe``; glue
    parameters are replicated across ``pipe`` (and ``model``).  When
    ``mesh`` carries a ``model`` axis of size > 1, stage leaves additionally
    shard their :data:`TP_STAGE_AXES` dims over ``model`` — the weight
    layout of the TP-composable stage bodies.  Accepts a params tree or a
    ParamSpec tree (the latter is required for TP, which needs the logical
    axes)."""
    tp = mesh is not None and model_size(mesh) > 1

    def sub(key, tree):
        if key in stage_keys:
            return jax.tree.map(lambda s: _stage_leaf_spec(s, tp), tree,
                                is_leaf=_is_param_spec)
        return jax.tree.map(lambda _: P(), tree, is_leaf=_is_param_spec)
    return {k: sub(k, v) for k, v in params_tree.items()}


def sharded_ef_specs(params_tree, stage_keys: Sequence[str] = STAGE_KEYS,
                     mesh=None):
    """PartitionSpec tree for the compressed-psum error-feedback residuals:
    each leaf is its parameter's spec (:func:`sharded_param_specs`) with a
    leading ``pod``-block dim prepended — the residual is local to a pod
    rank, and mirrors the parameter/gradient sharding underneath."""
    p_specs = sharded_param_specs(params_tree, stage_keys, mesh)
    return jax.tree.map(lambda sp: P("pod", *sp), p_specs)


# ---------------------------------------------------------------------------
# activation / batch shardings
# ---------------------------------------------------------------------------

def batch_spec(mesh, batch: int, ndim: int = 2) -> P:
    """Leading-dim data parallelism with divisibility fallback."""
    dp = dp_axes(mesh)
    if dp is None or batch % dp_size(mesh) != 0:
        dp = None
    return P(dp, *([None] * (ndim - 1)))


def cache_sharding(mesh, batch: int, seq: int, n_kv_heads: int) -> P:
    """PartitionSpec for a (batch, seq, kv_heads, head_dim) KV cache.

    Heuristics, in order:
      * batch not data-divisible (the batch=1 long-context cell): shard the
        *sequence* over every divisible mesh axis — the cache dominates
        memory at 500k context, so it must spread over the whole slice;
      * kv heads divisible by ``model``: head sharding (dense GQA/MHA) —
        decode attention then needs no cross-device traffic at all;
      * MQA / few-kv-head models: sequence sharding over ``model`` (the
        flash-decode split-S pattern; partial softmax combines are cheap);
      * otherwise replicate the non-batch dims.
    """
    sizes = axis_sizes(mesh)
    model = sizes.get("model", 1)
    b_ax = dp_axes(mesh)
    if b_ax is not None and batch % dp_size(mesh) == 0:
        if n_kv_heads % model == 0 and model > 1:
            return P(b_ax, None, "model", None)
        if model > 1 and seq % model == 0:
            return P(b_ax, "model", None, None)
        return P(b_ax, None, None, None)

    # batch not shardable: spread the sequence as widely as divisibility
    # allows (prefer the full data×model slice, fall back to model only)
    for axes in (tuple(a for a in ("pod", "data", "model") if a in sizes),
                 tuple(a for a in ("data", "model") if a in sizes),
                 ("model",) if "model" in sizes else ()):
        if not axes:
            continue
        n = math.prod(sizes[a] for a in axes)
        if n > 1 and seq % n == 0:
            return P(None, axes, None, None)
    return P(None, None, None, None)


def decode_cache_shardings(cfg, caches, mesh):
    """NamedSharding tree for a decode-cache pytree (any model family).

    ``caches`` may hold arrays or ShapeDtypeStructs; leaves are classified
    by rank/shape the same way ``serve.decode.init_caches`` builds them.
    """
    def leaf_spec(x) -> P:
        shape = x.shape
        dp = dp_axes(mesh)
        b_ax = dp if shape[0] % dp_size(mesh) == 0 else None
        if len(shape) == 4 and shape[2] == cfg.n_kv_heads \
                and shape[3] == cfg.head_dim:
            return cache_sharding(mesh, shape[0], shape[1], cfg.n_kv_heads)
        if len(shape) == 4:  # ssm state (B, H, P, N)
            h_ax = "model" if shape[1] % model_size(mesh) == 0 else None
            return P(b_ax, h_ax, None, None)
        if len(shape) == 3:  # mla latent (B, S, R) / ssm conv (B, W, C)
            # shard the sequence, NOT the latent dim: the attention einsums
            # contract over R, and a contraction-dim sharding makes the SPMD
            # partitioner all-gather the whole (f32-upcast) cache every
            # layer — measured at 16.8 GB/device/step on deepseek decode_32k
            # before this rule (EXPERIMENTS.md §Perf cell B).
            if shape[1] % model_size(mesh) == 0 \
                    and shape[1] >= model_size(mesh):
                return P(b_ax, "model", None)
            if cfg.mla and shape[2] in (cfg.kv_lora_rank, cfg.qk_rope_dim):
                return P(b_ax, None, None)   # latent IS the contraction dim
            last_ax = "model" if shape[2] % model_size(mesh) == 0 \
                and shape[2] >= model_size(mesh) else None
            return P(b_ax, None, last_ax)
        return P(*([None] * len(shape)))

    return jax.tree.map(lambda x: NamedSharding(mesh, leaf_spec(x)), caches)


def kv_pool_shardings(cfg, caches, mesh, kinds=None):
    """Placement for the serve engine's KV cache (dense or paged).

    **Dense** (``kinds=None``): the pool's backing arrays are the decode
    caches with the slot dimension in the batch position
    (``max_batch + 1`` rows: the slots plus the scratch row the padded
    step writes), so they place under exactly the decode-cache rules —
    slot rows across data axes when divisible, KV heads across the model
    axis for GQA, sequence for MQA/long-context, latent/conv leaves by
    their own rules.

    **Paged** (``kinds`` = ``serve.decode.paged_cache_kinds(cfg)``, one
    entry per cache in the list): block-major page leaves
    ``(num_blocks, block_size, ...)`` shard KV heads over the model axis
    and NEVER shard the block or in-block position dims — every lane
    gathers arbitrary physical blocks through its table, so a sharded
    block dim would turn each gather into an all-to-all.  MLA latent
    pages replicate their trailing dim (it is the attention contraction
    — same rule as the dense path).  ``"slot"`` entries (recurrent state
    rows) keep the decode-cache rules."""
    if kinds is None:
        return decode_cache_shardings(cfg, caches, mesh)

    def paged_leaf_spec(x) -> P:
        shape = x.shape
        if len(shape) == 4 and shape[2] == cfg.n_kv_heads \
                and shape[3] == cfg.head_dim:
            h_ax = "model" if cfg.n_kv_heads % model_size(mesh) == 0 else None
            return P(None, None, h_ax, None)
        return P(*([None] * len(shape)))

    return [decode_cache_shardings(cfg, c, mesh) if kind == "slot"
            else jax.tree.map(
                lambda x: NamedSharding(mesh, paged_leaf_spec(x)), c)
            for c, kind in zip(caches, kinds)]
