"""GPipe microbatch pipelining over a ``pipe`` mesh axis.

``gpipe`` runs ``stage_fn`` S times (one stage per pipeline rank) over M
microbatches with the classic fill/steady/drain schedule: at step ``t``
stage ``s`` processes microbatch ``t - s``, and activations hop to the next
stage through a ring ``ppermute``.  Total ``M + S - 1`` steps, so bubble
fraction ``(S - 1) / (M + S - 1)`` — the caller picks M accordingly.

Two entry points:

* :func:`gpipe` — standalone: wraps the schedule in its own ``shard_map``
  (stage weights enter stacked ``(S, ...)`` and sharded ``P("pipe")``);
* :func:`gpipe_local` — the per-device schedule alone, for callers that
  are *already inside* a ``shard_map`` over a mesh containing ``axis``
  (the sharded train step composes it with data-parallel gradient
  collectives this way).

Numerics match running the stages sequentially — asserted against that
oracle by tests/test_dist.py.  The schedule is differentiable: the ring
``ppermute`` transposes to the inverted ring, so ``jax.grad`` through
``gpipe_local`` routes activation cotangents backwards stage by stage
(exactly the 1F1B-style backward traffic).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def gpipe_local(stage_fn, stage_weights, microbatches, *, n_stages: int,
                axis: str = "pipe", replicate_out: bool = True):
    """Run the fill/steady/drain schedule from inside an enclosing
    ``shard_map`` over ``axis``.

    Args:
      stage_fn: ``(w, x) -> y`` for this rank's stage; ``x``/``y`` shaped
        like one microbatch ``(mb, ...)``.
      stage_weights: this rank's (already local) stage weights, handed to
        ``stage_fn`` unchanged.
      microbatches: ``(M, mb, ...)`` array, replicated over ``axis`` (only
        stage 0 reads it).
      n_stages: size of ``axis`` (not recoverable from inside shard_map).
      axis: pipeline mesh axis name.
      replicate_out: when True, psum-replicate the final-stage outputs to
        every rank; when False, return them only on the last stage (zeros
        elsewhere) — callers computing a loss mask it to the last stage so
        gradients are not over-counted ``n_stages`` times.

    Returns:
      ``(M, mb, ...)`` outputs of the final stage.
    """
    n_micro = microbatches.shape[0]
    stage = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
    out = jnp.zeros_like(microbatches)
    recv = jnp.zeros_like(microbatches[0])
    for t in range(n_micro + n_stages - 1):
        # stage 0 injects microbatch t during the fill phase; every other
        # stage consumes what its predecessor sent last step
        inp = jnp.where(stage == 0, microbatches[min(t, n_micro - 1)], recv)
        y = stage_fn(stage_weights, inp)
        m = t - (n_stages - 1)
        if m >= 0:  # drain: the last stage owns finished microbatch m
            out = out.at[m].set(jnp.where(stage == n_stages - 1, y, out[m]))
        if t < n_micro + n_stages - 2:
            recv = jax.lax.ppermute(y, axis, perm)
    # only the last stage holds real outputs
    out = jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out))
    if replicate_out:
        # psum replicates them (every other rank contributes zeros)
        out = jax.lax.psum(out, axis)
    return out


def gpipe(stage_fn, stage_weights, microbatches, mesh, axis: str = "pipe"):
    """Pipeline-parallel application of ``S`` sequential stages.

    Args:
      stage_fn: ``(w, x) -> y`` for one stage; ``x``/``y`` shaped (mb, d).
      stage_weights: pytree whose leaves are stacked (S, ...) per-stage
        weights; sharded one stage per rank over ``axis``.
      microbatches: (M, mb, d) input microbatches (replicated; only stage 0
        reads them).
      mesh: mesh containing ``axis`` with size S.
      axis: pipeline mesh axis name.

    Returns:
      (M, mb, d) outputs of the final stage, replicated over ``axis``.
    """
    n_stages = dict(mesh.shape)[axis]
    lead = jax.tree.leaves(stage_weights)[0].shape[0]
    assert lead == n_stages, (
        f"gpipe: got {lead} stage weights for a {n_stages}-way '{axis}' axis")

    def local_fn(ws, xs):
        # ws: (1, ...) — this rank's stage; xs: (M, mb, d) — full stream
        w = jax.tree.map(lambda a: a[0], ws)
        return gpipe_local(stage_fn, w, xs, n_stages=n_stages, axis=axis)

    w_specs = jax.tree.map(lambda _: P(axis), stage_weights)
    x_specs = jax.tree.map(lambda _: P(), microbatches)
    fn = jax.shard_map(local_fn, mesh=mesh, in_specs=(w_specs, x_specs),
                       out_specs=P(), check_vma=False)
    return fn(stage_weights, microbatches)
