"""Pipeline schedules over a ``pipe`` mesh axis.

A :class:`PipelineSchedule` is a *schedule table*: for every clock tick it
says which ``(microbatch, stage, phase)`` micro-ops run, with ``phase`` one
of ``"F"`` (forward) / ``"B"`` (backward).  The table is the single source
of truth for three consumers:

* **execution** — :meth:`PipelineSchedule.run_local` streams the forward
  micro-ops from inside an enclosing ``shard_map`` (activations hop between
  stages through a ring ``ppermute``; the backward ops are realized by
  ``jax.grad`` transposing the forward stream, so the table's ``B`` entries
  describe when a real pipelined runtime would retire each microbatch's
  activations);
* **memory accounting** — :meth:`peak_live_microbatches` simulates the
  table (``F`` allocates one stage-activation, ``B`` frees it) and reports
  the per-stage peak.  GPipe holds all ``M`` microbatches live; 1F1B bounds
  the peak at ``min(S, M)`` — the whole point of the schedule;
* **analysis** — :meth:`bubble_fraction` and the schedule diagrams in the
  README / ``benchmarks/bench_pipeline.py`` render the same table.

Two implementations:

* :class:`GPipeSchedule` — fill/steady/drain: at tick ``t`` stage ``s``
  forwards microbatch ``t - s``; every backward runs after the last
  forward.  Its ``run_local`` is the original :func:`gpipe_local` loop,
  bit-exact against the pre-abstraction code.
* :class:`OneFOneBSchedule` — PipeDream-flush 1F1B: stage ``s`` warms up
  with ``min(S - s - 1, M)`` forwards, then alternates one-forward /
  one-backward, then drains the remaining backwards.  Forward micro-ops
  execute through the generic table-driven runner with a bounded
  activation ring buffer (capacity derived from the table, ≈ ``min(S,
  M)``) instead of gpipe's unbounded in-flight window.

Both schedules push every microbatch through the same per-stage math in
the same microbatch order, so their losses/gradients agree **exactly** —
only op placement (and therefore live-activation memory) differs.  That
equivalence and the memory bound are asserted in ``tests/test_dist.py``.

Legacy entry points :func:`gpipe` / :func:`gpipe_local` are kept verbatim;
``repro.train.step.make_sharded_train_step`` now goes through
:func:`get_schedule` (``ModelConfig.pipeline_schedule``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MicroOp:
    """One cell of the schedule table: at ``tick``, ``stage`` runs the
    ``phase`` ("F"/"B") pass of ``micro``."""
    tick: int
    stage: int
    micro: int
    phase: str


def _table_to_fwd_rows(table: Sequence[MicroOp], n_stages: int
                       ) -> List[Tuple[int, ...]]:
    """Compress the table to forward-only rows for the SPMD runner: one row
    per tick that contains at least one ``F`` op; ``row[s]`` is the micro
    stage ``s`` forwards that tick (``-1`` = idle).  Dropping forward-empty
    ticks (pure-backward slots — under ``jax.grad`` the transpose runs
    them, not the primal loop) preserves the relative order of every
    ``F`` op, which is all the ring transfer needs."""
    by_tick: Dict[int, Dict[int, int]] = {}
    for op in table:
        if op.phase == "F":
            by_tick.setdefault(op.tick, {})[op.stage] = op.micro
    rows = []
    for t in sorted(by_tick):
        row = tuple(by_tick[t].get(s, -1) for s in range(n_stages))
        rows.append(row)
    # the ring buffer's `micro % capacity` slot assignment is collision-free
    # only while every stage consumes micros in increasing order (the live
    # set is then a contiguous window).  gpipe and 1F1B satisfy this; an
    # interleaved/virtual-stage schedule would not — fail loudly instead of
    # silently training on an aliased activation.
    last = [-1] * n_stages
    for row in rows:
        for s, m in enumerate(row):
            if m >= 0:
                if m <= last[s]:
                    raise ValueError(
                        f"schedule forwards micro {m} on stage {s} after "
                        f"micro {last[s]}: non-monotone forward order is "
                        "not supported by the ring-buffer runner")
                last[s] = m
    return rows


def _ring_capacity(rows: Sequence[Tuple[int, ...]], n_stages: int) -> int:
    """Minimal per-rank activation-buffer capacity for the runner: the max
    number of microbatches simultaneously resident on any stage (received
    from the predecessor but not yet consumed).  Micros arrive in order, so
    ``micro % capacity`` slots never collide at this capacity."""
    cap = 1
    for s in range(1, n_stages):
        produced = {}
        consumed = {}
        for t, row in enumerate(rows):
            if row[s - 1] >= 0:
                produced[row[s - 1]] = t
            if row[s] >= 0:
                consumed[row[s]] = t
        for t in range(len(rows)):
            live = sum(1 for m, pt in produced.items()
                       if pt < t <= consumed.get(m, -1))
            cap = max(cap, live)
    return cap


def _run_fwd_rows(rows: Sequence[Tuple[int, ...]], stage_fn, stage_weights,
                  microbatches, *, n_stages: int, axis: str,
                  replicate_out: bool):
    """Execute a forward row table from inside a ``shard_map`` over
    ``axis``.  Same SPMD shape as :func:`gpipe_local` — every rank calls
    ``stage_fn`` every row (idle ranks compute on don't-care data whose
    outputs are masked out of the buffer/output writes, so their cotangents
    are exactly zero) — but produce→consume gaps larger than one tick are
    carried in a bounded ring buffer instead of a single ``recv`` slot."""
    n_micro = microbatches.shape[0]
    stage = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
    cap = _ring_capacity(rows, n_stages)
    buf = jnp.zeros((cap,) + microbatches.shape[1:], microbatches.dtype)
    out = jnp.zeros_like(microbatches)
    recv = jnp.zeros_like(microbatches[0])
    for t, row in enumerate(rows):
        if t > 0:
            # bank the activation ppermuted in at the end of the previous
            # row under the *sender's* micro index (static per stage)
            prev = rows[t - 1]
            recv_micro = jnp.asarray(
                tuple(prev[s - 1] if s > 0 else -1
                      for s in range(n_stages)))[stage]
            slot = jnp.maximum(recv_micro, 0) % cap
            buf = jnp.where(
                recv_micro >= 0,
                jax.lax.dynamic_update_index_in_dim(buf, recv, slot, 0),
                buf)
        m_here = jnp.asarray(row)[stage]
        idx = jnp.maximum(m_here, 0)
        x0 = jax.lax.dynamic_index_in_dim(
            microbatches, jnp.minimum(idx, n_micro - 1), 0, keepdims=False)
        xb = jax.lax.dynamic_index_in_dim(buf, idx % cap, 0, keepdims=False)
        inp = jnp.where(stage == 0, x0, xb)
        y = stage_fn(stage_weights, inp)
        m_last = row[n_stages - 1]
        if m_last >= 0:  # the last stage finished microbatch m_last
            out = out.at[m_last].set(
                jnp.where(stage == n_stages - 1, y, out[m_last]))
        if t < len(rows) - 1:
            recv = jax.lax.ppermute(y, axis, perm)
    out = jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out))
    if replicate_out:
        out = jax.lax.psum(out, axis)
    return out


class PipelineSchedule:
    """Base schedule: subclasses define :meth:`table`; execution, memory
    accounting and bubble analysis derive from it."""

    name: str = "abstract"

    def table(self, n_micro: int, n_stages: int) -> List[MicroOp]:
        raise NotImplementedError

    def forward_rows(self, n_micro: int, n_stages: int
                     ) -> List[Tuple[int, ...]]:
        """Forward-only rows for the SPMD runner (one row per tick that
        forwards anything; ``row[s]`` = micro or -1)."""
        return _table_to_fwd_rows(self.table(n_micro, n_stages), n_stages)

    def peak_live_microbatches(self, n_micro: int, n_stages: int) -> int:
        """Max microbatch activations simultaneously live on any stage
        (``F`` allocates, ``B`` frees — the classic pipeline memory
        model).  Multiply by bytes-per-microbatch-activation for a peak
        memory estimate (``benchmarks/bench_pipeline.py`` does)."""
        live = [0] * n_stages
        peak = 0
        for op in sorted(self.table(n_micro, n_stages),
                         key=lambda o: o.tick):
            live[op.stage] += 1 if op.phase == "F" else -1
            peak = max(peak, live[op.stage])
        return peak

    def bubble_fraction(self, n_micro: int, n_stages: int) -> float:
        """Idle fraction of the busiest-possible schedule: 1 - useful ops /
        (stages × total ticks)."""
        table = self.table(n_micro, n_stages)
        ticks = max(op.tick for op in table) + 1
        return 1.0 - len(table) / float(n_stages * ticks)

    def run_local(self, stage_fn, stage_weights, microbatches, *,
                  n_stages: int, axis: str = "pipe",
                  replicate_out: bool = True):
        """Run the schedule's forward stream from inside an enclosing
        ``shard_map`` over ``axis`` (same contract as :func:`gpipe_local`)."""
        return _run_fwd_rows(
            self.forward_rows(microbatches.shape[0], n_stages),
            stage_fn, stage_weights, microbatches,
            n_stages=n_stages, axis=axis, replicate_out=replicate_out)

    def run(self, stage_fn, stage_weights, microbatches, mesh,
            axis: str = "pipe"):
        """Standalone entry point: wraps :meth:`run_local` in its own
        ``shard_map`` (stage weights stacked ``(S, ...)``, sharded
        ``P(axis)``) — the generalization of :func:`gpipe`."""
        n_stages = dict(mesh.shape)[axis]
        lead = jax.tree.leaves(stage_weights)[0].shape[0]
        assert lead == n_stages, (
            f"{self.name}: got {lead} stage weights for a "
            f"{n_stages}-way '{axis}' axis")

        def local_fn(ws, xs):
            w = jax.tree.map(lambda a: a[0], ws)
            return self.run_local(stage_fn, w, xs, n_stages=n_stages,
                                  axis=axis)

        w_specs = jax.tree.map(lambda _: P(axis), stage_weights)
        x_specs = jax.tree.map(lambda _: P(), microbatches)
        fn = jax.shard_map(local_fn, mesh=mesh, in_specs=(w_specs, x_specs),
                           out_specs=P(), check_vma=False)
        return fn(stage_weights, microbatches)


class GPipeSchedule(PipelineSchedule):
    """Classic fill/steady/drain: all forwards, then all backwards.
    ``run_local`` is the original :func:`gpipe_local` loop — bit-exact
    against the pre-abstraction pipeline step."""

    name = "gpipe"

    def table(self, n_micro: int, n_stages: int) -> List[MicroOp]:
        ops = [MicroOp(s + m, s, m, "F")
               for s in range(n_stages) for m in range(n_micro)]
        t_fwd = n_micro + n_stages - 1  # every forward done before any B
        ops += [MicroOp(t_fwd + (n_stages - 1 - s) + (n_micro - 1 - m),
                        s, m, "B")
                for s in range(n_stages) for m in range(n_micro)]
        return ops

    def run_local(self, stage_fn, stage_weights, microbatches, *,
                  n_stages: int, axis: str = "pipe",
                  replicate_out: bool = True):
        return gpipe_local(stage_fn, stage_weights, microbatches,
                           n_stages=n_stages, axis=axis,
                           replicate_out=replicate_out)


class OneFOneBSchedule(PipelineSchedule):
    """PipeDream-flush 1F1B: bounded in-flight activations.

    Per stage ``s``: ``min(S - s - 1, M)`` warmup forwards, then strict
    one-forward/one-backward alternation, then the remaining backwards.
    Tick placement comes from a greedy list-scheduling pass over the
    dependency DAG (``F(s, m)`` after ``F(s-1, m)``; ``B(s, m)`` after
    ``B(s+1, m)`` and, on the last stage, after ``F(S-1, m)``; one op per
    stage per tick) — the standard synchronous 1F1B timetable."""

    name = "1f1b"

    def table(self, n_micro: int, n_stages: int) -> List[MicroOp]:
        seqs = []
        for s in range(n_stages):
            warmup = min(n_stages - s - 1, n_micro)
            seq = [("F", m) for m in range(warmup)]
            b = 0
            for m in range(warmup, n_micro):
                seq.append(("F", m))
                seq.append(("B", b))
                b += 1
            seq += [("B", m) for m in range(b, n_micro)]
            seqs.append(seq)

        ptr = [0] * n_stages
        done: Dict[tuple, int] = {}
        ops: List[MicroOp] = []
        total = sum(len(q) for q in seqs)
        t = 0
        while len(ops) < total:
            if t > 4 * (n_micro + n_stages) + 8:
                raise RuntimeError(
                    f"1f1b schedule did not converge for M={n_micro}, "
                    f"S={n_stages}")  # pragma: no cover - scheduler bug net
            for s in range(n_stages):
                if ptr[s] >= len(seqs[s]):
                    continue
                phase, m = seqs[s][ptr[s]]
                if phase == "F":
                    ready = s == 0 or done.get(("F", s - 1, m), t) < t
                elif s == n_stages - 1:
                    ready = done.get(("F", s, m), t) < t
                else:
                    ready = done.get(("B", s + 1, m), t) < t
                if ready:
                    done[(phase, s, m)] = t
                    ops.append(MicroOp(t, s, m, phase))
                    ptr[s] += 1
            t += 1
        return ops


SCHEDULES = {
    "gpipe": GPipeSchedule,
    "1f1b": OneFOneBSchedule,
}


def get_schedule(name) -> PipelineSchedule:
    """Resolve a schedule by name (or pass a :class:`PipelineSchedule`
    instance through).  Raises ``ValueError`` listing the valid choices —
    launchers surface this eagerly, before any tracing."""
    if isinstance(name, PipelineSchedule):
        return name
    try:
        return SCHEDULES[name]()
    except KeyError:
        raise ValueError(
            f"unknown pipeline schedule {name!r}; valid choices: "
            f"{sorted(SCHEDULES)}") from None


# ---------------------------------------------------------------------------
# legacy entry points (PR-2 API): the gpipe loop, verbatim
# ---------------------------------------------------------------------------

def gpipe_local(stage_fn, stage_weights, microbatches, *, n_stages: int,
                axis: str = "pipe", replicate_out: bool = True):
    """Run the gpipe fill/steady/drain schedule from inside an enclosing
    ``shard_map`` over ``axis``.

    Args:
      stage_fn: ``(w, x) -> y`` for this rank's stage; ``x``/``y`` shaped
        like one microbatch ``(mb, ...)``.
      stage_weights: this rank's (already local) stage weights, handed to
        ``stage_fn`` unchanged.
      microbatches: ``(M, mb, ...)`` array, replicated over ``axis`` (only
        stage 0 reads it).
      n_stages: size of ``axis`` (not recoverable from inside shard_map).
      axis: pipeline mesh axis name.
      replicate_out: when True, psum-replicate the final-stage outputs to
        every rank; when False, return them only on the last stage (zeros
        elsewhere) — callers computing a loss mask it to the last stage so
        gradients are not over-counted ``n_stages`` times.

    Returns:
      ``(M, mb, ...)`` outputs of the final stage.
    """
    n_micro = microbatches.shape[0]
    stage = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
    out = jnp.zeros_like(microbatches)
    recv = jnp.zeros_like(microbatches[0])
    for t in range(n_micro + n_stages - 1):
        # stage 0 injects microbatch t during the fill phase; every other
        # stage consumes what its predecessor sent last step
        inp = jnp.where(stage == 0, microbatches[min(t, n_micro - 1)], recv)
        y = stage_fn(stage_weights, inp)
        m = t - (n_stages - 1)
        if m >= 0:  # drain: the last stage owns finished microbatch m
            out = out.at[m].set(jnp.where(stage == n_stages - 1, y, out[m]))
        if t < n_micro + n_stages - 2:
            recv = jax.lax.ppermute(y, axis, perm)
    # only the last stage holds real outputs
    out = jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out))
    if replicate_out:
        # psum replicates them (every other rank contributes zeros)
        out = jax.lax.psum(out, axis)
    return out


def gpipe(stage_fn, stage_weights, microbatches, mesh, axis: str = "pipe"):
    """Pipeline-parallel application of ``S`` sequential stages with the
    gpipe schedule (standalone ``shard_map`` wrapper; see
    :meth:`PipelineSchedule.run` for the schedule-generic form)."""
    return GPipeSchedule().run(stage_fn, stage_weights, microbatches, mesh,
                               axis=axis)
