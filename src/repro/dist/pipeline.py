"""GPipe microbatch pipelining over a ``pipe`` mesh axis.

``gpipe`` runs ``stage_fn`` S times (one stage per pipeline rank) over M
microbatches with the classic fill/steady/drain schedule: at step ``t``
stage ``s`` processes microbatch ``t - s``, and activations hop to the next
stage through a ring ``ppermute``.  Total ``M + S - 1`` steps, so bubble
fraction ``(S - 1) / (M + S - 1)`` — the caller picks M accordingly.

Implemented with ``shard_map`` so the collective schedule is explicit and
the per-device program is exactly one stage's weights (stage weights enter
sharded ``P("pipe")`` and never replicate).  Numerics match running the
stages sequentially — asserted against that oracle by tests/test_dist.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def gpipe(stage_fn, stage_weights, microbatches, mesh, axis: str = "pipe"):
    """Pipeline-parallel application of ``S`` sequential stages.

    Args:
      stage_fn: ``(w, x) -> y`` for one stage; ``x``/``y`` shaped (mb, d).
      stage_weights: pytree whose leaves are stacked (S, ...) per-stage
        weights; sharded one stage per rank over ``axis``.
      microbatches: (M, mb, d) input microbatches (replicated; only stage 0
        reads them).
      mesh: mesh containing ``axis`` with size S.
      axis: pipeline mesh axis name.

    Returns:
      (M, mb, d) outputs of the final stage, replicated over ``axis``.
    """
    n_stages = dict(mesh.shape)[axis]
    n_micro = jax.tree.leaves(microbatches)[0].shape[0]
    lead = jax.tree.leaves(stage_weights)[0].shape[0]
    assert lead == n_stages, (
        f"gpipe: got {lead} stage weights for a {n_stages}-way '{axis}' axis")
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def local_fn(ws, xs):
        # ws: (1, ...) — this rank's stage; xs: (M, mb, d) — full stream
        w = jax.tree.map(lambda a: a[0], ws)
        stage = jax.lax.axis_index(axis)
        out = jnp.zeros_like(xs)
        recv = jnp.zeros_like(xs[0])
        for t in range(n_micro + n_stages - 1):
            # stage 0 injects microbatch t during the fill phase; every
            # other stage consumes what its predecessor sent last step
            inp = jnp.where(stage == 0, xs[min(t, n_micro - 1)], recv)
            y = stage_fn(w, inp)
            m = t - (n_stages - 1)
            if m >= 0:  # drain: the last stage owns finished microbatch m
                out = out.at[m].set(jnp.where(stage == n_stages - 1,
                                              y, out[m]))
            if t < n_micro + n_stages - 2:
                recv = jax.lax.ppermute(y, axis, perm)
        # only the last stage holds real outputs; psum replicates them
        # (every other rank contributes zeros)
        out = jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out))
        return jax.lax.psum(out, axis)

    w_specs = jax.tree.map(lambda _: P(axis), stage_weights)
    x_specs = jax.tree.map(lambda _: P(), microbatches)
    fn = jax.shard_map(local_fn, mesh=mesh, in_specs=(w_specs, x_specs),
                       out_specs=P(), check_vma=False)
    return fn(stage_weights, microbatches)
