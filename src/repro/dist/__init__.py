"""Distribution layer: logical-axis sharding rules, pipeline parallelism,
and compressed cross-pod collectives.

* :mod:`repro.dist.sharding` — logical axis -> mesh axis rules with
  divisibility fallback; parameter / batch / KV-cache shardings.
* :mod:`repro.dist.pipeline` — GPipe microbatch pipelining over ``pipe``.
* :mod:`repro.dist.compress` — bf16 + error-feedback ``psum`` for the
  slow ``pod`` axis.
"""

from repro.dist import compress, pipeline, sharding
from repro.dist.compress import compressed_psum, ef_state
from repro.dist.pipeline import gpipe
from repro.dist.sharding import (batch_spec, cache_sharding,
                                 decode_cache_shardings, dp_axes, dp_size,
                                 model_size, param_shardings, spec_for_axes)

__all__ = [
    "sharding", "pipeline", "compress",
    "spec_for_axes", "param_shardings", "batch_spec", "cache_sharding",
    "decode_cache_shardings", "dp_axes", "dp_size", "model_size",
    "gpipe", "compressed_psum", "ef_state",
]
