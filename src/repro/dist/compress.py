"""Compressed cross-pod gradient reduction with error feedback.

Over the slow ``pod`` axis (data-center interconnect, not ICI) the gradient
all-reduce dominates step time, so it runs quantized: each step the local
gradient plus the carried *error-feedback* residual is rounded to bf16,
the bf16 payload is psum-averaged, and the rounding error is carried into
the next step.  The residual makes the scheme unbiased over time — the
accumulated average converges to the true mean (1-bit-Adam / EF-SGD
argument), which tests/test_dist.py asserts over 20 steps.

Usage inside a shard_map over the reduction axis::

    err = ef_state(grads)                     # once, outside the step
    avg, err = compressed_psum(grads, err, "pod")
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

COMPRESSED_DTYPE = jnp.bfloat16


def ef_state(tree):
    """Zero-initialized f32 error-feedback accumulators matching ``tree``."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)


def compressed_psum(grads, err, axis: str) -> Tuple[object, object]:
    """Mean-reduce ``grads`` over ``axis`` with bf16 payload + error
    feedback.  Must be called inside a shard_map/pmap over ``axis``.

    Returns ``(avg, new_err)``: the (replicated) quantized mean and the
    residual to carry into the next step.
    """
    n = jax.lax.psum(1, axis)
    corrected = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, err)
    quantized = jax.tree.map(
        lambda c: c.astype(COMPRESSED_DTYPE), corrected)
    new_err = jax.tree.map(
        lambda c, q: c - q.astype(jnp.float32), corrected, quantized)
    # reduce in the compressed dtype — upcasting first would put f32 back
    # on the wire and defeat the whole point.  The reduction's own bf16
    # rounding is NOT error-fed-back (only local quantization is), but it
    # is bounded per step and unbiased in expectation.
    avg = jax.tree.map(
        lambda q: jax.lax.psum(q, axis).astype(jnp.float32) / n, quantized)
    return avg, new_err


def compressed_psum_grouped(grads, err, axis: str, group_order):
    """:func:`compressed_psum` issued as independent per-group reductions.

    ``grads``/``err`` are dicts of subtrees; ``group_order`` lists their
    keys in *issue order*.  Quantization and reduction are elementwise, so
    the result is bit-identical to one tree-wide :func:`compressed_psum` —
    what changes is the program: each group's bf16 buckets enter the HLO as
    soon as its gradients finalize (the pipeline step lists the stage
    groups first — their grads finish during the backward drain — then
    glue), and the join happens at the optimizer update that consumes them.
    A latency-hiding scheduler can therefore overlap the slow ``pod``-axis
    wire time of early buckets with the remaining backward work and the
    next step's fill phase, instead of serializing one monolithic
    reduction behind the full gradient tree.

    Returns ``(avg, new_err)`` dicts keyed like ``grads``.
    """
    missing = set(grads) - set(group_order)
    if missing:
        raise ValueError(f"group_order misses gradient groups {missing}")
    avg: dict = {}
    new_err: dict = {}
    for k in group_order:
        if k not in grads:
            continue
        avg[k], new_err[k] = compressed_psum(grads[k], err[k], axis)
    return avg, new_err
