"""Learned cost-model subsystem (ROADMAP item: learned cost model).

Turns the cross-kernel measurement memo into training data
(:mod:`repro.costmodel.dataset`), fits a small JAX MLP cycle predictor
(:mod:`repro.costmodel.model`), and spends it through ranker-guided
search strategies (:mod:`repro.costmodel.search` via
:mod:`repro.costmodel.rankers`) that verify only their top-k candidates
on the real timer.  :mod:`repro.costmodel.evaluator` races every
registered strategy under one measurement budget
(``python -m repro.launch.evaluate``).
"""

from repro.costmodel.dataset import (FEATURE_DIM, CostDataset,
                                     CostModelVersionError,
                                     ProgramFeaturizer)
from repro.costmodel.evaluator import (DEFAULT_STRATEGIES,
                                       evaluate_strategies, format_table,
                                       heldout_rank_correlation, spearman)
from repro.costmodel.model import CostModel
from repro.costmodel.rankers import (CostModelRanker, CostRanker,
                                     OracleRanker, PolicyRanker,
                                     make_ranker)
from repro.costmodel.search import BeamSearchStrategy, GreedyLookaheadStrategy

__all__ = [
    "CostDataset", "ProgramFeaturizer", "FEATURE_DIM",
    "CostModel", "CostModelVersionError",
    "CostRanker", "OracleRanker", "CostModelRanker", "PolicyRanker",
    "make_ranker",
    "BeamSearchStrategy", "GreedyLookaheadStrategy",
    "evaluate_strategies", "format_table", "heldout_rank_correlation",
    "spearman", "DEFAULT_STRATEGIES",
]
