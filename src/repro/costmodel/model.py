"""The learned cycle predictor: a small JAX MLP over schedule features.

Trained on :class:`repro.costmodel.dataset.CostDataset` with two heads
sharing one scalar output:

* **MSE on log-cycles** — absolute calibration, so predictions stay
  comparable across kernels of very different magnitudes;
* **pairwise ranking loss over same-kernel pairs** (the CUDA-L1 recipe,
  2507.14111): for two schedules of one program, a logistic loss on the
  prediction difference signed by the measured ordering.  Search only
  needs *ranking* to be right — the top-k candidates it verifies on the
  real timer are chosen by order, not by value — so the ranking head
  optimizes exactly the quantity the beam consumes.

``fit`` is bit-reproducible under a fixed seed: batch indices come from a
``numpy`` generator seeded once, parameters from ``jax.random.PRNGKey``,
and the jitted update is deterministic on CPU.  Models persist to a
versioned ``.npz``; unknown versions raise
:class:`~repro.costmodel.dataset.CostModelVersionError` (the schedule
cache / measurement memo convention).
"""

from __future__ import annotations

import zipfile
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.costmodel.dataset import (FEATURE_VERSION, CostDataset,
                                     CostModelVersionError)
from repro.optim import adam
from repro.optim.adamw import apply_updates

MODEL_FORMAT = "repro-cost-model"
MODEL_VERSION = 1
_KNOWN_MODEL_VERSIONS = (1,)

DEFAULT_HIDDEN = (64, 64)


class CostModel:
    """MLP cycle predictor: ``init`` / ``apply`` / ``loss`` plus the
    convenience ``fit`` / ``predict_log`` / ``save`` / ``load`` wrappers.

    ``params`` is a flat dict of jnp arrays (``w0, b0, w1, b1, ...``);
    ``norm`` holds the feature/target standardization (means and scales)
    learned from the training split — stored outside the gradient tree.
    """

    def __init__(self, params: Dict[str, jnp.ndarray],
                 norm: Dict[str, np.ndarray],
                 feature_version: int = FEATURE_VERSION):
        self.params = params
        self.norm = norm
        self.feature_version = int(feature_version)

    # -- the three core functions (pure, jit-friendly) -----------------------

    @staticmethod
    def init(key: jax.Array, in_dim: int,
             hidden: Sequence[int] = DEFAULT_HIDDEN) -> Dict[str, jnp.ndarray]:
        dims = (int(in_dim),) + tuple(hidden) + (1,)
        params: Dict[str, jnp.ndarray] = {}
        for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
            key, sub = jax.random.split(key)
            params[f"w{i}"] = (jax.random.normal(sub, (a, b), jnp.float32)
                               * np.sqrt(2.0 / a))
            params[f"b{i}"] = jnp.zeros((b,), jnp.float32)
        return params

    @staticmethod
    def apply(params: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
        """Normalized-log-cycle predictions for normalized features."""
        n_layers = len(params) // 2
        h = x
        for i in range(n_layers - 1):
            h = jax.nn.relu(h @ params[f"w{i}"] + params[f"b{i}"])
        last = n_layers - 1
        return (h @ params[f"w{last}"] + params[f"b{last}"])[..., 0]

    @staticmethod
    def loss(params: Dict[str, jnp.ndarray], x: jnp.ndarray, y: jnp.ndarray,
             group: jnp.ndarray, rank_weight: float = 1.0
             ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
        """MSE + pairwise same-group ranking loss over one batch.

        Every ordered pair (i, j) in the batch with ``group[i] == group[j]``
        and a measurable target difference contributes
        ``softplus(-sign(y_i - y_j) * (pred_i - pred_j))`` — minimized when
        the prediction difference agrees in sign (and grows in margin) with
        the measured one.
        """
        pred = CostModel.apply(params, x)
        mse = jnp.mean((pred - y) ** 2)
        dp = pred[:, None] - pred[None, :]
        dy = y[:, None] - y[None, :]
        same = ((group[:, None] == group[None, :])
                & (jnp.abs(dy) > 1e-6))
        pair = jax.nn.softplus(-jnp.sign(dy) * dp)
        rank = (jnp.sum(jnp.where(same, pair, 0.0))
                / jnp.maximum(jnp.sum(same), 1))
        return mse + rank_weight * rank, (mse, rank)

    # -- training ------------------------------------------------------------

    @classmethod
    def fit(cls, dataset: CostDataset, steps: int = 1500, seed: int = 0,
            batch_size: int = 256, lr: float = 1e-3,
            hidden: Sequence[int] = DEFAULT_HIDDEN,
            rank_weight: float = 1.0, verbose: bool = False
            ) -> Tuple["CostModel", List[Dict]]:
        """Train on the dataset's train split; returns (model, history).

        Bit-reproducible under a fixed ``seed``: re-running this call on
        the same dataset yields parameter arrays that compare equal.
        """
        train = dataset.train
        if len(train) < 2:
            raise ValueError(
                f"cost-model training needs >= 2 train rows, got "
                f"{len(train)} (warm a memo first)")
        X = train.X.astype(np.float32)
        y = train.y.astype(np.float32)
        mu = X.mean(axis=0)
        sigma = X.std(axis=0) + 1e-6
        ymu = np.float32(y.mean())
        ystd = np.float32(y.std() + 1e-6)
        Xn = (X - mu) / sigma
        yn = (y - ymu) / ystd
        norm = {"mu": mu.astype(np.float32),
                "sigma": sigma.astype(np.float32),
                "ymu": np.asarray(ymu, np.float32),
                "ystd": np.asarray(ystd, np.float32)}

        params = cls.init(jax.random.PRNGKey(seed), X.shape[1], hidden)
        opt = adam(lr, max_grad_norm=1.0)
        opt_state = opt.init(params)

        @jax.jit
        def update(params, opt_state, xb, yb, gb):
            (total, (mse, rank)), grads = jax.value_and_grad(
                cls.loss, has_aux=True)(params, xb, yb, gb,
                                        rank_weight=rank_weight)
            upd, opt_state = opt.update(grads, opt_state, params)
            return apply_updates(params, upd), opt_state, total, mse, rank

        rng = np.random.default_rng(seed)
        n = Xn.shape[0]
        bs = min(batch_size, n)
        history: List[Dict] = []
        for step in range(int(steps)):
            idx = rng.integers(0, n, size=bs)
            params, opt_state, total, mse, rank = update(
                params, opt_state, jnp.asarray(Xn[idx]),
                jnp.asarray(yn[idx]), jnp.asarray(train.group[idx]))
            if step % 100 == 0 or step == int(steps) - 1:
                row = {"step": step, "loss": float(total),
                       "mse": float(mse), "rank": float(rank)}
                history.append(row)
                if verbose:
                    print(f"[costmodel] step={step} loss={row['loss']:.4f} "
                          f"mse={row['mse']:.4f} rank={row['rank']:.4f}")
        return cls(params, norm, dataset.feature_version), history

    # -- inference -----------------------------------------------------------

    def predict_log(self, X: np.ndarray) -> np.ndarray:
        """Predicted log-cycles for raw (unnormalized) feature rows."""
        X = np.asarray(X, np.float32)
        if X.ndim == 1:
            X = X[None, :]
        xn = (X - self.norm["mu"]) / self.norm["sigma"]
        pred = CostModel.apply(self.params, jnp.asarray(xn))
        return (np.asarray(pred) * float(self.norm["ystd"])
                + float(self.norm["ymu"]))

    def predict(self, X: np.ndarray) -> np.ndarray:
        """Predicted cycles (exp of the log head)."""
        return np.exp(self.predict_log(X))

    # -- persistence ---------------------------------------------------------

    def save(self, path: str) -> None:
        arrays = {f"param_{k}": np.asarray(v)
                  for k, v in self.params.items()}
        arrays.update({f"norm_{k}": np.asarray(v)
                       for k, v in self.norm.items()})
        np.savez(path, format=MODEL_FORMAT, version=MODEL_VERSION,
                 feature_version=self.feature_version, **arrays)

    @classmethod
    def load(cls, path: str) -> "CostModel":
        try:
            with np.load(path, allow_pickle=False) as z:
                if "format" not in z.files \
                        or str(z["format"]) != MODEL_FORMAT:
                    raise CostModelVersionError(
                        f"{path} is not a {MODEL_FORMAT} file")
                version = int(z["version"])
                if version not in _KNOWN_MODEL_VERSIONS:
                    raise CostModelVersionError(
                        f"cost model {path} has version {version!r}; this "
                        f"build reads {_KNOWN_MODEL_VERSIONS}")
                params = {k[len("param_"):]: jnp.asarray(z[k])
                          for k in z.files if k.startswith("param_")}
                norm = {k[len("norm_"):]: z[k]
                        for k in z.files if k.startswith("norm_")}
                return cls(params, norm, int(z["feature_version"]))
        except (OSError, ValueError, KeyError, zipfile.BadZipFile) as e:
            raise CostModelVersionError(
                f"corrupt cost model {path}: {e}") from e
