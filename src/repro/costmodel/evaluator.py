"""Strategy Evaluator: every search strategy, one measurement budget.

Runs the full strategy roster — ppo / greedy-swap / random / beam x
{oracle, cost, policy} / greedy-lookahead — over registry kernels, each
cell on a **fresh** ``FastTimingBackend`` so its memo counters are that
cell's true measurement bill, and emits a per-kernel comparison table:
best cycles, improvement vs the -O3 baseline, real measurements spent,
wall time.

The harness owns the cost-model lifecycle the guided strategies need:

1. **warm** — one PPO run per kernel (this is also the roster's "ppo"
   row), harvesting the agent params for the :class:`PolicyRanker` and
   the backend memo's measurement corpus;
2. **train** — export the warm memos into a :class:`CostDataset`, fit the
   :class:`CostModel`, and score its held-out Spearman rank correlation
   against the oracle cycles;
3. **race** — run every remaining strategy cell under the shared budget.

Budget semantics: ``budget`` is the per-cell real-measurement allowance.
PPO gets it as timesteps, greedy as ``budget / branching`` steps, random
as restart episodes; the beam/lookahead strategies enforce it directly
via ``max_measurements`` — the model-guided ones get only a **quarter of
what greedy actually spent** on that kernel (``budget / 4`` when greedy
is not in the roster), which is the claim under test
(ranked-then-verified search matches exhaustive probing on a fraction of
the measurements).

CLI: ``python -m repro.launch.evaluate``.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.env import AssemblyGame
from repro.core.microbench import build_stall_table
from repro.core.ppo import PPOConfig
from repro.costmodel.dataset import CostDataset, ProgramFeaturizer
from repro.costmodel.model import CostModel
from repro.costmodel.search import BeamSearchStrategy, GreedyLookaheadStrategy
from repro.sched import baseline, lowering
from repro.sched.backends import FastTimingBackend, SharedMeasureMemo
from repro.sched.session import (GreedySwapStrategy, PPOStrategy,
                                 RandomSearchStrategy)

# the two kernels of §5.7 — the paper's discovery study set
DEFAULT_KERNELS = ("matmul_leakyrelu", "bmm")

DEFAULT_STRATEGIES = ("ppo", "greedy", "random", "beam-oracle",
                      "beam-cost", "beam-policy", "lookahead")

# strategies that rank through the trained cost model / policy value head
# run on a quarter of greedy's measured bill (or of the budget when greedy
# is absent) — the evaluator's headline comparison
GUIDED_BUDGET_DIVISOR = 4

# roster names whose cells race before the guided ones (greedy's measured
# spend sizes the guided allowance)
UNGUIDED = ("greedy", "random", "beam-oracle")


def _rankdata(x: np.ndarray) -> np.ndarray:
    """Average-tie ranks (scipy.stats.rankdata; the container has no
    scipy)."""
    x = np.asarray(x, np.float64)
    order = np.argsort(x, kind="stable")
    ranks = np.empty(len(x), np.float64)
    ranks[order] = np.arange(1, len(x) + 1, dtype=np.float64)
    # average ranks over tied values
    sx = x[order]
    i = 0
    while i < len(sx):
        j = i
        while j + 1 < len(sx) and sx[j + 1] == sx[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = (i + j) / 2.0 + 1.0
        i = j + 1
    return ranks


def spearman(a: Sequence[float], b: Sequence[float]) -> float:
    """Spearman rank correlation (Pearson over average-tie ranks)."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    if len(a) < 2:
        return float("nan")
    ra, rb = _rankdata(a), _rankdata(b)
    sa, sb = ra.std(), rb.std()
    if sa == 0 or sb == 0:
        return float("nan")
    return float(((ra - ra.mean()) * (rb - rb.mean())).mean() / (sa * sb))


def heldout_rank_correlation(model: CostModel, dataset: CostDataset,
                             min_group: int = 3) -> float:
    """Size-weighted mean per-kernel Spearman of model predictions vs
    measured cycles over the held-out split."""
    ev = dataset.eval
    if len(ev) == 0:
        return float("nan")
    pred = model.predict_log(ev.X)
    corrs, weights = [], []
    for g in np.unique(ev.group):
        m = ev.group == g
        if int(m.sum()) < min_group:
            continue
        c = spearman(pred[m], ev.y[m])
        if not np.isnan(c):
            corrs.append(c)
            weights.append(int(m.sum()))
    if not corrs:
        return float("nan")
    return float(np.average(corrs, weights=weights))


def _baseline_branching(program, stall_db) -> int:
    """Legal-swap count of the -O3 schedule — the per-step probe bill a
    steepest-descent pass pays (sized on a throwaway env so nothing is
    charged to any strategy's backend)."""
    env = AssemblyGame(program, stall_db=stall_db, episode_length=1)
    return max(1, len(env.valid_actions()))


def make_roster(names: Sequence[str], budget: int, seed: int,
                branching: Dict[str, int], model: Optional[CostModel],
                policy_params: Dict[str, Dict],
                guided_budget: Dict[str, int]) -> Dict[str, "callable"]:
    """name -> (kernel -> strategy instance) factories for the race phase
    (per-kernel because branching / policy params / guided budgets are
    per-kernel).  ``guided_budget`` is looked up at *call* time so the
    race loop can re-derive it from greedy's measured spend before the
    guided cells run.

    Guided beams run at ``width=1``: verified first-improvement descent
    with model-ordered probing.  Wider beams expand predicted-but-
    unverified candidates whose children then compete for the scarce
    verification budget — empirically that drifts on the 1-cycle
    near-ties where the model misranks, while the width-1 walk matches
    greedy's best on a quarter of its measurements.
    """
    roster = {}
    for name in names:
        if name == "ppo":
            continue                       # the warm phase is the ppo row
        if name == "greedy":
            roster[name] = lambda k: GreedySwapStrategy(
                max_steps=max(1, budget // branching[k]))
        elif name == "random":
            roster[name] = lambda k: RandomSearchStrategy(
                episodes=max(1, budget // 16), episode_length=16, seed=seed)
        elif name == "beam-oracle":
            roster[name] = lambda k: BeamSearchStrategy(
                width=4, depth=64, ranker="oracle",
                max_measurements=budget)
        elif name == "beam-cost":
            roster[name] = lambda k: BeamSearchStrategy(
                width=1, depth=64, verify_top_k=2, ranker="cost",
                model=model, max_measurements=guided_budget[k])
        elif name == "beam-policy":
            roster[name] = lambda k: BeamSearchStrategy(
                width=1, depth=64, verify_top_k=2, ranker="policy",
                policy_params=policy_params[k],
                max_measurements=guided_budget[k])
        elif name == "lookahead":
            roster[name] = lambda k: GreedyLookaheadStrategy(
                lookahead=4, verify_top_k=2, max_steps=64, ranker="cost",
                model=model, max_measurements=guided_budget[k])
        else:
            raise KeyError(f"unknown evaluator strategy {name!r}; one of "
                           f"{list(DEFAULT_STRATEGIES)}")
    return roster


def evaluate_strategies(kernels: Optional[Sequence[str]] = None,
                        strategies: Optional[Sequence[str]] = None,
                        budget: int = 512,
                        seed: int = 0,
                        train_steps: int = 1500,
                        stall_db: Optional[Dict[str, int]] = None,
                        extra_memo: Optional[SharedMeasureMemo] = None,
                        verbose: bool = False) -> Dict:
    """Run the strategy roster under a shared per-cell measurement budget.

    Returns ``{"rows": [...], "rank_correlation": float, "budget": int,
    "dataset_rows": int, "model": CostModel | None}`` — rows carry
    (strategy, kernel, baseline/best cycles, improvement vs -O3, real
    measurements spent, wall seconds).  ``extra_memo`` contributes extra
    training corpus (e.g. a campaign's ``--memo-dir`` payload) without
    affecting any cell's accounting.
    """
    kernels = list(kernels or DEFAULT_KERNELS)
    strategies = list(strategies or DEFAULT_STRATEGIES)
    if stall_db is None:
        stall_db = build_stall_table()

    from repro.kernels import get_kernel
    programs: Dict[str, list] = {}
    for name in kernels:
        kdef = get_kernel(name)
        spec = kdef.make_spec(kdef.configs[0])
        programs[name] = baseline.schedule(lowering.lower(spec))
    featurizers = {name: ProgramFeaturizer(prog, stall_db=stall_db)
                   for name, prog in programs.items()}
    branching = {name: _baseline_branching(prog, stall_db)
                 for name, prog in programs.items()}

    rows: List[Dict] = []

    def add_row(strategy: str, kernel: str, outcome, spent: int,
                seconds: float) -> None:
        rows.append({
            "strategy": strategy, "kernel": kernel,
            "baseline_cycles": float(outcome.baseline_cycles),
            "best_cycles": float(outcome.best_cycles),
            "improvement_pct": round(
                100.0 * (outcome.baseline_cycles - outcome.best_cycles)
                / outcome.baseline_cycles, 3),
            "measurements": int(spent),
            "seconds": round(seconds, 3),
        })

    # -- phase 1: warm (the roster's "ppo" row + training corpus) ------------
    policy_params: Dict[str, Dict] = {}
    datasets: List[CostDataset] = []
    needs_model = any(s in ("beam-cost", "lookahead") for s in strategies)
    needs_warm = needs_model or "ppo" in strategies \
        or "beam-policy" in strategies
    if needs_warm:
        ppo_cfg = PPOConfig(
            total_timesteps=budget, num_envs=4,
            num_steps=max(8, min(32, budget // 8)),
            episode_length=16, seed=seed)
        for name in kernels:
            backend = FastTimingBackend()
            t0 = time.time()
            outcome = PPOStrategy(ppo_cfg).search(
                programs[name], stall_db=stall_db, backend=backend,
                owner=name, verbose=verbose)
            spent = backend.memo.stats()["misses"]
            if "ppo" in strategies:
                add_row("ppo", name, outcome, spent, time.time() - t0)
            policy_params[name] = outcome.game.params
            datasets.append(CostDataset.from_memo(
                backend.memo, {name: programs[name]}, stall_db=stall_db,
                featurizers={name: featurizers[name]}))
            if verbose:
                print(f"[evaluator] warmed {name}: {spent} measurements, "
                      f"{len(datasets[-1])} dataset rows")
    if extra_memo is not None:
        datasets.append(CostDataset.from_memo(
            extra_memo, programs, stall_db=stall_db,
            featurizers=featurizers))

    # -- phase 2: train the cost model + held-out rank correlation -----------
    dataset = CostDataset.concat(datasets)
    model: Optional[CostModel] = None
    rank_corr = float("nan")
    if needs_model or (len(dataset) >= 2 and needs_warm):
        model, _ = CostModel.fit(dataset, steps=train_steps, seed=seed)
        rank_corr = heldout_rank_correlation(model, dataset)
        if verbose:
            print(f"[evaluator] cost model: {len(dataset)} rows, held-out "
                  f"Spearman {rank_corr:.3f}")

    # -- phase 3: the race ----------------------------------------------------
    # unguided cells go first: greedy's measured spend sizes the guided
    # allowance (spent // 4), so "a quarter of greedy's bill" is exact
    # per kernel rather than a share of the nominal budget
    guided_budget = {k: max(1, budget // GUIDED_BUDGET_DIVISOR)
                     for k in kernels}
    roster = make_roster(strategies, budget, seed, branching, model,
                         policy_params, guided_budget)
    order = sorted(roster, key=lambda s: (s not in UNGUIDED, s != "greedy"))
    for sname in order:
        for kernel in kernels:
            backend = FastTimingBackend()
            strategy = roster[sname](kernel)
            t0 = time.time()
            outcome = strategy.search(programs[kernel], stall_db=stall_db,
                                      backend=backend, owner=kernel,
                                      verbose=verbose)
            spent = backend.memo.stats()["misses"]
            add_row(sname, kernel, outcome, spent, time.time() - t0)
            if sname == "greedy":
                guided_budget[kernel] = max(
                    1, spent // GUIDED_BUDGET_DIVISOR)

    return {"rows": rows, "rank_correlation": rank_corr,
            "budget": int(budget), "dataset_rows": len(dataset),
            "dataset": dataset, "model": model}


def format_table(result: Dict) -> str:
    """The per-kernel comparison table, human-readable."""
    rows = result["rows"]
    header = (f"{'strategy':<14} {'kernel':<18} {'baseline':>9} "
              f"{'best':>9} {'impr%':>7} {'meas':>6} {'sec':>7}")
    lines = [header, "-" * len(header)]
    for r in sorted(rows, key=lambda r: (r["kernel"], r["best_cycles"])):
        lines.append(
            f"{r['strategy']:<14} {r['kernel']:<18} "
            f"{r['baseline_cycles']:>9.0f} {r['best_cycles']:>9.0f} "
            f"{r['improvement_pct']:>7.2f} {r['measurements']:>6d} "
            f"{r['seconds']:>7.2f}")
    rc = result.get("rank_correlation")
    lines.append(f"cost-model held-out Spearman vs oracle: "
                 f"{rc if rc is None else round(rc, 3)} "
                 f"({result['dataset_rows']} corpus rows, "
                 f"budget {result['budget']}/cell)")
    return "\n".join(lines)
