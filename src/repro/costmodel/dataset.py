"""Cost-model dataset: memo measurements -> feature matrices.

The :class:`repro.sched.backends.SharedMeasureMemo` accumulates
(fingerprint, permutation) -> cycles entries for every schedule a campaign
ever measured.  :class:`CostDataset.from_memo` exports that corpus into a
supervised-learning dataset: one row per measured schedule, whose features
are computed by a :class:`ProgramFeaturizer` shared with the search-time
:class:`repro.costmodel.rankers.CostModelRanker` (train/serve skew is a
bug class this sharing rules out).

Feature design (DESIGN.md §2.3 discipline: *program-text information
only* — no machine-side latency tables; the model learns latency
thresholds from measurements):

* **aggregate embedding features** — the kernel-independent fixed-column
  prefix of :func:`repro.core.embedding.embed_program` rows (wait bits,
  barrier indices, yield, stall, is-mem, predication), averaged plain and
  position-weighted (the weighting breaks the permutation invariance of a
  plain mean: two schedules of one kernel are the same multiset of rows);
* **schedule-order features** — stall prefix-sum statistics over the
  semaphore setter->waiter gaps (the scoreboard's wait cost is a function
  of exactly these gaps), register def->use stall shortfalls against the
  microbenchmarked ``analysis.stall_table`` (Algorithm 1's accumulation),
  a reuse-distance histogram over def->use position distances, and
  per-engine-class (DMA in/out, MXU, vector-memory) issue-gap statistics.

Splits are deterministic: each row hashes its (canonical timing records,
permutation) key, so the same schedule always lands on the same side —
across rebuilds, merges and processes — and never leaks from train to
eval.  Datasets serialize to a versioned ``.npz`` next to ``--memo-dir``
payloads; unknown versions fail loudly (:class:`CostModelVersionError`,
mirroring the cache/memo conventions).
"""

from __future__ import annotations

import dataclasses
import hashlib
import zipfile
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import embedding
from repro.core.analysis import Analysis, analyze
from repro.core.isa import NUM_SEMAPHORES, Instruction, is_fixed_latency

# on-disk format for exported datasets (CostDataset.save/load).  Same
# loud-versioning convention as the schedule cache and measurement memo.
DATASET_FORMAT = "repro-cost-dataset"
DATASET_VERSION = 1
_KNOWN_DATASET_VERSIONS = (1,)

# bump when the featurizer's output layout changes: a model trained on
# version-N features must refuse version-M matrices
FEATURE_VERSION = 1


class CostModelVersionError(RuntimeError):
    """A persisted cost-model artifact (dataset ``.npz`` or model ``.npz``)
    is corrupt or from an unknown format version.  Deliberately loud, like
    ``CacheVersionError`` / ``MemoVersionError``."""


_GAP_EDGES = np.array([0.0, 2.0, 4.0, 8.0, 16.0, 32.0, np.inf])
_DIST_EDGES = np.array([1.0, 2.0, 4.0, 8.0, 16.0, 32.0, np.inf])
_CLASSES = ("CPYIN", "CPYOUT", "MXM", "VMEM")

# 2 globals + (plain + position-weighted) embedding-prefix means
# + semaphore-slack block (3 stats + 6-bin hist)
# + dependency block (3 stats + 6-bin reuse-distance hist)
# + 4 engine classes x 4 gap stats
FEATURE_DIM = (2 + 2 * (embedding.FIXED_FEATURES - 1)
               + (3 + len(_GAP_EDGES) - 1)
               + (3 + len(_DIST_EDGES) - 1)
               + 4 * len(_CLASSES))


class ProgramFeaturizer:
    """Schedule-order -> feature-vector map for one instruction list.

    Built once per kernel from the *baseline* program (so instruction
    identities match the game's ``id_at`` encoding); ``features(order)``
    then evaluates any permutation in O(n) numpy.  Shared by dataset
    export and by :class:`repro.costmodel.rankers.CostModelRanker`.
    """

    feature_version = FEATURE_VERSION

    def __init__(self, program: Sequence[Instruction],
                 analysis: Optional[Analysis] = None,
                 stall_db: Optional[Dict[str, int]] = None):
        if analysis is None:
            analysis = analyze(program, stall_db)
        self.analysis = analysis
        self.n = len(program)
        emb = embedding.embed_program(program, analysis)
        # drop the validity column; keep only the kernel-independent prefix
        self._emb = emb[:, 1:embedding.FIXED_FEATURES].astype(np.float64)
        self._stall = np.array([max(1, ins.ctrl.stall) for ins in program],
                               np.float64)

        setters: List[List[int]] = [[] for _ in range(NUM_SEMAPHORES)]
        waiters: List[List[int]] = [[] for _ in range(NUM_SEMAPHORES)]
        for i, ins in enumerate(program):
            for s in (ins.ctrl.read_bar, ins.ctrl.write_bar):
                if s is not None:
                    setters[s].append(i)
            for s in ins.ctrl.wait_mask:
                waiters[s].append(i)
        self._setters = [np.array(s, np.int64) for s in setters]
        self._waiters = [np.array(w, np.int64) for w in waiters]

        # register def->use pairs with their Algorithm-1 minimum stall
        # (stall_table is microbenchmark output — program-side information)
        last_def: Dict[str, int] = {}
        prod: List[int] = []
        cons: List[int] = []
        min_st: List[float] = []
        for i, ins in enumerate(program):
            for reg in ins.uses or ():
                if reg.startswith("UR"):
                    continue
                j = last_def.get(reg)
                if j is None:
                    continue
                p = program[j]
                st = (analysis.stall_table.get(p.opcode, 0)
                      if is_fixed_latency(p.opcode) else 0) or 0
                prod.append(j)
                cons.append(i)
                min_st.append(float(st))
            for reg in ins.defs or ():
                last_def[reg] = i
        self._prod = np.array(prod, np.int64)
        self._cons = np.array(cons, np.int64)
        self._min_st = np.array(min_st, np.float64)

        self._class_ids = {}
        for name in _CLASSES:
            if name == "VMEM":
                ids = [i for i, ins in enumerate(program)
                       if ins.base in ("LDV", "STV")]
            else:
                ids = [i for i, ins in enumerate(program)
                       if ins.base == name]
            self._class_ids[name] = np.array(ids, np.int64)

    @property
    def feature_dim(self) -> int:
        return FEATURE_DIM

    @staticmethod
    def _gap_stats(gaps: np.ndarray, edges: np.ndarray) -> List[float]:
        """[log-count, log-mean, clipped-min] + normalized histogram."""
        nbins = len(edges) - 1
        if gaps.size == 0:
            return [0.0] * (3 + nbins)
        hist, _ = np.histogram(gaps, bins=edges)
        return ([np.log1p(gaps.size), np.log1p(gaps.mean()),
                 min(float(gaps.min()) / 16.0, 4.0)]
                + (hist / gaps.size).tolist())

    def features(self, order: Sequence[int]) -> np.ndarray:
        order = np.asarray(order, dtype=np.int64)
        n = self.n
        pos_of = np.empty(n, np.int64)
        pos_of[order] = np.arange(n)
        st = self._stall[order]
        prefix = np.concatenate(([0.0], np.cumsum(st)))

        feats: List[float] = [np.log1p(n), np.log1p(prefix[-1])]

        emb = self._emb[order]
        weight = (np.arange(n) + 1.0) / n
        feats.extend(emb.mean(axis=0).tolist())
        feats.extend((emb * weight[:, None]).mean(axis=0).tolist())

        # semaphore setter -> waiter stall gaps: for each waiter, the
        # accumulated stall since the latest setter issued before it (the
        # quantity the scoreboard's semaphore waits stall on)
        sem_gaps = []
        for s in range(NUM_SEMAPHORES):
            sp = np.sort(pos_of[self._setters[s]])
            wp = pos_of[self._waiters[s]]
            if sp.size == 0 or wp.size == 0:
                continue
            idx = np.searchsorted(sp, wp, side="left") - 1
            ok = idx >= 0
            if not ok.any():
                continue
            sem_gaps.append(prefix[wp[ok]] - prefix[sp[idx[ok]] + 1])
        g = (np.concatenate(sem_gaps) if sem_gaps
             else np.empty(0, np.float64))
        feats.extend(self._gap_stats(g, _GAP_EDGES))

        # register def->use: Algorithm-1 stall shortfall + reuse distances
        if self._prod.size:
            pp = pos_of[self._prod]
            cp = pos_of[self._cons]
            gap = prefix[cp] - prefix[pp]        # stalls from def to use
            short = np.maximum(0.0, self._min_st - gap)
            dist = np.abs(cp - pp).astype(np.float64)
            feats.append(np.log1p(short.sum()))
            feats.append(float((short > 0).mean()))
            feats.append(np.log1p(gap.mean()))
            hist, _ = np.histogram(dist, bins=_DIST_EDGES)
            feats.extend((hist / dist.size).tolist())
        else:
            feats.extend([0.0] * (3 + len(_DIST_EDGES) - 1))

        # per-engine-class issue gaps (DMA queues, MXU pipe, vector memory)
        for name in _CLASSES:
            ids = self._class_ids[name]
            feats.append(ids.size / n)
            if ids.size >= 2:
                p_sorted = np.sort(pos_of[ids])
                cg = prefix[p_sorted[1:]] - prefix[p_sorted[:-1]]
                feats.append(np.log1p(cg.mean()))
                feats.append(min(float(cg.min()) / 16.0, 4.0))
                feats.append(float((cg <= 2.0).mean()))
            else:
                feats.extend([0.0, 0.0, 0.0])

        out = np.asarray(feats, dtype=np.float32)
        assert out.shape[0] == FEATURE_DIM, out.shape
        return out

    def features_many(self, orders: Sequence[Sequence[int]]) -> np.ndarray:
        if len(orders) == 0:
            return np.empty((0, FEATURE_DIM), np.float32)
        return np.stack([self.features(o) for o in orders])


def _canonical_records(records: tuple) -> tuple:
    """Timing records with set-valued fields sorted — a process-independent
    representation (frozenset iteration order is hash-randomized)."""
    return tuple(
        tuple(tuple(sorted(x)) if isinstance(x, frozenset) else x
              for x in rec)
        for rec in records)


def _split_of(records: tuple, permutation: np.ndarray,
              eval_fraction: float) -> int:
    """Deterministic train(0)/eval(1) assignment for one schedule."""
    h = hashlib.sha256(repr(_canonical_records(records)).encode()
                       + b"|" + permutation.tobytes()).digest()
    frac = int.from_bytes(h[:8], "big") / 2.0 ** 64
    return 1 if frac < eval_fraction else 0


@dataclasses.dataclass
class CostDataset:
    """Feature matrix + log-cycle targets exported from a measurement memo.

    ``group`` carries each row's program fingerprint (the ranking loss
    only compares schedules of the same program); ``split`` is 0 for
    train rows, 1 for held-out eval rows.
    """

    X: np.ndarray                        # (N, FEATURE_DIM) float32
    y: np.ndarray                        # (N,) float32, log(cycles)
    group: np.ndarray                    # (N,) int64 fingerprint ids
    split: np.ndarray                    # (N,) uint8: 0 train / 1 eval
    feature_version: int = FEATURE_VERSION

    def __len__(self) -> int:
        return int(self.X.shape[0])

    @property
    def train(self) -> "CostDataset":
        return self._subset(self.split == 0)

    @property
    def eval(self) -> "CostDataset":
        return self._subset(self.split == 1)

    def _subset(self, mask: np.ndarray) -> "CostDataset":
        return CostDataset(self.X[mask], self.y[mask], self.group[mask],
                           self.split[mask], self.feature_version)

    @classmethod
    def from_memo(cls, memo, programs: Dict[str, Sequence[Instruction]],
                  stall_db: Optional[Dict[str, int]] = None,
                  eval_fraction: float = 0.25,
                  featurizers: Optional[Dict[str, ProgramFeaturizer]] = None
                  ) -> "CostDataset":
        """Export every resident memo entry belonging to one of
        ``programs`` (name -> baseline instruction list) into a dataset.

        Each program is fingerprinted through the memo's interner to join
        against :meth:`SharedMeasureMemo.export_entries`; entries for
        programs not supplied here (other kernels, other autotune configs)
        are skipped, as are evicted entries (absent from the export by
        construction) and non-permutation keys.
        """
        ftz = dict(featurizers or {})
        fp_to_name: Dict[int, str] = {}
        for name, program in programs.items():
            fp_to_name[memo.fingerprint(program)] = name
            if name not in ftz:
                ftz[name] = ProgramFeaturizer(program, stall_db=stall_db)
        rows, ys, groups, splits = [], [], [], []
        for entry in memo.export_entries():
            name = fp_to_name.get(entry.fingerprint)
            if name is None or entry.permutation is None:
                continue
            f = ftz[name]
            if entry.permutation.shape[0] != f.n or entry.cycles <= 0:
                continue
            rows.append(f.features(entry.permutation))
            ys.append(np.log(entry.cycles))
            groups.append(entry.fingerprint)
            splits.append(_split_of(entry.records, entry.permutation,
                                    eval_fraction))
        if not rows:
            return cls(np.empty((0, FEATURE_DIM), np.float32),
                       np.empty(0, np.float32), np.empty(0, np.int64),
                       np.empty(0, np.uint8))
        return cls(np.stack(rows),
                   np.asarray(ys, np.float32),
                   np.asarray(groups, np.int64),
                   np.asarray(splits, np.uint8))

    @classmethod
    def concat(cls, datasets: Sequence["CostDataset"]) -> "CostDataset":
        """Concatenate datasets built from *different* memos: fingerprint
        ids are process-local per memo, so each dataset's groups are
        offset into a disjoint range before stacking."""
        datasets = [d for d in datasets if len(d)]
        if not datasets:
            return cls(np.empty((0, FEATURE_DIM), np.float32),
                       np.empty(0, np.float32), np.empty(0, np.int64),
                       np.empty(0, np.uint8))
        versions = {d.feature_version for d in datasets}
        if len(versions) > 1:
            raise CostModelVersionError(
                f"cannot concat datasets of feature versions {versions}")
        groups, offset = [], 0
        for d in datasets:
            groups.append(d.group + offset)
            offset += int(d.group.max()) + 1
        return cls(np.concatenate([d.X for d in datasets]),
                   np.concatenate([d.y for d in datasets]),
                   np.concatenate(groups),
                   np.concatenate([d.split for d in datasets]),
                   datasets[0].feature_version)

    # -- persistence ---------------------------------------------------------

    def save(self, path: str) -> int:
        """Write the dataset as a versioned ``.npz``; returns row count."""
        np.savez(path, format=DATASET_FORMAT, version=DATASET_VERSION,
                 feature_version=self.feature_version,
                 X=self.X, y=self.y, group=self.group, split=self.split)
        return len(self)

    @classmethod
    def load(cls, path: str) -> "CostDataset":
        """Load a dataset ``.npz``; raises :class:`CostModelVersionError`
        on corrupt or unknown-version files."""
        try:
            with np.load(path, allow_pickle=False) as z:
                if "format" not in z.files \
                        or str(z["format"]) != DATASET_FORMAT:
                    raise CostModelVersionError(
                        f"{path} is not a {DATASET_FORMAT} file")
                version = int(z["version"])
                if version not in _KNOWN_DATASET_VERSIONS:
                    raise CostModelVersionError(
                        f"dataset {path} has version {version!r}; this "
                        f"build reads {_KNOWN_DATASET_VERSIONS}")
                return cls(z["X"], z["y"], z["group"], z["split"],
                           int(z["feature_version"]))
        except (OSError, ValueError, KeyError, zipfile.BadZipFile) as e:
            raise CostModelVersionError(
                f"corrupt cost dataset {path}: {e}") from e
