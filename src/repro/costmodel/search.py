"""Model-guided search strategies: beam search and greedy lookahead.

Both strategies explore the same masked-swap neighborhood as the PPO game
(children are single legal adjacent swaps, so every reached schedule is
reachable by masked swaps and therefore semantics-preserving), but rank
candidates through a :class:`~repro.costmodel.rankers.CostRanker` and
route only the **top-k** through the session's real
:class:`~repro.sched.backends.MeasureBackend` — the measurement path
(``ResilientBackend`` wrapping, shared-memo accounting,
``use_fast_measure`` fallback) composes unchanged because all measuring
still happens inside one :class:`~repro.core.env.AssemblyGame` built
exactly like the other strategies build theirs.

The verified-cycles contract: ``SearchOutcome.best_cycles`` always comes
from a real measurement (``env.measure_schedule``), never from a model
prediction — an unverified candidate can win the *beam*, but it cannot
win the *search* without being measured.

``max_measurements`` bounds real measurements (memo misses / oracle runs)
spent by one search, so an evaluator can hand every strategy the same
budget and compare what each buys with it.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.costmodel.rankers import make_ranker
from repro.sched.session import SearchOutcome, _strategy_env


def _spent(env) -> int:
    """Real measurements this env has paid for: fast-path memo misses plus
    oracle-path runs (oracle measurements never touch the memo counters)."""
    return env.measure_calls - env.memo_hits


def _expand(env, order: np.ndarray, seen: set) -> List[np.ndarray]:
    """All unseen single-masked-swap children of ``order``."""
    env.set_order(order)
    children = []
    for a in env.valid_actions():
        q = env.action_swap_pos(a)
        child = order.copy()
        child[q - 1], child[q] = child[q], child[q - 1]
        key = child.tobytes()
        if key not in seen:
            seen.add(key)
            children.append(child)
    return children


class BeamSearchStrategy:
    """Breadth-limited search over masked-swap space, ranked by a cost
    ranker: each depth expands every beam member's legal swaps, keeps the
    ``width`` best-scored candidates, and verifies the top
    ``verify_top_k`` on the real timer.  With ``ranker="oracle"`` every
    candidate is measured (classic beam search); with ``"cost"`` /
    ``"policy"`` thousands of candidates rank for the price of ``k``
    measurements per depth."""

    def __init__(self, width: int = 8, depth: int = 16,
                 verify_top_k: int = 2, ranker: str = "oracle",
                 model=None, policy_params: Optional[Dict] = None,
                 max_measurements: Optional[int] = None):
        self.width = int(width)
        self.depth = int(depth)
        self.verify_top_k = int(verify_top_k)
        self.ranker = ranker
        self.model = model
        self.policy_params = policy_params
        self.max_measurements = max_measurements
        self.name = f"beam-{ranker}"

    def search(self, program, *, stall_db, backend, owner="", verbose=False):
        env = _strategy_env(program, stall_db, backend, owner,
                            episode_length=self.depth + 1)
        ranker = make_ranker(self.ranker, env, model=self.model,
                             policy_params=self.policy_params,
                             max_measurements=self.max_measurements)
        budget = self.max_measurements
        root = env.id_at.copy()
        beam: List[np.ndarray] = [root]
        best_order = root
        seen = {root.tobytes()}
        stats: List[Dict] = []
        for d in range(self.depth):
            if budget is not None and _spent(env) >= budget:
                break
            candidates: List[np.ndarray] = []
            for order in beam:
                candidates.extend(_expand(env, order, seen))
            if not candidates:
                break
            scores = ranker.scores(candidates)
            rank_idx = np.argsort(scores, kind="stable")
            improved = False
            if ranker.verified:
                # scores ARE measurements; env.best_* already tracked them
                # (a budget-capped oracle leaves inf for the unmeasured)
                i = int(rank_idx[0])
                if scores[i] <= env.best_cycles:
                    best_order = candidates[i]
                    improved = True
                measured = int(np.isfinite(scores).sum())
                beam = [candidates[int(i)] for i in rank_idx[:self.width]]
                if not any(np.array_equal(best_order, b) for b in beam):
                    beam.append(best_order)
            else:
                # verify in predicted order.  At least ``verify_top_k``
                # measurements (near-tie predictions need a real
                # comparison), escalating past k until one *improves* the
                # verified incumbent — misranked 1-cycle ties are exactly
                # where a fixed top-k verifies the wrong candidate and
                # drifts.
                measured = 0
                for i in rank_idx:
                    if budget is not None and _spent(env) >= budget:
                        break
                    if measured >= self.verify_top_k and improved:
                        break
                    prev_best = env.best_cycles
                    env.set_order(candidates[int(i)])
                    cycles = env.measure_schedule()
                    measured += 1
                    if cycles < prev_best:
                        best_order = candidates[int(i)]
                        improved = True
                # the verified incumbent anchors the beam (predictions
                # steer exploration, measurements steer the walk); the
                # remaining width-1 slots go to the best-scored candidates
                beam = [best_order]
                for i in rank_idx[:self.width - 1]:
                    c = candidates[int(i)]
                    if not np.array_equal(c, best_order):
                        beam.append(c)
            stats.append({"depth": d, "candidates": len(candidates),
                          "best_cycles": env.best_cycles,
                          "measurements": _spent(env),
                          "time": time.time()})
            if verbose:
                print(f"[{self.name}] depth={d} "
                      f"candidates={len(candidates)} "
                      f"best={env.best_cycles:.0f} spent={_spent(env)}")
            if measured >= len(candidates) and not improved:
                # a full verified sweep of the frontier found nothing
                # better: converged to a measured local optimum (the
                # greedy stopping rule, reached at a fraction of its bill)
                break
        return SearchOutcome(
            best_program=[ins.copy() for ins in env.best_program],
            best_cycles=env.best_cycles, baseline_cycles=env.t0,
            stats=stats)


class GreedyLookaheadStrategy:
    """Greedy descent with model-guided lookahead: from the current
    schedule, every legal swap is scored by the best ranker score found
    along a ``lookahead``-deep ranker-greedy rollout from it, the top
    ``verify_top_k`` children are verified for real, and the walk moves
    to the best-scored child.  A one-swap trap (a swap that scores worse
    now but enables a better schedule two swaps later) is exactly what
    the lookahead sees past and plain greedy does not."""

    def __init__(self, lookahead: int = 4, verify_top_k: int = 2,
                 max_steps: int = 32, ranker: str = "cost",
                 model=None, policy_params: Optional[Dict] = None,
                 max_measurements: Optional[int] = None):
        self.lookahead = int(lookahead)
        self.verify_top_k = int(verify_top_k)
        self.max_steps = int(max_steps)
        self.ranker = ranker
        self.model = model
        self.policy_params = policy_params
        self.max_measurements = max_measurements
        self.name = f"lookahead-{ranker}" if ranker != "cost" else "lookahead"

    def search(self, program, *, stall_db, backend, owner="", verbose=False):
        env = _strategy_env(program, stall_db, backend, owner,
                            episode_length=self.max_steps + 1)
        ranker = make_ranker(self.ranker, env, model=self.model,
                             policy_params=self.policy_params,
                             max_measurements=self.max_measurements)
        budget = self.max_measurements
        current = env.id_at.copy()
        seen = {current.tobytes()}
        stats: List[Dict] = []
        for step in range(self.max_steps):
            if budget is not None and _spent(env) >= budget:
                break
            children = _expand(env, current, seen)
            if not children:
                break
            child_scores = ranker.scores(children)
            # rollout: follow the ranker greedily for lookahead - 1 more
            # swaps; a child is as good as the best score on its path
            rollout_seen = set(seen)
            for ci, child in enumerate(children):
                order, best_s = child, child_scores[ci]
                for _ in range(self.lookahead - 1):
                    nxt = _expand(env, order, rollout_seen)
                    if not nxt:
                        break
                    s = ranker.scores(nxt)
                    j = int(np.argmin(s))
                    best_s = min(best_s, s[j])
                    order = nxt[j]
                child_scores[ci] = best_s
            rank_idx = np.argsort(child_scores, kind="stable")
            if not ranker.verified:
                for i in rank_idx[:self.verify_top_k]:
                    if budget is not None and _spent(env) >= budget:
                        break
                    env.set_order(children[int(i)])
                    env.measure_schedule()
            current = children[int(rank_idx[0])]
            stats.append({"step": step, "candidates": len(children),
                          "best_cycles": env.best_cycles,
                          "measurements": _spent(env),
                          "time": time.time()})
            if verbose:
                print(f"[{self.name}] step={step} "
                      f"candidates={len(children)} "
                      f"best={env.best_cycles:.0f} spent={_spent(env)}")
        return SearchOutcome(
            best_program=[ins.copy() for ins in env.best_program],
            best_cycles=env.best_cycles, baseline_cycles=env.t0,
            stats=stats)
