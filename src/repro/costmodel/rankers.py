"""Candidate rankers for model-guided search (:mod:`repro.costmodel.search`).

A :class:`CostRanker` scores candidate schedules — **lower is better** —
so a search strategy can triage thousands of candidates and route only
the top-k through the real measurement backend:

* :class:`OracleRanker` — scores *are* real measurements (every candidate
  goes through the game's timer + memo path).  ``verified = True``: the
  strategy may trust the scores as cycles.
* :class:`CostModelRanker` — predicted log-cycles from a trained
  :class:`~repro.costmodel.model.CostModel` through the same
  :class:`~repro.costmodel.dataset.ProgramFeaturizer` used at training
  time.  Predictions; never reported as cycles.
* :class:`PolicyRanker` — the PPO agent's value head
  (:func:`repro.core.ppo.bootstrap_value`) over the schedule's embedding
  matrix: states the critic expects more future cycle reduction from
  score better.  Ranks *promise*, not absolute cycles.
"""

from __future__ import annotations

from typing import Dict, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core import embedding
from repro.costmodel.dataset import ProgramFeaturizer
from repro.costmodel.model import CostModel

# PolicyRanker pads candidate batches to a multiple of this so the jitted
# critic forward compiles for one shape instead of one per candidate count
_VALUE_BATCH = 64


@runtime_checkable
class CostRanker(Protocol):
    name: str
    verified: bool        # True iff scores are real measured cycles

    def scores(self, orders: Sequence[np.ndarray]) -> np.ndarray:
        """One score per candidate order; lower is better."""
        ...


class OracleRanker:
    """Every candidate measured for real through the game's measurement
    path (timer + shared memo, or the dataflow oracle) — the exhaustive
    reference the learned rankers are judged against.

    ``max_measurements`` stops mid-batch once the env's real-measurement
    spend reaches the cap; unmeasured candidates score ``inf`` so they
    rank last without pretending to be cycles.
    """

    name = "oracle"
    verified = True

    def __init__(self, env, max_measurements: Optional[int] = None):
        self._env = env
        self._budget = max_measurements

    def scores(self, orders: Sequence[np.ndarray]) -> np.ndarray:
        out = np.full(len(orders), np.inf, np.float64)
        env = self._env
        for i, order in enumerate(orders):
            if self._budget is not None and \
                    env.measure_calls - env.memo_hits >= self._budget:
                break
            env.set_order(order)
            out[i] = env.measure_schedule()
        return out


class CostModelRanker:
    """Predicted log-cycles from the trained MLP (monotonic in predicted
    cycles, so ranking is identical and the exp is skipped)."""

    name = "cost"
    verified = False

    def __init__(self, model: CostModel, featurizer: ProgramFeaturizer):
        if model.feature_version != featurizer.feature_version:
            raise ValueError(
                f"cost model trained on feature version "
                f"{model.feature_version}, featurizer computes "
                f"{featurizer.feature_version}")
        self._model = model
        self._featurizer = featurizer

    def scores(self, orders: Sequence[np.ndarray]) -> np.ndarray:
        X = self._featurizer.features_many(orders)
        return np.asarray(self._model.predict_log(X), np.float64)

    def predicted_cycles(self, orders: Sequence[np.ndarray]) -> np.ndarray:
        return np.exp(self.scores(orders))


class PolicyRanker:
    """PPO value head as a ranker: score = -V(s).  ``emb`` is the
    baseline program's embedding matrix (rows indexed by identity, the
    same ``embed_program`` output the game observes)."""

    name = "policy"
    verified = False

    def __init__(self, params: Dict, emb: np.ndarray):
        self._params = params
        self._emb = np.asarray(emb, np.float32)

    @classmethod
    def from_game(cls, params: Dict, program, analysis) -> "PolicyRanker":
        return cls(params, embedding.embed_program(program, analysis))

    def scores(self, orders: Sequence[np.ndarray]) -> np.ndarray:
        from repro.core.ppo import bootstrap_value
        states = np.stack([self._emb[np.asarray(o, np.int64)]
                           for o in orders])
        n = states.shape[0]
        pad = (-n) % _VALUE_BATCH
        if pad:
            states = np.concatenate(
                [states, np.repeat(states[-1:], pad, axis=0)])
        values = []
        for i in range(0, states.shape[0], _VALUE_BATCH):
            values.append(np.asarray(
                bootstrap_value(self._params, states[i:i + _VALUE_BATCH])))
        return -np.concatenate(values)[:n].astype(np.float64)


def make_ranker(name: str, env, *, model: Optional[CostModel] = None,
                featurizer: Optional[ProgramFeaturizer] = None,
                policy_params: Optional[Dict] = None,
                max_measurements: Optional[int] = None) -> CostRanker:
    """Ranker factory the search strategies call at search time (rankers
    need the live env / featurizer, which only exist once ``search``
    runs)."""
    if name == "oracle":
        return OracleRanker(env, max_measurements=max_measurements)
    if name == "cost":
        if model is None:
            raise ValueError(
                "ranker='cost' needs a trained CostModel (train one via "
                "CostModel.fit on a CostDataset, or let the evaluator "
                "harness train it from a warmed memo)")
        if featurizer is None:
            featurizer = ProgramFeaturizer(env.original,
                                           analysis=env.analysis)
        return CostModelRanker(model, featurizer)
    if name == "policy":
        if policy_params is None:
            raise ValueError(
                "ranker='policy' needs PPO agent params (GameResult.params "
                "from a prior PPOStrategy run on this kernel)")
        return PolicyRanker(policy_params,
                            embedding.embed_program(env.original,
                                                    env.analysis))
    raise KeyError(f"unknown ranker {name!r}; one of "
                   "['oracle', 'cost', 'policy']")
