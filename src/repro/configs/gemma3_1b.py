"""gemma3-1b [dense] — 26L, d_model=1152, 4 heads GQA kv=1, d_ff=6912,
vocab=262144, 5:1 local:global sliding-window attention (window=512, every
6th layer global), 128k+ context.  long_500k RUNS: 25/30 of layers have a
bounded 512-token cache; the kv=1 global layers hold the long cache,
sharded by sequence over the model axis."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab=262144,
    window=512,
    global_every=6,           # layers 5, 11, 17, 23 are global
    rope_theta=1_000_000.0,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

REDUCED = CONFIG.replace(
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab=256, window=8, global_every=3, attn_chunk=32,
    dtype="float32", remat=False)
