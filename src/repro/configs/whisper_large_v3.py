"""whisper-large-v3 [audio] — encoder-decoder transformer backbone
(arXiv:2212.04356).  32 enc + 32 dec layers, d_model=1280, 20 heads
(kv=20), d_ff=5120, vocab=51866.  The conv/mel frontend is a STUB per the
brief: input_specs() provides precomputed frame embeddings.  Decode shapes
lower the decoder serve_step with cross-attention over stubbed encoder
states (ENC_LEN_DECODE frames).  long_500k skipped: dense full attention."""

from repro.configs.base import ModelConfig

ENC_LEN_DECODE = 1536  # encoder frames available to the decoder at decode

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=64,
    enc_layers=32,
    dec_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    norm="layernorm",
    mlp="gelu",
    qkv_bias=True,
    tie_embeddings=True,
    frontend="audio_frames",
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
    shape_skips={"long_500k": "dense full attention; 500k KV cache is the "
                              "textbook sub-quadratic-only cell"},
)

REDUCED = CONFIG.replace(
    n_layers=4, enc_layers=2, dec_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=128, vocab=128, attn_chunk=32,
    dtype="float32", remat=False)
