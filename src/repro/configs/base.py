"""Model / shape configuration schema for the assigned architectures.

Every architecture file instantiates one :class:`ModelConfig` with its
published numbers plus a ``reduced()`` smoke variant (same family, tiny
dims) that runs a real forward/train step on CPU.  The full configs are
exercised only through the 512-device dry-run (ShapeDtypeStruct, no
allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

# The four assigned input shapes (seq_len, global_batch, kind)
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


@dataclasses.dataclass
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    window: Optional[int] = None       # sliding-window size for local layers
    global_every: int = 0              # every Nth layer is global (gemma 5:1 -> 6)

    # norm / mlp flavour
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    mlp: str = "swiglu"                # swiglu | gelu
    tie_embeddings: bool = True

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                  # per-expert hidden dim
    first_dense_layers: int = 0        # DeepSeek: leading dense FFN layers
    capacity_factor: float = 1.25

    # MLA (DeepSeek)
    mla: bool = False
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_p: int = 64
    ssm_groups: int = 1

    # hybrid (zamba2): one shared attention block applied every k layers
    shared_attn_every: int = 0

    # encoder-decoder (whisper)
    enc_layers: int = 0
    dec_layers: int = 0

    # modality frontend stub: None | "audio_frames" | "vq_tokens"
    frontend: Optional[str] = None

    # execution
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"   # nothing | dots (save matmul outputs)
    train_microbatches: int = 1     # gradient-accumulation chunks
    attn_chunk: int = 2048
    seq_parallel: bool = False   # constrain inter-block activations to be
                                 # sequence-sharded over the model axis (SP)

    # distributed train step (train.step.make_sharded_train_step):
    # pipeline_stages > 1 opts the config into the shard_map pipeline step —
    # launchers size the mesh's `pipe` axis from it; pipeline_microbatches
    # is the microbatch stream M (bubble fraction (S-1)/(M+S-1));
    # pipeline_schedule picks the micro-op timetable (dist.pipeline
    # SCHEDULES): "gpipe" holds all M microbatch activations live per
    # stage, "1f1b" bounds them at min(S, M) in the schedule's accounting
    # model (what a runtime that retires activations at each backward
    # micro-op realizes — see dist.pipeline); compress_pod_grads routes
    # the multi-pod gradient reduction through
    # dist.compress.compressed_psum (bf16 wire format + error feedback)
    # instead of a plain fp32 psum, and overlap_pod_reduce issues it
    # per gradient group as the stage grads finalize during the backward
    # drain (joined at the optimizer update) instead of monolithically.
    pipeline_stages: int = 0
    pipeline_microbatches: int = 4
    pipeline_schedule: str = "gpipe"
    compress_pod_grads: bool = True
    overlap_pod_reduce: bool = True
    supported_shapes: Tuple[str, ...] = ("train_4k", "prefill_32k",
                                         "decode_32k")
    shape_skips: Dict[str, str] = dataclasses.field(default_factory=dict)

    # ---- derived -----------------------------------------------------------

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_p

    def n_params(self) -> int:
        """Total parameter estimate (for 6·N·D roofline bookkeeping)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d
        if self.family == "encdec":
            per = 4 * d * self.n_heads * self.head_dim + 2 * d * self.d_ff
            enc = self.enc_layers * per
            dec = self.dec_layers * (per + 4 * d * self.n_heads * self.head_dim)
            return emb + enc + dec
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
            + self.n_heads * self.head_dim * d
        if self.mla:
            attn = (d * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.n_heads
                    * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d)
        if self.family == "ssm":
            per = (d * (2 * self.d_inner + 2 * self.ssm_groups * self.ssm_state
                        + self.ssm_heads)
                   + self.d_inner * d)
            return emb + L * per
        mlp = 3 * d * self.d_ff if self.mlp == "swiglu" else 2 * d * self.d_ff
        if self.n_experts:
            moe = self.n_experts * 3 * d * self.moe_d_ff \
                + self.n_shared_experts * 3 * d * (self.moe_d_ff *
                                                   max(self.n_shared_experts, 1))
            n_moe_layers = L - self.first_dense_layers
            return emb + L * attn + self.first_dense_layers * mlp \
                + n_moe_layers * moe
        if self.family == "hybrid":
            ssm_per = (d * (2 * self.d_inner + 2 * self.ssm_groups
                            * self.ssm_state + self.ssm_heads)
                       + self.d_inner * d)
            shared = attn + mlp
            return emb + L * (ssm_per + mlp) + shared
        return emb + L * (attn + mlp)

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top-k experts count)."""
        if not self.n_experts:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
            + self.n_heads * self.head_dim * d
        if self.mla:
            attn = (d * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.n_heads
                    * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d)
        mlp = 3 * d * self.d_ff
        active_moe = (self.top_k + self.n_shared_experts) * 3 * d * self.moe_d_ff
        n_moe = L - self.first_dense_layers
        return emb + L * attn + self.first_dense_layers * mlp + n_moe * active_moe

    def shape(self, shape_name: str) -> Tuple[int, int, str]:
        return SHAPES[shape_name]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
