"""stablelm-3b [dense] — 32L, d_model=2560, 32 heads (MHA), d_ff=6912,
vocab=50304.  long_500k skipped: dense full attention."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab=50304,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
    shape_skips={"long_500k": "dense full attention"},
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256, attn_chunk=32, dtype="float32", remat=False)
