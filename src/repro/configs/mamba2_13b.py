"""mamba2-1.3b [ssm] — attention-free SSD LM (arXiv:2405.21060).
48L, d_model=2048 (d_inner=4096, 64 heads of P=64), ssm_state=128,
vocab=50280.  long_500k RUNS: O(1) recurrent state per layer."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,          # attention-free; SSD heads derived below
    n_kv_heads=1,
    head_dim=1,
    d_ff=0,             # no MLP: pure Mamba2 stack
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_p=64,
    ssm_groups=1,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, vocab=256, ssm_state=16, ssm_head_p=16,
    dtype="float32", remat=False)
