"""chameleon-34b [vlm] — early-fusion token-based VLM backbone
(arXiv:2405.09818).  48L, d_model=8192, 64 heads GQA kv=8, d_ff=22016,
unified vocab=65536 (text + VQ image tokens), qk-norm.  The VQ image
tokenizer frontend is a STUB per the brief: input_specs() provides token
ids drawn from the unified vocabulary.  long_500k skipped: dense full
attention."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="dense",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab=65536,
    qk_norm=True,
    frontend="vq_tokens",
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
    shape_skips={"long_500k": "dense full attention"},
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, attn_chunk=32, dtype="float32", remat=False)
