"""qwen1.5-4b [dense] — 40L, d_model=2560, 20 heads (MHA), d_ff=6912,
vocab=151936, QKV bias.  long_500k skipped: dense full attention."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab=151936,
    qkv_bias=True,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
    shape_skips={"long_500k": "dense full attention"},
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256, attn_chunk=32, dtype="float32", remat=False)
