"""deepseek-v2-lite-16b [moe] — MLA + fine-grained MoE (arXiv:2405.04434).
27L, d_model=2048, 16 heads, MLA kv_lora_rank=512, MoE 64 routed top-6 + 2
shared experts (expert d_ff=1408), first layer dense (d_ff=10944),
vocab=102400.  long_500k skipped: dense full attention."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,          # v_head_dim; qk dims below
    d_ff=10944,            # the leading dense layer
    vocab=102400,
    mla=True,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    first_dense_layers=1,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
    shape_skips={"long_500k": "dense full attention (MLA compresses the "
                              "cache but per-step attention is still over "
                              "the full 500k latent sequence)"},
)

REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=192, vocab=256, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16, n_experts=8, top_k=2, n_shared_experts=1, moe_d_ff=48,
    first_dense_layers=1, attn_chunk=32, dtype="float32", remat=False)
