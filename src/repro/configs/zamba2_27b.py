"""zamba2-2.7b [hybrid] — Mamba2 backbone with a shared attention block
(arXiv:2411.15242).  54 Mamba2 layers (d_model=2560, ssm_state=64) with one
shared attention+MLP block (32 heads, d_ff=10240) applied every 6 layers.
long_500k RUNS: SSM state is O(1); the shared attention block's cache is
small (9 applications) and per-step attention is linear."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_p=64,
    ssm_groups=2,
    shared_attn_every=6,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

REDUCED = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256, ssm_state=16, ssm_head_p=16, ssm_groups=1,
    shared_attn_every=2, attn_chunk=32, dtype="float32", remat=False)
