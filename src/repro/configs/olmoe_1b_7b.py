"""olmoe-1b-7b [moe] — fully sparse MoE LM (arXiv:2409.02060).
16L, d_model=2048, 16 heads, 64 experts top-8 (expert d_ff=1024),
vocab=50304.  long_500k skipped: dense full attention."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab=50304,
    qk_norm=True,
    n_experts=64,
    top_k=8,
    moe_d_ff=1024,
    capacity_factor=1.25,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
    shape_skips={"long_500k": "dense full attention"},
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=48, vocab=256, n_experts=8, top_k=2, moe_d_ff=48,
    attn_chunk=32, dtype="float32", remat=False)
