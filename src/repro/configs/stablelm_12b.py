"""stablelm-12b [dense] — 40L, d_model=5120, 32 heads GQA kv=8,
d_ff=13824, vocab=100352.  long_500k skipped: dense full attention."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab=100352,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
    shape_skips={"long_500k": "dense full attention"},
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, attn_chunk=32, dtype="float32", remat=False)
