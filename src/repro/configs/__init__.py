"""Registry of the 10 assigned architectures (``--arch <id>``)."""

from repro.configs import (chameleon_34b, deepseek_v2_lite_16b, gemma3_1b,
                           mamba2_13b, olmoe_1b_7b, qwen15_4b, stablelm_12b,
                           stablelm_3b, whisper_large_v3, zamba2_27b)
from repro.configs.base import SHAPES, ModelConfig

_MODULES = {
    "whisper-large-v3": whisper_large_v3,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "stablelm-3b": stablelm_3b,
    "qwen1.5-4b": qwen15_4b,
    "stablelm-12b": stablelm_12b,
    "gemma3-1b": gemma3_1b,
    "mamba2-1.3b": mamba2_13b,
    "chameleon-34b": chameleon_34b,
    "zamba2-2.7b": zamba2_27b,
}

ARCHS = tuple(_MODULES)


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCHS}")
    mod = _MODULES[name]
    return mod.REDUCED if reduced else mod.CONFIG


def all_configs(reduced: bool = False):
    return {name: get_config(name, reduced) for name in ARCHS}
