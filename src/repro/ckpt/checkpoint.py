"""Sharded, atomic, async-capable checkpointing (no orbax in-container).

Layout:  <dir>/step_<k>/
            manifest.json      — tree structure, shapes, dtypes, step
            shard_<i>.npz      — flattened leaves (chunked)
         <dir>/LATEST          — committed pointer (atomic rename)

Guarantees:
  * step-atomic: the LATEST pointer is renamed only after every shard and
    the manifest are fully on disk — a crash mid-write never corrupts the
    restore path (fault-tolerance tests kill mid-run and restart);
  * elastic: restore() rebuilds leaves host-side and re-shards onto
    whatever mesh the restoring job runs (device counts may differ);
  * async: save() can run on a background thread (returns a handle), the
    training loop overlaps the next steps with the write.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Dict, Optional, Tuple

import jax
import numpy as np

_LEAVES_PER_SHARD = 64


def _tree_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save(directory: str, step: int, tree) -> str:
    """Blocking sharded save + atomic commit; returns the step dir."""
    step_dir = os.path.join(directory, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"
    if os.path.exists(tmp_dir):
        shutil.rmtree(tmp_dir)
    os.makedirs(tmp_dir, exist_ok=True)

    flat, treedef = _tree_paths(tree)
    host = [np.asarray(x) for x in flat]
    manifest = {
        "step": step,
        "n_leaves": len(host),
        "shards": [],
        "dtypes": [str(x.dtype) for x in host],
        "shapes": [list(x.shape) for x in host],
    }
    # npz cannot represent extension dtypes (bfloat16 etc.): store raw bytes
    # as uint8; restore() views them back per the manifest dtype
    host = [x if x.dtype.kind in "fiub" and str(x.dtype) != "bfloat16"
            else np.ascontiguousarray(x).view(np.uint8) for x in host]
    for si in range(0, len(host), _LEAVES_PER_SHARD):
        chunk = host[si: si + _LEAVES_PER_SHARD]
        name = f"shard_{si // _LEAVES_PER_SHARD:05d}.npz"
        np.savez(os.path.join(tmp_dir, name),
                 **{f"leaf_{si + j}": c for j, c in enumerate(chunk)})
        manifest["shards"].append(name)
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)

    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.replace(tmp_dir, step_dir)  # atomic on POSIX

    # commit the LATEST pointer last
    fd, tmp = tempfile.mkstemp(dir=directory)
    with os.fdopen(fd, "w") as f:
        f.write(os.path.basename(step_dir))
    os.replace(tmp, os.path.join(directory, "LATEST"))
    return step_dir


class AsyncCheckpointer:
    """One in-flight save at a time; wait() joins the previous write."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None

    def save(self, directory: str, step: int, tree) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async
        self._thread = threading.Thread(
            target=save, args=(directory, step, host_tree), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> Optional[int]:
    pointer = os.path.join(directory, "LATEST")
    if not os.path.exists(pointer):
        return None
    with open(pointer) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(directory, name)):
        return None
    return int(name.split("_")[-1])


def restore(directory: str, like_tree, step: Optional[int] = None,
            shardings=None) -> Tuple[object, int]:
    """Restore into the structure of ``like_tree``; re-shard if
    ``shardings`` (a matching tree of NamedSharding) is given — this is the
    elastic-resize path (device count may differ from the saving job)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    step_dir = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    leaves: Dict[int, np.ndarray] = {}
    for name in manifest["shards"]:
        with np.load(os.path.join(step_dir, name)) as z:
            for key in z.files:
                leaves[int(key.split("_")[1])] = z[key]
    flat = []
    for i in range(manifest["n_leaves"]):
        arr = leaves[i]
        want_dtype = np.dtype(manifest["dtypes"][i])
        want_shape = tuple(manifest["shapes"][i])
        if arr.dtype != want_dtype:
            arr = arr.view(want_dtype).reshape(want_shape)
        flat.append(arr)
    _, treedef = jax.tree.flatten(like_tree)
    tree = jax.tree.unflatten(treedef, flat)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, step
