"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape) cell.

``lowerable(cfg, shape_name, mesh)`` returns (fn, args_sds) such that
``jax.jit(fn, in_shardings=...).lower(*args_sds)`` is exactly the cell the
dry-run and roofline analysis evaluate — no device allocation anywhere.

Kinds:
  train_4k     -> train_step(state, batch)
  prefill_32k  -> prefill(params, inputs) -> logits
  decode_32k / long_500k -> serve_step(params, caches, token, pos)
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig
from repro.dist import sharding as shd
from repro.sched.scenario import Scenario
from repro.models import encdec, lm
from repro.optim import adamw as adamw_fn, constant_schedule
from repro.serve import decode as serve_decode
from repro.train.step import (PipelineStepError, TrainState,
                              make_sharded_train_step, make_train_step,
                              wants_ef)


def _sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _with_sharding(tree_sds, tree_sharding):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_sds, tree_sharding)


def params_sds(cfg: ModelConfig, mesh) -> Tuple[Dict, Dict]:
    """(ShapeDtypeStruct tree, NamedSharding tree) for the parameters."""
    model = encdec if cfg.family == "encdec" else lm
    sds = jax.eval_shape(functools.partial(model.init_model, cfg),
                         jax.random.PRNGKey(0))
    spec_tree = model.model_spec(cfg)
    shardings = shd.param_shardings(spec_tree, mesh)
    return _with_sharding(sds, shardings), shardings


def _batch_sds(cfg: ModelConfig, mesh, seq: int, batch: int,
               with_labels: bool = True) -> Dict:
    bspec = shd.batch_spec(mesh, batch)
    out = {"tokens": _sds((batch, seq), jnp.int32, mesh, bspec)}
    if with_labels:
        out["labels"] = _sds((batch, seq), jnp.int32, mesh, bspec)
    if cfg.frontend == "audio_frames":
        out["frames"] = _sds((batch, seq, cfg.d_model), jnp.bfloat16, mesh,
                             shd.batch_spec(mesh, batch, ndim=3))
    return out


def sharded_train_lowerable(cfg: ModelConfig, mesh, *, seq: int,
                            batch: int, num_microbatches: int = None):
    """(fn, args_sds) for the shard_map pipeline train step on ``mesh`` —
    the ``pipe``-axis analogue of the ``train`` branch of :func:`lowerable`
    (requires ``pipe >= 2``; a ``model`` axis > 1 composes tensor
    parallelism into the stage bodies — see
    ``train.step.make_sharded_train_step`` for the constraints)."""
    step_fn = make_sharded_train_step(cfg, _lower_opt(), mesh,
                                      num_microbatches=num_microbatches)
    spec_tree = lm.model_spec(cfg)
    p_sds = jax.eval_shape(functools.partial(lm.init_model, cfg),
                           jax.random.PRNGKey(0))
    p_specs = shd.sharded_param_specs(spec_tree, mesh=mesh)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)
    p_sds = _with_sharding(p_sds, p_sh)
    opt_sds = jax.eval_shape(_lower_opt().init, p_sds)
    opt_sds = type(opt_sds)(
        step=_sds((), jnp.int32, mesh, P()),
        mu=jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=sh), opt_sds.mu, p_sh),
        nu=jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=sh), opt_sds.nu, p_sh))
    ef_sds = None
    if wants_ef(cfg, mesh):
        pod = shd.axis_sizes(mesh).get("pod", 1)
        ef_specs = shd.sharded_ef_specs(spec_tree, mesh=mesh)
        ef_sds = jax.tree.map(
            lambda s, sp: _sds((pod,) + s.shape, jnp.float32, mesh, sp),
            p_sds, ef_specs)
    state_sds = TrainState(params=p_sds, opt_state=opt_sds,
                           step=_sds((), jnp.int32, mesh, P()),
                           ef=ef_sds)
    bspec = P(shd.dp_axes(mesh))
    batch_sds = {"tokens": _sds((batch, seq), jnp.int32, mesh, bspec),
                 "labels": _sds((batch, seq), jnp.int32, mesh, bspec)}
    return step_fn, (state_sds, batch_sds)


def _lower_opt():
    return adamw_fn(constant_schedule(3e-4), weight_decay=0.1,
                    max_grad_norm=1.0)


def lowerable(cfg: ModelConfig, shape_name: str, mesh):
    """-> (fn, args_sds tuple).  ``jax.jit(fn).lower(*args_sds)``."""
    seq, batch, kind = SHAPES[shape_name]

    if kind == "train" and shd.pipe_size(mesh) > 1:
        try:
            return sharded_train_lowerable(cfg, mesh, seq=seq, batch=batch)
        except PipelineStepError:
            # arch/mesh not stage-uniform (encdec/hybrid families, leading
            # dense MoE layers, layers not divisible by pipe): the jit/GSPMD
            # step below still lowers — it simply ignores the pipe axis —
            # so an all-arch sweep over a pipe mesh keeps going
            pass

    if kind == "train":
        p_sds, p_sh = params_sds(cfg, mesh)
        opt = _lower_opt()
        opt_sds = jax.eval_shape(opt.init, p_sds)
        opt_sh = type(opt_sds)(
            step=NamedSharding(mesh, P()),
            mu=jax.tree.map(lambda s: s.sharding, p_sds),
            nu=jax.tree.map(lambda s: s.sharding, p_sds))
        state_sds = TrainState(
            params=p_sds,
            opt_state=_with_sharding(opt_sds, opt_sh),
            step=_sds((), jnp.int32, mesh, P()))
        batch_sds = _batch_sds(cfg, mesh, seq, batch)
        step_fn = make_train_step(cfg, opt, mesh=mesh,
                                  num_microbatches=cfg.train_microbatches)
        return step_fn, (state_sds, batch_sds)

    if kind == "prefill":
        p_sds, _ = params_sds(cfg, mesh)
        batch_sds = _batch_sds(cfg, mesh, seq, batch, with_labels=False)

        if cfg.family == "encdec":
            def prefill(params, batch):
                return encdec.forward(params, batch["frames"],
                                      batch["tokens"], cfg, mesh=mesh)
        else:
            def prefill(params, batch):
                return lm.forward(params, batch["tokens"], cfg, mesh=mesh)
        return prefill, (p_sds, batch_sds)

    # decode kinds: one new token against a cache of length `seq`
    p_sds, _ = params_sds(cfg, mesh)
    caches_sds = jax.eval_shape(
        functools.partial(serve_decode.init_caches, cfg, batch, seq))
    caches_sds = _with_sharding(
        caches_sds, shd.decode_cache_shardings(cfg, caches_sds, mesh))
    token_sds = _sds((batch, 1), jnp.int32, mesh,
                     shd.batch_spec(mesh, batch))
    pos_sds = _sds((), jnp.int32, mesh, P())

    def serve_step(params, caches, token, pos):
        return serve_decode.decode_step(params, caches, token, pos, cfg,
                                        mesh=mesh)
    return serve_step, (p_sds, caches_sds, token_sds, pos_sds)


# ---------------------------------------------------------------------------
# schedule-optimizer fleet
# ---------------------------------------------------------------------------

def kernel_fleet_names(cfg: ModelConfig):
    """Registry names of the schedule-optimizable kernels this config's
    forward pass leans on (see :func:`kernel_fleet` for the scenario-
    annotated form the launchers consume)."""
    fleet = ["matmul_leakyrelu", "fused_ff"]
    if cfg.norm == "rmsnorm":
        fleet.append("rmsnorm")
    if cfg.family in ("ssm", "hybrid"):
        fleet.append("ssd")
    if cfg.family != "ssm":            # attention stacks
        fleet += ["flash_attention", "softmax", "bmm"]
    return fleet


def shape_scenario(cfg: ModelConfig, shape_name: str) -> Scenario:
    """The workload point a (config × shape) cell runs the kernels at.

    Train/prefill cells keep the core fully occupied; decode cells sit at
    half occupancy for large batches and low occupancy for the
    single-stream long-context shape (one token per step leaves most of
    the machine idle — a different best schedule than the saturated
    case)."""
    seq, batch, kind = SHAPES[shape_name]
    if kind in ("train", "prefill"):
        occ = "full"
    else:
        occ = "half" if batch >= 64 else "low"
    return Scenario(batch=batch, seq_len=seq, dtype=cfg.dtype, occupancy=occ)


def fleet_scenarios(cfg: ModelConfig):
    """Distinct workload points (one per scenario bucket) derived from the
    config's supported shapes, in shape order."""
    out, seen = [], set()
    for shape_name in cfg.supported_shapes:
        sc = shape_scenario(cfg, shape_name)
        if sc.bucket not in seen:
            seen.add(sc.bucket)
            out.append(sc)
    return out


def kernel_fleet(cfg: ModelConfig):
    """``(kernel, Scenario)`` pairs for every schedule-optimizable kernel
    this config's forward pass leans on, at every workload point its
    supported shapes imply — the fleet ``python -m repro.launch.optimize
    --arch`` feeds to ``OptimizationSession.optimize_many`` and the
    serving launcher resolves through the schedule cache (one tuned
    schedule per kernel × scenario bucket)."""
    return [(name, sc) for name in kernel_fleet_names(cfg)
            for sc in fleet_scenarios(cfg)]
