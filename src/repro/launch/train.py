"""Training launcher: ``python -m repro.launch.train --arch stablelm-3b``.

On this CPU container it trains the reduced config end-to-end (the ~100M /
few-hundred-step driver lives in examples/train_lm.py); on a real cluster
the same entrypoint takes --full --mesh to pjit over the production mesh.

``--pipe S`` (or ``pipeline_stages`` on the config) builds a host mesh
with a ``pipe`` axis and switches the Trainer onto the shard_map pipeline
step (``--pipe-schedule gpipe|1f1b`` picks the micro-op timetable);
``--model M`` composes a tensor-parallel ``model`` axis into the pipeline
stages; ``--pods P`` adds a ``pod`` axis whose gradient reduction — when
the shard_map step is active, i.e. ``--pipe >= 2`` — runs compressed
(bf16 + error feedback) unless ``--no-compress-pod-grads``.  With
``--pods`` alone the jit/GSPMD path still data-parallelizes over ``pod``,
in plain fp32.
Multi-device CPU smoke needs
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` exported before
launch.
"""

from __future__ import annotations

import argparse
import warnings

from repro.configs import ARCHS, get_config
from repro.train import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b", choices=list(ARCHS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation chunks (jit step)")
    ap.add_argument("--pipe", type=int, default=0,
                    help="pipeline stages (0 = cfg.pipeline_stages; > 1 "
                         "builds a `pipe` mesh axis + shard_map step)")
    ap.add_argument("--pipe-microbatches", type=int, default=0,
                    help="pipeline microbatches "
                         "(0 = cfg.pipeline_microbatches)")
    ap.add_argument("--pipe-schedule", default=None,
                    help="pipeline micro-op schedule: gpipe | 1f1b "
                         "(default cfg.pipeline_schedule)")
    ap.add_argument("--model", type=int, default=1,
                    help="tensor-parallel `model` axis size (> 1 composes "
                         "TP into the pipeline stages)")
    ap.add_argument("--pods", type=int, default=1,
                    help="pod axis size (> 1 = multi-pod gradient reduction)")
    ap.add_argument("--no-compress-pod-grads", action="store_true",
                    help="plain fp32 psum over `pod` instead of bf16+EF")
    ap.add_argument("--full", action="store_true",
                    help="full (not reduced) config — cluster use")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    pipe = args.pipe or cfg.pipeline_stages
    overrides = {}
    if pipe:
        overrides["pipeline_stages"] = pipe
    if args.pipe_microbatches:
        overrides["pipeline_microbatches"] = args.pipe_microbatches
    if args.pipe_schedule:
        overrides["pipeline_schedule"] = args.pipe_schedule
    if args.no_compress_pod_grads:
        overrides["compress_pod_grads"] = False
    if overrides:
        cfg = cfg.replace(**overrides)

    # validate the schedule name eagerly — a typo should die here with the
    # valid choices, not deep inside step construction
    from repro.dist.pipeline import SCHEDULES
    if cfg.pipeline_schedule not in SCHEDULES:
        ap.error(f"--pipe-schedule {cfg.pipeline_schedule!r} is not a valid "
                 f"pipeline schedule; choose from {sorted(SCHEDULES)}")

    mesh = None
    if pipe > 1 or args.pods > 1 or args.model > 1:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(model=args.model, pipe=max(pipe, 1),
                              pods=args.pods)
        note = ""
        if args.pods > 1:
            # the compressed reduction lives in the shard_map pipeline
            # step, which the Trainer only selects for pipe >= 2 — say so
            # instead of claiming compression the jit path won't do
            if args.no_compress_pod_grads:
                pod_grads = "fp32 psum"
            elif pipe > 1:
                pod_grads = "bf16+EF compressed"
            else:
                pod_grads = ("fp32 psum — compressed reduction needs the "
                             "shard_map step, pass --pipe >= 2")
            note = f" (pod grads: {pod_grads})"
        print(f"[train] mesh: {dict(mesh.shape)}{note}")
        if pipe > 1:
            # surface the gcd clamp the Trainer will apply instead of
            # letting a non-dividing --pipe-microbatches remap silently
            from repro.train.loop import pipeline_microbatch_clamp
            n_micro, local_b = pipeline_microbatch_clamp(
                cfg.pipeline_microbatches, args.global_batch, mesh)
            if n_micro != cfg.pipeline_microbatches:
                warnings.warn(
                    f"--pipe-microbatches {cfg.pipeline_microbatches} does "
                    f"not divide the per-shard batch {local_b}; the Trainer "
                    f"will clamp it to {n_micro}", stacklevel=1)

    tcfg = TrainConfig(steps=args.steps, seq_len=args.seq_len,
                       global_batch=args.global_batch, lr=args.lr,
                       ckpt_dir=args.ckpt_dir,
                       num_microbatches=args.microbatches)
    trainer = Trainer(cfg, tcfg, mesh=mesh)

    def on_straggler(step, dt):
        print(f"[train] straggler watermark: step {step} took {dt:.2f}s")

    trainer.straggler_hook = on_straggler
    log = trainer.run()
    for row in log[:: max(1, len(log) // 10)]:
        print(f"[train] step={row['step']:5d} loss={row['loss']:.4f} "
              f"gnorm={row['grad_norm']:.3f} {row['seconds']*1e3:.0f}ms")
    print(f"[train] final loss: {log[-1]['loss']:.4f} "
          f"(start {log[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
