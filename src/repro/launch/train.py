"""Training launcher: ``python -m repro.launch.train --arch stablelm-3b``.

On this CPU container it trains the reduced config end-to-end (the ~100M /
few-hundred-step driver lives in examples/train_lm.py); on a real cluster
the same entrypoint takes --full --mesh to pjit over the production mesh.
"""

from __future__ import annotations

import argparse

from repro.configs import ARCHS, get_config
from repro.train import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b", choices=list(ARCHS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="full (not reduced) config — cluster use")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    tcfg = TrainConfig(steps=args.steps, seq_len=args.seq_len,
                       global_batch=args.global_batch, lr=args.lr,
                       ckpt_dir=args.ckpt_dir,
                       num_microbatches=args.microbatches)
    trainer = Trainer(cfg, tcfg)

    def on_straggler(step, dt):
        print(f"[train] straggler watermark: step {step} took {dt:.2f}s")

    trainer.straggler_hook = on_straggler
    log = trainer.run()
    for row in log[:: max(1, len(log) // 10)]:
        print(f"[train] step={row['step']:5d} loss={row['loss']:.4f} "
              f"gnorm={row['grad_norm']:.3f} {row['seconds']*1e3:.0f}ms")
    print(f"[train] final loss: {log[-1]['loss']:.4f} "
          f"(start {log[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
