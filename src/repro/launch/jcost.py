"""Trip-count-aware analytical cost model over jaxprs.

XLA's ``compiled.cost_analysis()`` counts while/scan bodies ONCE regardless
of trip count (verified empirically — see EXPERIMENTS.md §Roofline
methodology), which under-counts scan-over-layers models by ~n_layers×.
This walker computes exact FLOPs (and two byte estimates) from the closed
jaxpr, where ``scan`` still carries its ``length``:

  * flops        — 2·m·n·k per dot_general, 1/elem for elementwise/reduce,
                   × trip counts through nested scans (remat recompute is
                   explicit in the differentiated jaxpr, so it is counted);
  * bytes_naive  — every eqn materializes operands + outputs (no fusion):
                   upper bound on HBM traffic;
  * bytes_fused  — only "materialization points" touch HBM (dot/conv
                   operands+outputs, gathers/scatters, scan carries,
                   parameters): models perfect elementwise fusion, i.e. the
                   Pallas-kernel deployment path.  The truth lies between.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import numpy as np

_HEAVY = {"dot_general", "conv_general_dilated", "gather", "scatter",
          "scatter-add", "scatter_add", "dynamic_slice",
          "dynamic_update_slice", "take", "take_along_axis"}
_CALL = {"pjit", "closed_call", "custom_jvp_call", "custom_vjp_call",
         "checkpoint", "remat2", "remat", "custom_vjp_call_jaxpr",
         "shard_map", "smap"}
_FREE = {"broadcast_in_dim", "reshape", "transpose", "squeeze",
         "expand_dims", "convert_element_type", "copy", "stop_gradient",
         "slice", "rev", "iota", "constant", "bitcast_convert_type",
         "split", "concatenate"}


def _nbytes(aval) -> float:
    try:
        return float(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0.0


def _nelems(aval) -> float:
    try:
        return float(np.prod(aval.shape))
    except Exception:
        return 0.0


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes_naive: float = 0.0
    bytes_fused: float = 0.0

    def __add__(self, o):
        return Cost(self.flops + o.flops, self.bytes_naive + o.bytes_naive,
                    self.bytes_fused + o.bytes_fused)

    def __mul__(self, k: float):
        return Cost(self.flops * k, self.bytes_naive * k,
                    self.bytes_fused * k)

    def as_dict(self) -> Dict[str, float]:
        return {"flops": self.flops, "bytes_naive": self.bytes_naive,
                "bytes_fused": self.bytes_fused}


def _dot_flops(eqn) -> float:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    ((lc, rc), (lb, rb)) = eqn.params["dimension_numbers"]
    batch = np.prod([a.shape[i] for i in lb]) if lb else 1.0
    k = np.prod([a.shape[i] for i in lc]) if lc else 1.0
    m = np.prod([d for i, d in enumerate(a.shape)
                 if i not in set(lc) | set(lb)]) or 1.0
    n = np.prod([d for i, d in enumerate(b.shape)
                 if i not in set(rc) | set(rb)]) or 1.0
    return 2.0 * float(batch) * float(m) * float(n) * float(k)


def _eqn_io_bytes(eqn) -> float:
    ins = sum(_nbytes(v.aval) for v in eqn.invars
              if hasattr(v, "aval"))
    outs = sum(_nbytes(v.aval) for v in eqn.outvars)
    return ins + outs


def _looks_like_flash_body(jaxpr) -> bool:
    """Online-softmax attention chunk body: >=2 dot_generals + an exp."""
    prims = [e.primitive.name for e in jaxpr.eqns]
    return prims.count("dot_general") >= 2 and "exp" in prims


def jaxpr_cost(jaxpr, fused_attn: bool = False) -> Cost:
    total = Cost()
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "scan":
            inner = eqn.params["jaxpr"].jaxpr
            length = float(eqn.params["length"])
            body = jaxpr_cost(inner, fused_attn)
            if fused_attn and _looks_like_flash_body(inner):
                # deploy the Pallas flash kernel for this loop: internals
                # (scores, exp, running stats, the q-tile accumulators)
                # stay in VMEM.  HBM traffic per iteration = the xs slices
                # (K/V chunks); the carry is materialized once, not per
                # iteration.
                nc = int(eqn.params.get("num_consts", 0))
                ncar = int(eqn.params.get("num_carry", 0))
                slice_io = sum(_nbytes(v.aval)
                               for v in inner.invars[nc + ncar:])
                carry_io = sum(_nbytes(v.aval)
                               for v in inner.invars[nc: nc + ncar])
                body = Cost(body.flops, body.bytes_naive, slice_io)
                total = total + body * length
                total.bytes_fused += carry_io
                continue
            total = total + body * length
            continue
        if prim == "while":
            # bounded while loops are rare here (gpipe fori): count body once
            # per conservative default, plus note in methodology.
            body = jaxpr_cost(eqn.params["body_jaxpr"].jaxpr, fused_attn)
            total = total + body
            continue
        if prim == "cond":
            branches = [jaxpr_cost(b.jaxpr, fused_attn)
                        for b in eqn.params["branches"]]
            worst = max(branches, key=lambda c: c.flops)
            total = total + worst
            continue
        if prim in _CALL:
            inner = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if inner is None:
                continue
            total = total + jaxpr_cost(inner.jaxpr if hasattr(inner, "jaxpr")
                                       else inner, fused_attn)
            continue
        if prim == "dot_general":
            f = _dot_flops(eqn)
            io = _eqn_io_bytes(eqn)
            total.flops += f
            total.bytes_naive += io
            total.bytes_fused += io
            continue
        if prim in _FREE:
            total.bytes_naive += sum(_nbytes(v.aval) for v in eqn.outvars)
            continue
        if prim in _HEAVY:
            io = _eqn_io_bytes(eqn)
            # gathers/dynamic slices move only the slice, not the operand:
            # count output + indices, plus operand once for scatters
            outs = sum(_nbytes(v.aval) for v in eqn.outvars)
            total.bytes_naive += outs
            total.bytes_fused += outs
            continue
        # elementwise / reductions / everything else
        elems = max(sum(_nelems(v.aval) for v in eqn.outvars), 1.0)
        total.flops += elems
        total.bytes_naive += _eqn_io_bytes(eqn)
    return total


def cost_of(fn, *args, fused_attn: bool = False) -> Dict[str, float]:
    """Analytical cost of ``fn(*args)`` (args may be ShapeDtypeStructs).
    ``fused_attn=True`` models deploying the Pallas flash kernel for the
    online-softmax chunk loops (bytes drop to loop-boundary IO)."""
    closed = jax.make_jaxpr(fn)(*args)
    c = jaxpr_cost(closed.jaxpr, fused_attn)
    # parameters/arguments are read at least once per step
    arg_bytes = sum(_nbytes(v.aval) for v in closed.jaxpr.invars)
    c.bytes_fused += arg_bytes
    return c.as_dict()
