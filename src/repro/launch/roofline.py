"""Roofline-term extraction from compiled dry-run artifacts.

Hardware constants (TPU v5e-class target, per the brief):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

Per (arch × shape × mesh) cell:
  compute term    = HLO_FLOPs / (chips × peak)
  memory term     = HLO_bytes / (chips × hbm_bw)
  collective term = collective_bytes / (chips × link_bw)

cost_analysis() reports the *per-device partitioned* module, so global
HLO_FLOPs/bytes = per-device × chips.  collective_bytes is not in
cost_analysis: we parse the post-SPMD optimized HLO text and sum the result
buffer sizes of every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute (result size == operand size for all-reduce and
permute; all-gather counts the gathered buffer it must move; documented in
EXPERIMENTS.md §Roofline methodology).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = bf16[16,4096,128]{2,1,0} all-gather(
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+(" +
    "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")
_TUPLE_RE = re.compile(
    r"=\s*\(\s*((?:[a-z0-9]+\[[0-9,]*\][^,)]*,?\s*)+)\)\s*(" +
    "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved by each collective family + op counts."""
    out = {c: 0.0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        hit = None
        for c in _COLLECTIVES:
            if f" {c}(" in stripped or f" {c}-start(" in stripped:
                hit = c
                break
        if hit is None:
            continue
        if f"{hit}-done(" in stripped:
            continue  # -done pairs with -start: count once
        total = 0
        m = _OP_RE.search(stripped)
        if m:
            total = _shape_bytes(m.group(1), m.group(2))
        else:
            mt = _TUPLE_RE.search(stripped)
            if mt:
                for dtype, dims in _SHAPE_RE.findall(mt.group(1)):
                    total += _shape_bytes(dtype, dims)
        out[hit] += total
        counts[hit] += 1
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    out["counts"] = counts
    return out


@dataclasses.dataclass
class Roofline:
    chips: int
    flops_global: float
    bytes_global: float
    coll_bytes_global: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: Optional[float] = None
    useful_ratio: Optional[float] = None
    coll_breakdown: Optional[Dict] = None

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def analyze(compiled, chips: int, model_flops: Optional[float] = None,
            hlo_text: Optional[str] = None,
            analytic: Optional[Dict] = None) -> Roofline:
    """``analytic`` (from launch.jcost) supplies trip-count-correct global
    FLOPs/bytes; XLA's cost_analysis counts loop bodies once (verified) and
    is recorded alongside for reference."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)

    if analytic is not None:
        flops_global = float(analytic["flops"])
        bytes_global = float(analytic["bytes_fused"])
        coll["xla_flops_global"] = flops_dev * chips
        coll["xla_bytes_global"] = bytes_dev * chips
        coll["bytes_naive_global"] = float(analytic["bytes_naive"])
    else:
        flops_global = flops_dev * chips
        bytes_global = bytes_dev * chips
    coll_global = coll["total"] * chips

    compute_s = flops_global / (chips * PEAK_FLOPS)
    memory_s = bytes_global / (chips * HBM_BW)
    collective_s = coll_global / (chips * LINK_BW)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = (model_flops / flops_global) \
        if (model_flops and flops_global) else None
    return Roofline(chips=chips, flops_global=flops_global,
                    bytes_global=bytes_global, coll_bytes_global=coll_global,
                    compute_s=compute_s, memory_s=memory_s,
                    collective_s=collective_s, dominant=dominant,
                    model_flops=model_flops, useful_ratio=useful,
                    coll_breakdown=coll)
