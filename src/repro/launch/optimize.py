"""Schedule-optimizer launcher: run a kernel fleet through the session API.

    PYTHONPATH=src python -m repro.launch.optimize rmsnorm softmax \
        --strategy ppo --backend fast --timesteps 4096

    # optimize every kernel an architecture's forward pass leans on
    PYTHONPATH=src python -m repro.launch.optimize --arch stablelm-3b

    # deploy-time lookup only (no search, no autotune — §4.2 split)
    PYTHONPATH=src python -m repro.launch.optimize rmsnorm --deploy

Sibling of ``launch.train`` / ``launch.serve``: one session shares the
stall table and the cross-kernel measurement memo across the whole fleet,
and finished artifacts land in the spec-hash-indexed schedule cache the
serving launcher reads back.
"""

from __future__ import annotations

import argparse
import os

from repro.sched import (OptimizationSession, OptimizeRequest,
                         make_budgeted_strategy)
from repro.sched.backends import BACKENDS, make_backend
from repro.sched.cache import DEFAULT_CACHE_DIR
from repro.sched.session import STRATEGIES

MEMO_FILENAME = "measure_memo.pkl"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("kernels", nargs="*",
                    help="registry kernel names (see repro.kernels.KERNELS);"
                         " may be combined with --arch")
    ap.add_argument("--arch", default=None,
                    help="optimize the kernel fleet of this architecture "
                         "(launch.specs.kernel_fleet)")
    ap.add_argument("--strategy", default="ppo", choices=sorted(STRATEGIES))
    ap.add_argument("--backend", default="fast", choices=sorted(BACKENDS))
    ap.add_argument("--cache-dir", default=DEFAULT_CACHE_DIR)
    ap.add_argument("--memo-dir", default=None,
                    help="persist the cross-kernel measurement memo here "
                         f"({MEMO_FILENAME}): campaigns warm-start from "
                         "prior measurements and save back on completion "
                         "(fast/pooled backends)")
    ap.add_argument("--workers", type=int, default=1,
                    help="fleet threads for optimize_many (1 = serial)")
    ap.add_argument("--timesteps", type=int, default=8192)
    ap.add_argument("--episode-length", type=int, default=32)
    ap.add_argument("--force", action="store_true",
                    help="re-search even when a cached artifact exists")
    ap.add_argument("--deploy", action="store_true",
                    help="index lookup only; fails if not optimized yet")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    names = list(args.kernels)
    if args.arch:
        from repro.configs import get_config
        from repro.launch.specs import kernel_fleet
        names += [k for k in kernel_fleet(get_config(args.arch, reduced=True))
                  if k not in names]
    if not names:
        ap.error("give kernel names and/or --arch")
    from repro.kernels import get_kernel
    for name in names:
        get_kernel(name)               # fail fast on unknown names

    backend = make_backend(args.backend)
    memo_path = None
    if args.memo_dir:
        memo = getattr(backend, "memo", None)
        if memo is None:
            print(f"[optimize] --memo-dir ignored: backend "
                  f"{args.backend!r} shares no measurement memo")
        else:
            os.makedirs(args.memo_dir, exist_ok=True)
            memo_path = os.path.join(args.memo_dir, MEMO_FILENAME)
            if os.path.exists(memo_path):
                # corrupt / version-mismatched files raise MemoVersionError
                # here — loudly, before any search work starts
                n = memo.load(memo_path)
                print(f"[optimize] warm-started memo from {memo_path}: "
                      f"{n} entries")

    session = OptimizationSession(
        backend=backend,
        strategy=make_budgeted_strategy(args.strategy,
                                        timesteps=args.timesteps,
                                        episode_length=args.episode_length),
        cache_dir=args.cache_dir)
    if args.deploy:
        for name in names:
            art = session.deploy(name)
            print(f"[optimize] {name}: cached config {art.config} "
                  f"{art.baseline_cycles:.0f} -> {art.optimized_cycles:.0f} "
                  f"cycles ({art.speedup:.3f}x)")
        return

    results = session.optimize_many(
        [OptimizeRequest(kernel=n, force=args.force, verbose=args.verbose)
         for n in names],
        max_workers=args.workers)
    for res in results:
        art = res.artifact
        tag = "cache" if res.from_cache else res.strategy
        print(f"[optimize] {res.kernel}: "
              f"{art.baseline_cycles:.0f} -> {art.optimized_cycles:.0f} "
              f"cycles ({art.speedup:.3f}x, {tag}, {res.seconds:.1f}s)")
    if session.memo is not None:
        print(f"[optimize] shared memo: {session.memo.summary()}")
        if memo_path is not None:
            n = session.memo.save(memo_path)
            print(f"[optimize] saved memo to {memo_path} ({n} entries)")


if __name__ == "__main__":
    main()
