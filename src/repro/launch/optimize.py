"""Schedule-optimizer launcher: run a kernel fleet through the session API.

    PYTHONPATH=src python -m repro.launch.optimize rmsnorm softmax \
        --strategy ppo --backend fast --timesteps 4096

    # optimize every kernel an architecture's forward pass leans on, at
    # every workload point its supported shapes imply
    PYTHONPATH=src python -m repro.launch.optimize --arch stablelm-3b

    # fleet campaign: scenarios × targets product, resumable per bucket
    PYTHONPATH=src python -m repro.launch.optimize rmsnorm softmax \
        --scenarios 8x4096,64x32768xbf16xhalf \
        --targets tpu-tsass-v1,tpu-tsass-v2

    # deploy-time lookup only (no search, no autotune — §4.2 split)
    PYTHONPATH=src python -m repro.launch.optimize rmsnorm --deploy

Sibling of ``launch.train`` / ``launch.serve``: one session shares the
per-target stall tables and the cross-kernel measurement memo across the
whole campaign, and finished artifacts land in the scenario-keyed
schedule-cache index the serving launcher reads back.  Re-running the
same campaign without ``--force`` resumes: every already-tuned
(kernel, target, scenario bucket) cell is a cache hit and only the
missing cells search.
"""

from __future__ import annotations

import argparse
import os
from typing import List, Optional, Sequence, Tuple

from repro.sched import (OptimizationSession, OptimizeRequest,
                         make_budgeted_strategy)
from repro.sched.backends import BACKENDS, make_backend, warm_start_memo
from repro.sched.cache import DEFAULT_CACHE_DIR
from repro.sched.resilience import FailureLedger, ResilientBackend
from repro.sched.scenario import (DEFAULT_BUCKET, TARGETS, MachineTarget,
                                  Scenario, bucket_of, require_target)
from repro.sched.session import STRATEGIES

MEMO_FILENAME = "measure_memo.pkl"
LEDGER_FILENAME = "campaign_state.json"

FleetUnit = Tuple[str, Optional[Scenario]]


def parse_scenarios(spec: str) -> List[Scenario]:
    """Comma-separated ``BATCHxSEQ[xDTYPE[xOCC]]`` list -> Scenarios."""
    return [Scenario.parse(tok) for tok in spec.split(",") if tok.strip()]


def parse_targets(spec: str) -> List[MachineTarget]:
    """Comma-separated target names -> registered MachineTargets.

    Raises ``KeyError`` (listing the registered names) on an unknown name
    — a campaign aimed at a machine model that does not exist must fail
    before any search work starts, not tune against a silent default.
    """
    return [require_target(tok.strip()) for tok in spec.split(",")
            if tok.strip()]


def campaign_requests(units: Sequence[FleetUnit],
                      targets: Optional[Sequence[MachineTarget]] = None,
                      force: bool = False,
                      verbose: bool = False) -> List[OptimizeRequest]:
    """The deduplicated scenarios × targets product as OptimizeRequests.

    One request per distinct (kernel, scenario bucket, target) cell —
    overlapping units (e.g. positional kernel names that also appear in
    an ``--arch`` fleet, or two scenarios that fall in the same bucket)
    collapse to a single search.  Order is first-seen, so positional
    kernels keep their CLI position.
    """
    tgts: Sequence[Optional[MachineTarget]] = targets or [None]
    reqs: List[OptimizeRequest] = []
    seen = set()
    for name, scen in units:
        for tgt in tgts:
            key = (name, bucket_of(scen),
                   tgt.name if tgt is not None else None)
            if key in seen:
                continue
            seen.add(key)
            reqs.append(OptimizeRequest(kernel=name, scenario=scen,
                                        target=tgt, force=force,
                                        verbose=verbose))
    return reqs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("kernels", nargs="*",
                    help="registry kernel names (see repro.kernels.KERNELS);"
                         " may be combined with --arch")
    ap.add_argument("--arch", default=None,
                    help="optimize the kernel fleet of this architecture "
                         "at its derived workload points "
                         "(launch.specs.kernel_fleet)")
    ap.add_argument("--scenarios", default=None, metavar="LIST",
                    help="comma-separated workload points "
                         "BATCHxSEQ[xDTYPE[xOCC]], e.g. "
                         "'8x4096,64x32768xbf16xhalf': tune every kernel "
                         "at every point (overrides the --arch-derived "
                         "points).  Default: --arch derives points from "
                         "the config's shapes; bare kernel names tune the "
                         "single default bucket")
    ap.add_argument("--targets", default=None, metavar="LIST",
                    help="comma-separated machine-target names; the "
                         "campaign covers the full scenarios × targets "
                         "product.  Registered: " + ", ".join(sorted(TARGETS)))
    ap.add_argument("--strategy", default="ppo", choices=sorted(STRATEGIES))
    ap.add_argument("--backend", default="fast", choices=sorted(BACKENDS))
    ap.add_argument("--cache-dir", default=DEFAULT_CACHE_DIR)
    ap.add_argument("--memo-dir", default=None,
                    help="persist the cross-kernel measurement memo here "
                         f"({MEMO_FILENAME}): campaigns warm-start from "
                         "prior measurements and save back on completion; "
                         "concurrent campaigns merge on save "
                         "(fast/pooled backends)")
    ap.add_argument("--workers", type=int, default=1,
                    help="fleet threads for optimize_many (1 = serial)")
    ap.add_argument("--timesteps", type=int, default=8192)
    ap.add_argument("--episode-length", type=int, default=32)
    ap.add_argument("--force", action="store_true",
                    help="re-search even when a cached artifact exists")
    ap.add_argument("--deploy", action="store_true",
                    help="index lookup only; fails if not optimized yet")
    ap.add_argument("--resilient", action="store_true",
                    help="wrap the backend in ResilientBackend (per-measure "
                         "retries, robust timing, circuit breaker)")
    ap.add_argument("--max-retries", type=int, default=2, metavar="N",
                    help="per-cell retry budget across resumable passes; a "
                         "cell failing more than N+1 times total is skipped "
                         "and stays in the failure ledger (default 2)")
    ap.add_argument("--retry-backoff", type=float, default=0.0, metavar="S",
                    help="base backoff before re-running a previously "
                         "failed cell (doubles per prior failure)")
    ap.add_argument("--strict", action="store_true",
                    help="legacy fail-fast: the first failing cell aborts "
                         "the campaign (no failure ledger, no supervision)")
    ap.add_argument("--memo-stats", action="store_true",
                    help="print the shared memo's full counters "
                         "(entries/hits/misses/cross-kernel/evictions) in "
                         "the end-of-campaign output — the cost-model "
                         "corpus growth per run")
    ap.add_argument("--strict-memo", action="store_true",
                    help="die on a corrupt --memo-dir payload instead of "
                         "quarantining it and warm-starting empty")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    units: List[FleetUnit] = [(n, None) for n in args.kernels]
    if args.arch:
        from repro.configs import get_config
        from repro.launch.specs import kernel_fleet
        units += kernel_fleet(get_config(args.arch, reduced=True))
    if not units:
        ap.error("give kernel names and/or --arch")
    if args.scenarios:
        # explicit workload points win: every named kernel at every point
        points = parse_scenarios(args.scenarios)
        names = list(dict.fromkeys(n for n, _ in units))
        units = [(n, sc) for n in names for sc in points]
    targets: Optional[List[MachineTarget]] = None
    if args.targets:
        try:
            targets = parse_targets(args.targets)
        except KeyError as e:
            ap.error(str(e).strip('"\''))

    from repro.kernels import get_kernel
    for name in dict.fromkeys(n for n, _ in units):
        get_kernel(name)               # fail fast on unknown names

    backend = make_backend(args.backend)
    if args.resilient and not isinstance(backend, ResilientBackend):
        backend = ResilientBackend(backend)
    memo_path = None
    if args.memo_dir:
        memo = getattr(backend, "memo", None)
        if memo is None:
            print(f"[optimize] --memo-dir ignored: backend "
                  f"{args.backend!r} shares no measurement memo")
        else:
            os.makedirs(args.memo_dir, exist_ok=True)
            memo_path = os.path.join(args.memo_dir, MEMO_FILENAME)
            if os.path.exists(memo_path):
                # corrupt / version-mismatched payloads are quarantined
                # with a warning and the campaign warm-starts empty;
                # --strict-memo keeps the loud pre-search MemoVersionError
                n = warm_start_memo(memo, memo_path,
                                    strict=args.strict_memo)
                print(f"[optimize] warm-started memo from {memo_path}: "
                      f"{n} entries")

    session = OptimizationSession(
        backend=backend,
        strategy=make_budgeted_strategy(args.strategy,
                                        timesteps=args.timesteps,
                                        episode_length=args.episode_length),
        cache_dir=args.cache_dir)

    def label(kernel: str, bucket: Optional[str],
              target: Optional[str]) -> str:
        out = kernel
        if bucket not in (None, DEFAULT_BUCKET):
            out += f"@{bucket}"
        if target is not None and (targets or target != session.target.name):
            out += f" [{target}]"
        return out

    if args.deploy:
        for name, scen in units:
            for tgt in (targets or [None]):
                art = session.deploy(name, scenario=scen, target=tgt)
                print(f"[optimize] {label(name, art.bucket, art.target)}: "
                      f"cached config {art.config} "
                      f"{art.baseline_cycles:.0f} -> "
                      f"{art.optimized_cycles:.0f} "
                      f"cycles ({art.speedup:.3f}x)")
        return

    reqs = campaign_requests(units, targets, force=args.force,
                             verbose=args.verbose)
    ledger = None
    if not args.strict:
        # supervised campaign: per-cell fault isolation, failures land in
        # the persistent ledger and re-running the same command retries
        # exactly the failed cells (healthy ones are cache hits)
        ledger = FailureLedger(os.path.join(args.cache_dir, LEDGER_FILENAME))
        if len(ledger):
            print(f"[optimize] resuming: {len(ledger)} failed cell(s) in "
                  f"{ledger.path}")
    results = session.optimize_many(reqs, max_workers=args.workers,
                                    ledger=ledger,
                                    max_retries=args.max_retries,
                                    retry_backoff=args.retry_backoff)
    ok = [r for r in results if r is not None and r.ok]
    failed = [r for r in results if r is not None and not r.ok]
    degraded = [r for r in ok if getattr(r, "degraded", False)]
    for res in ok:
        art = res.artifact
        tag = "cache" if res.from_cache else res.strategy
        if res.degraded:
            tag += ", DEGRADED"
        print(f"[optimize] {label(res.kernel, res.scenario, res.target)}: "
              f"{art.baseline_cycles:.0f} -> {art.optimized_cycles:.0f} "
              f"cycles ({art.speedup:.3f}x, {tag}, {res.seconds:.1f}s)")
    for res in failed:
        state = "skipped (retry budget spent)" if res.skipped else "FAILED"
        print(f"[optimize] {label(res.kernel, res.scenario, res.target)}: "
              f"{state} after {res.attempts} attempt(s): "
              f"{res.error_type}: {res.error}")
    if ledger is not None:
        print(f"[optimize] campaign: {len(ok)} succeeded "
              f"({len(degraded)} degraded), {len(failed)} failed; "
              f"ledger: {ledger.path} ({len(ledger)} open cell(s))")
    health = getattr(session.backend, "summary", None)
    if callable(health) and isinstance(session.backend, ResilientBackend):
        print(f"[optimize] backend health: {session.backend.summary()}")
    if session.memo is not None:
        print(f"[optimize] shared memo: {session.memo.summary()}")
        if args.memo_stats:
            s = session.memo.stats()
            print(f"[optimize] memo stats: {s['entries']} entries over "
                  f"{s['programs']} programs, {s['hits']} hits / "
                  f"{s['misses']} misses, {s['cross_kernel_hits']} "
                  f"cross-kernel hits, {s['evictions']} evictions")
        if memo_path is not None:
            n = session.memo.save(memo_path)
            print(f"[optimize] saved memo to {memo_path} ({n} entries)")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
