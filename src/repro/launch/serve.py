"""Serving launcher: ``python -m repro.launch.serve --arch gemma3-1b``.

Runs batched greedy generation on the reduced config (CPU) or the full
config on a cluster mesh.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import for_config
from repro.serve import generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=list(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--schedule-cache", default=None, metavar="DIR",
                    help="report the arch's RL-optimized kernel schedules "
                         "from this cache (index lookup only, no autotune)")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    if cfg.family == "encdec":
        raise SystemExit("use examples/serve_decode.py for the enc-dec arch")
    if args.schedule_cache:
        from repro.launch.specs import kernel_fleet
        from repro.serve.engine import schedule_plan
        for key, art in schedule_plan(kernel_fleet(cfg),
                                      cache_dir=args.schedule_cache).items():
            name, bucket = key if isinstance(key, tuple) else (key, None)
            label = name if bucket in (None, "default") else f"{name}@{bucket}"
            state = (f"{art.speedup:.3f}x ({art.optimized_cycles:.0f} cycles)"
                     if art is not None else "not optimized (-O3 baseline)")
            print(f"[serve] schedule {label}: {state}")
    model = for_config(cfg)
    params = model.init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                          dtype=np.int32)
    t0 = time.time()
    out = jax.jit(lambda p, t: generate(p, cfg, t, args.new_tokens))(
        params, prompt)
    out.block_until_ready()
    dt = time.time() - t0
    toks = args.batch * args.new_tokens
    print(f"[serve] {args.arch}: generated {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print("[serve] sample:", np.asarray(out[0, :24]).tolist())


if __name__ == "__main__":
    main()
