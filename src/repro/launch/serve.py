"""Serving launcher: ``python -m repro.launch.serve --arch gemma3-1b``.

The launcher is built around :class:`repro.serve.engine.ServeEngine`:
it spins up the continuous-batching engine, replays a seeded
Poisson-arrival trace at ``--qps`` across ``--tenants`` weighted
tenants, and reports p50/p99 latency, TTFT, tokens/s, the per-tenant
fairness table, and the resolved ``kernel@bucket [target]`` schedule
plan (pure cache-index lookups — no autotune at serve time).

The pre-engine invocation (``--batch/--prompt-len/--new-tokens`` without
``--qps``) still runs the one-shot static-batch :func:`repro.serve.generate`
path, with a deprecation note pointing at the engine flags.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import for_config
from repro.serve import generate


def _print_plan(engine) -> None:
    if not engine.plan:
        return
    print("[serve] resolved schedule plan:")
    for line in engine.plan_summary():
        print(f"[serve]   {line}")


def _print_fairness(engine) -> None:
    rows = engine.scheduler.fairness_table()
    cols = ["tenant", "weight", "token_budget", "admitted", "served_tokens",
            "in_flight_tokens", "queued", "vtime"]
    widths = {c: max(len(c), *(len(str(r[c])) for r in rows)) for c in cols}
    print("[serve] tenant fairness:")
    print("[serve]   " + "  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("[serve]   " + "  ".join(str(r[c]).ljust(widths[c])
                                       for c in cols))


def _engine_mode(args, cfg) -> None:
    from repro.serve import ServeEngine, Tenant, TrafficConfig, run_load

    weights = ([float(w) for w in args.tenant_weights.split(",")]
               if args.tenant_weights else [1.0] * args.tenants)
    if len(weights) != args.tenants:
        raise SystemExit(f"--tenant-weights needs {args.tenants} values")
    tenants = [Tenant(f"t{i}", weight=w) for i, w in enumerate(weights)]

    model = for_config(cfg)
    params = model.init_model(cfg, jax.random.PRNGKey(0))
    max_seq = args.max_seq or (args.prompt_len + args.new_tokens + 8)
    engine = ServeEngine.from_config(
        cfg, params=params, max_batch=args.max_batch, max_seq=max_seq,
        block_size=args.block_size, kv_blocks=args.kv_blocks,
        tenants=tenants, schedule_cache=args.schedule_cache,
        paged=args.paged, debug_invariants=args.debug_invariants,
        on_missing="raise" if args.strict_schedules else "baseline")
    _print_plan(engine)
    if engine.counters.get("schedule_fallbacks"):
        print(f"[serve] WARNING: {engine.counters['schedule_fallbacks']} "
              f"kernel(s) serving the -O3 baseline (no cached schedule); "
              f"use --strict-schedules to refuse degraded serving")

    traffic = TrafficConfig(
        qps=args.qps, n_requests=args.requests, n_tenants=args.tenants,
        prompt_len=(max(2, args.prompt_len // 2), args.prompt_len),
        output_len=(max(1, args.new_tokens // 2), args.new_tokens),
        vocab=cfg.vocab, seed=0,
        prefix_tokens=args.prefix_tokens, prefix_groups=args.prefix_groups)
    print(f"[serve] {args.arch}: {args.requests} requests @ {args.qps} qps, "
          f"{args.tenants} tenants, max_batch={args.max_batch}, "
          f"max_seq={max_seq}, kv_blocks={engine.pool.num_blocks}, "
          f"kv={'paged' if engine.paged else 'dense slots'}"
          + (f", shared prefix {args.prefix_tokens} tokens x "
             f"{args.prefix_groups} groups" if args.prefix_tokens else ""))
    report = run_load(engine, traffic)
    print(f"[serve] tokens/s {report['tokens_per_s']:.1f}  "
          f"p50 {report['latency_p50_s'] * 1e3:.1f}ms  "
          f"p99 {report['latency_p99_s'] * 1e3:.1f}ms  "
          f"ttft p50 {report['ttft_p50_s'] * 1e3:.1f}ms  "
          f"completed {report['completed']}/{report['n_requests']} "
          f"(truncated {report['truncated']})")
    eng = report["stats"]["engine"]
    print(f"[serve] engine: {eng['passes']} passes, lane utilization "
          f"{eng['lane_utilization']:.2f}, {eng['stalls']} stalls, "
          f"{eng['preemptions']} preemptions")
    if engine.paged:
        pool = report["stats"]["pool"]
        print(f"[serve] paged kv: max_active {eng['max_active']}, "
              f"prefix hits {eng['prefix_hits']} "
              f"({pool['shared_tokens_reused']} tokens reused), "
              f"cow forks {eng['cow_forks']}, "
              f"spills {eng['preempt_spills']}, "
              f"high water {pool['high_water_blocks']}/"
              f"{engine.pool.num_blocks} blocks, "
              f"peak kv {engine.peak_kv_bytes() / 1e6:.1f} MB")
    _print_fairness(engine)


def _legacy_mode(args, cfg) -> None:
    print("[serve] note: the flat --batch static path is deprecated; use "
          "--qps/--tenants/--max-batch/--kv-blocks to run the "
          "continuous-batching engine (ServeEngine.from_config)")
    if args.schedule_cache:
        from repro.launch.specs import kernel_fleet
        from repro.serve.engine import schedule_plan
        on_missing = "raise" if args.strict_schedules else "baseline"
        for key, art in schedule_plan(kernel_fleet(cfg),
                                      cache_dir=args.schedule_cache,
                                      on_missing=on_missing).items():
            name, bucket = key if isinstance(key, tuple) else (key, None)
            label = name if bucket in (None, "default") else f"{name}@{bucket}"
            state = (f"{art.speedup:.3f}x ({art.optimized_cycles:.0f} cycles)"
                     if art is not None else "not optimized (-O3 baseline)")
            print(f"[serve] schedule {label}: {state}")
    model = for_config(cfg)
    params = model.init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                          dtype=np.int32)
    t0 = time.time()
    out = jax.jit(lambda p, t: generate(p, cfg, t, args.new_tokens))(
        params, prompt)
    out.block_until_ready()
    dt = time.time() - t0
    toks = args.batch * args.new_tokens
    print(f"[serve] {args.arch}: generated {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print("[serve] sample:", np.asarray(out[0, :24]).tolist())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=list(ARCHS))
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--schedule-cache", default=None, metavar="DIR",
                    help="resolve the arch's RL-optimized kernel schedules "
                         "from this cache (index lookup only, no autotune)")
    ap.add_argument("--strict-schedules", action="store_true",
                    help="refuse to serve kernels without a cached schedule "
                         "(on_missing='raise'); default degrades them to "
                         "the -O3 baseline with a warning")
    # engine mode
    ap.add_argument("--qps", type=float, default=None,
                    help="offered Poisson arrival rate; enables the "
                         "continuous-batching engine")
    ap.add_argument("--tenants", type=int, default=2,
                    help="number of weighted-fair tenants")
    ap.add_argument("--tenant-weights", default=None, metavar="W1,W2,...",
                    help="per-tenant WFQ weights (default: all 1.0)")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="engine slots (concurrent requests per pass)")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="KV pool blocks; below slots*blocks_per_slot "
                         "oversubscribes the pool (stall/preempt pressure)")
    ap.add_argument("--max-seq", type=int, default=None,
                    help="cache positions per slot")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV pool block granularity (tokens)")
    ap.add_argument("--requests", type=int, default=32,
                    help="trace length for the load generator")
    ap.add_argument("--paged", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="paged KV: block-table indirection with prefix "
                         "sharing and copy-free preemption (default on; "
                         "--no-paged restores the dense per-slot cache)")
    ap.add_argument("--prefix-tokens", type=int, default=0,
                    help="prepend a shared system prompt of this many tokens "
                         "to every request (Zipf-distributed over "
                         "--prefix-groups distinct prefixes)")
    ap.add_argument("--prefix-groups", type=int, default=4,
                    help="distinct shared prefixes for --prefix-tokens")
    ap.add_argument("--debug-invariants", action="store_true",
                    help="run KVBlockPool.check() every engine tick")
    # shared with legacy static mode
    ap.add_argument("--batch", type=int, default=4,
                    help="[deprecated static path] batch rows")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    if cfg.family == "encdec":
        raise SystemExit("use examples/serve_decode.py for the enc-dec arch")
    if args.qps is not None:
        _engine_mode(args, cfg)
    else:
        _legacy_mode(args, cfg)


if __name__ == "__main__":
    main()
