"""Production mesh construction (DESIGN.md §5).

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run pins the device count *before* any jax
initialization).

Axis layout (outermost → innermost): ``pod`` (multi-pod replica groups),
``pipe`` (pipeline stages, carved out of the data-parallel dimension),
``data`` (within-pod DP / FSDP), ``model`` (tensor/expert parallelism).
``pod``/``pipe`` only appear when their size is > 1, so meshes built
without them keep the original two- or three-axis shape.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False, pipe: int = 1):
    """single-pod: (data=16, model=16) = 256 chips;
    multi-pod:  (pod=2, data=16, model=16) = 512 chips.

    ``pipe > 1`` carves the pipeline axis out of the 16-way data dimension
    (same chip count): e.g. ``pipe=4`` -> (pipe=4, data=4, model=16).
    """
    if 16 % pipe:
        raise ValueError(f"pipe={pipe} must divide the 16-way data axis")
    shape: Tuple[int, ...] = ()
    axes: Tuple[str, ...] = ()
    if multi_pod:
        shape, axes = (2,), ("pod",)
    if pipe > 1:
        shape, axes = shape + (pipe,), axes + ("pipe",)
    shape += (16 // pipe, 16)
    axes += ("data", "model")
    return jax.make_mesh(shape, axes)


def host_mesh_shape(n_devices: int, *, model: int = 1,
                    data: Optional[int] = None, pipe: Optional[int] = None,
                    pods: Optional[int] = None):
    """Pure shape arithmetic behind :func:`make_host_mesh` (unit-testable
    without devices).  Returns ``(shape, axis_names)``.

    ``pipe``/``pods`` compose with ``data``/``model`` instead of replacing
    them: the data dimension defaults to whatever devices remain after the
    other axes take their share.
    """
    pipe = pipe or 1
    pods = pods or 1
    if data is None:
        denom = pods * pipe * model
        if n_devices % denom:
            raise ValueError(
                f"{n_devices} devices not divisible by pods*pipe*model="
                f"{denom}")
        data = n_devices // denom
    shape: Tuple[int, ...] = ()
    axes: Tuple[str, ...] = ()
    if pods > 1:
        shape, axes = shape + (pods,), axes + ("pod",)
    if pipe > 1:
        shape, axes = shape + (pipe,), axes + ("pipe",)
    shape += (data, model)
    axes += ("data", "model")
    return shape, axes


def make_host_mesh(model: int = 1, data: Optional[int] = None,
                   pipe: Optional[int] = None, pods: Optional[int] = None):
    """Small meshes over whatever devices exist (tests / CPU smoke).

    ``make_host_mesh(pipe=4)`` on 8 devices builds
    ``(pipe=4, data=2, model=1)`` — the pipe axis composes with the others
    rather than silently dropping them.
    """
    shape, axes = host_mesh_shape(len(jax.devices()), model=model, data=data,
                                  pipe=pipe, pods=pods)
    return jax.make_mesh(shape, axes)
