"""Production mesh construction (DESIGN.md §5).

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run pins the device count *before* any jax
initialization)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """single-pod: (data=16, model=16) = 256 chips;
    multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int = None, pipe: int = None):
    """Small meshes over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    if pipe:
        return jax.make_mesh((pipe,), ("pipe",))
    data = data if data is not None else n // model
    return jax.make_mesh((data, model), ("data", "model"))
