"""Strategy Evaluator launcher: race every search strategy under one
measurement budget (the cost-model subsystem's comparison harness).

    PYTHONPATH=src python -m repro.launch.evaluate \
        --kernels matmul_leakyrelu,bmm --budget 512 --out evaluator.json

    # reuse a campaign's measurement corpus and persist the trained
    # cost model + dataset next to it
    PYTHONPATH=src python -m repro.launch.evaluate \
        --memo-dir runs/memo --train-cost-model

Sibling of ``launch.optimize``: where optimize runs *one* strategy per
campaign cell, evaluate runs the whole roster (ppo / greedy / random /
beam x {oracle, cost, policy} / lookahead) on fresh per-cell backends and
reports what each strategy's best cycles cost in real measurements.
"""

from __future__ import annotations

import argparse
import json
import os

from repro.costmodel.evaluator import (DEFAULT_KERNELS, DEFAULT_STRATEGIES,
                                       evaluate_strategies, format_table)
from repro.launch.optimize import MEMO_FILENAME

DATASET_FILENAME = "cost_dataset.npz"
MODEL_FILENAME = "cost_model.npz"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategies", default=",".join(DEFAULT_STRATEGIES),
                    metavar="LIST",
                    help="comma-separated roster subset (default: "
                         + ",".join(DEFAULT_STRATEGIES) + ")")
    ap.add_argument("--kernels", default=",".join(DEFAULT_KERNELS),
                    metavar="LIST",
                    help="comma-separated registry kernel names "
                         "(default: the §5.7 pair)")
    ap.add_argument("--budget", type=int, default=512,
                    help="per-cell real-measurement allowance; "
                         "model-guided strategies get a quarter of "
                         "greedy's measured spend (budget/4 when greedy "
                         "is not in the roster)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--train-steps", type=int, default=1500,
                    help="cost-model fit steps")
    ap.add_argument("--memo-dir", default=None,
                    help=f"read {MEMO_FILENAME} here as extra training "
                         "corpus; --train-cost-model writes the dataset "
                         "and model back alongside it")
    ap.add_argument("--train-cost-model", action="store_true",
                    help=f"persist {DATASET_FILENAME} + {MODEL_FILENAME} "
                         "into --memo-dir")
    ap.add_argument("--out", default=None, metavar="JSON",
                    help="write the machine-readable comparison here")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    if args.train_cost_model and not args.memo_dir:
        ap.error("--train-cost-model needs --memo-dir to write into")

    extra_memo = None
    if args.memo_dir:
        from repro.sched.backends import SharedMeasureMemo, warm_start_memo
        path = os.path.join(args.memo_dir, MEMO_FILENAME)
        if os.path.exists(path):
            extra_memo = SharedMeasureMemo()
            n = warm_start_memo(extra_memo, path)
            print(f"[evaluate] loaded {n} corpus entries from {path}")

    result = evaluate_strategies(
        kernels=[k for k in args.kernels.split(",") if k.strip()],
        strategies=[s for s in args.strategies.split(",") if s.strip()],
        budget=args.budget, seed=args.seed, train_steps=args.train_steps,
        extra_memo=extra_memo, verbose=args.verbose)

    print(format_table(result))

    if args.train_cost_model and result["model"] is not None:
        os.makedirs(args.memo_dir, exist_ok=True)
        ds_path = os.path.join(args.memo_dir, DATASET_FILENAME)
        model_path = os.path.join(args.memo_dir, MODEL_FILENAME)
        n = result["dataset"].save(ds_path)
        result["model"].save(model_path)
        print(f"[evaluate] saved {n}-row dataset to {ds_path}, "
              f"model to {model_path}")

    if args.out:
        payload = {k: v for k, v in result.items()
                   if k not in ("dataset", "model")}
        rc = payload.get("rank_correlation")
        if rc is not None and rc != rc:            # NaN -> null
            payload["rank_correlation"] = None
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2, allow_nan=False)
        print(f"[evaluate] wrote {args.out}")


if __name__ == "__main__":
    main()
