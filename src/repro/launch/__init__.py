# NOTE: launch.dryrun must be imported/run only in a fresh process (it pins
# the XLA device count); import nothing here that touches jax device state.
