import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes, proving the distribution config is coherent without
hardware, and extracting the roofline terms from the compiled artifacts.

MUST be run as its own process (the device-count flag above is read at
first jax init; nothing may import jax before it):

    PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-3b
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both \
        --out results/dryrun.json
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCHS, get_config          # noqa: E402
from repro.configs.base import SHAPES                # noqa: E402
from repro.launch import jcost                       # noqa: E402
from repro.launch import roofline as rl              # noqa: E402
from repro.launch.mesh import make_production_mesh   # noqa: E402
from repro.launch.specs import lowerable             # noqa: E402


def model_flops(cfg, shape_name: str) -> float:
    seq, batch, kind = SHAPES[shape_name]
    n_active = cfg.n_active_params()
    if kind == "train":
        return 6.0 * n_active * seq * batch
    if kind == "prefill":
        return 2.0 * n_active * seq * batch
    return 2.0 * n_active * batch   # decode: one token per sequence


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             collect_hlo: bool = True, fused_attn: bool = False,
             cfg_overrides: dict = None) -> dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    if shape_name not in cfg.supported_shapes:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip", "reason": cfg.shape_skips.get(shape_name,
                                                                "n/a")}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    try:
        fn, args = lowerable(cfg, shape_name, mesh)
        with mesh:
            lowered = jax.jit(fn).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            hlo = compiled.as_text() if collect_hlo else ""
            analytic = jcost.cost_of(fn, *args,
                                     fused_attn=fused_attn)
            roof = rl.analyze(compiled, chips,
                              model_flops=model_flops(cfg, shape_name),
                              hlo_text=hlo, analytic=analytic)
        return {
            "arch": arch, "shape": shape_name,
            "mesh": "multi" if multi_pod else "single",
            "chips": chips, "status": "ok",
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "peak_bytes": int(getattr(mem, "temp_size_in_bytes", 0))
                + int(getattr(mem, "argument_size_in_bytes", 0)),
            },
            "roofline": roof.as_dict(),
        }
    except Exception as e:  # a failing cell is a bug; record it loudly
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help=f"one of {ARCHS} or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {tuple(SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                r = run_cell(arch, shape_name, multi)
                results.append(r)
                tag = f"{arch:>22s} {shape_name:<12s} " \
                      f"{'multi ' if multi else 'single'}"
                if r["status"] == "ok":
                    roof = r["roofline"]
                    print(f"[dryrun] {tag} OK  compile={r['compile_s']:.0f}s "
                          f"flops={roof['flops_global']:.3e} "
                          f"coll={roof['coll_bytes_global']:.3e}B "
                          f"dom={roof['dominant']}", flush=True)
                elif r["status"] == "skip":
                    print(f"[dryrun] {tag} SKIP ({r['reason']})", flush=True)
                else:
                    print(f"[dryrun] {tag} FAIL {r['error']}", flush=True)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {args.out}")
    n_fail = sum(1 for r in results if r["status"] == "FAIL")
    print(f"[dryrun] {len(results)} cells, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
