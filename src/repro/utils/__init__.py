from repro.utils.tree import (clip_by_global_norm, global_norm, param_bytes,
                              param_count, tree_add, tree_cast, tree_scale,
                              tree_sub, tree_zeros_like)

__all__ = [
    "clip_by_global_norm", "global_norm", "param_bytes", "param_count",
    "tree_add", "tree_cast", "tree_scale", "tree_sub", "tree_zeros_like",
]
