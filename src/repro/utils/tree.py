"""Small pytree utilities shared across the framework (no optax/flax)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, a)


def sum_squares(tree) -> jnp.ndarray:
    """fp32 sum of squared entries over every leaf (0. for empty trees)."""
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sum(jnp.stack(leaves)) if leaves else jnp.zeros(())


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum_squares(tree))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda x: x * scale, tree), norm


def param_count(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def param_bytes(tree) -> int:
    return sum(int(x.size * x.dtype.itemsize) for x in jax.tree.leaves(tree))
