"""Dependency-based micro-benchmarking of fixed-latency stall counts (§4.3).

The machine's latency table is undocumented (private), so — exactly like the
paper does against real Ampere silicon — we construct use-definition TSASS
instruction pairs and *gradually lower the producer's stall count until the
consumer observes a stale value*.  The minimum stall count that still yields
the expected output is the instruction's latency.

Also reproduces the paper's negative result: clock-based measurement
(`CS2R SR_CLOCKLO` → our ``SCLK``) underestimates the stall count because
nothing guarantees the timed sequence has completed at the second clock read
(§4.3, Listing 7: 2.6 measured vs 4 true for IADD3).
"""

from __future__ import annotations

from typing import Dict, Iterable, Optional, Tuple

from repro.core.isa import Control, Instruction, SCALAR_OPS
from repro.core.machine import Machine, dataflow_reference
from repro.core.parser import analyze_operands

MAX_PROBE_STALL = 32

# Table-1 scope: "common integer operations, because they are frequently
# involved in address calculation".  VPU/MXU latencies are left to the
# inference pass — this split is what produces the paper's Fig. 7 db/infer
# fractions.
# SADDX (the IADD3.X analogue) is deliberately absent: the paper reports
# the analysis pass *infers* it from schedules instead (§3.2)
DEFAULT_BENCH_OPS: Tuple[str, ...] = tuple(
    o for o in SCALAR_OPS if o != "SADDX")


def _ins(opcode, operands, stall=1, pred=None, wait=(), wbar=None):
    ctrl = Control(wait_mask=frozenset(wait), write_bar=wbar, stall=stall)
    return analyze_operands(Instruction(opcode, list(operands), ctrl, pred))


def _probe_program(opcode: str, stall: int) -> list:
    """``SMOV``-seeded use-def pair: producer under test feeds a store to an
    observable HBM cell (the paper stores to global memory, Listing 6)."""
    wide = opcode.endswith("W")
    dst = "R6.64" if wide else "R6"
    prog = [
        _ins("SMOV", ["R2", "0x7"], stall=MAX_PROBE_STALL),
        _ins("SMOV", ["R4", "0x9"], stall=MAX_PROBE_STALL),
        _ins(opcode, [dst, "R2", "R4"], stall=stall),
        _ins("STV", ["[R90]", "R6"], stall=MAX_PROBE_STALL),
        _ins("CPYOUT.64", ["[OUT0]", "R6"], stall=MAX_PROBE_STALL),
        _ins("EXIT", [], stall=1),
    ]
    return prog


def measure_stall_count(opcode: str, machine: Optional[Machine] = None,
                        max_stall: int = MAX_PROBE_STALL) -> int:
    """Minimum stall count for ``opcode`` on the target machine.

    SMOV bootstraps itself: the very first probe measures SMOV using a
    maximally-stalled producer, which is always safe.
    """
    machine = machine or Machine()
    expected = dataflow_reference(_probe_program(opcode, max_stall))
    lo = None
    for stall in range(max_stall, 0, -1):
        got = machine.run(_probe_program(opcode, stall)).outputs
        if got == expected:
            lo = stall
        else:
            break
    if lo is None:
        raise RuntimeError(f"could not bound stall count for {opcode}")
    return lo


def build_stall_table(opcodes: Iterable[str] = DEFAULT_BENCH_OPS,
                      machine: Optional[Machine] = None) -> Dict[str, int]:
    """The paper's Table 1: opcode -> microbenchmarked stall count."""
    machine = machine or Machine()
    return {op: measure_stall_count(op, machine) for op in opcodes}


def clock_based_estimate(opcode: str = "SADD", n: int = 16,
                         machine: Optional[Machine] = None) -> float:
    """Listing-7-style clock measurement: two SCLK reads around an ``n``-long
    back-to-back sequence, average cycles per instruction.  Underestimates
    (no completion guarantee), motivating the dependency-based method.

    Clock reads are timing-only (an SCLK destination holds ``int(issue)``),
    so this probe runs on ``Machine.issue_times`` instead of the dataflow
    oracle; the dependency probes above must keep using ``run`` — observing
    stale values *is* their measurement principle.
    """
    machine = machine or Machine()
    prog = [_ins("SCLK", ["R2"], stall=2)]
    for i in range(n):
        prog.append(_ins(opcode, [f"R{10 + 2 * i}", "R4", "R6"], stall=1))
    prog.append(_ins("SCLK", ["R8"], stall=2))
    prog.append(_ins("EXIT", [], stall=1))
    issue = machine.issue_times(prog)
    t1 = int(issue[0])        # what the first SCLK wrote to R2
    t2 = int(issue[n + 1])    # ... second SCLK to R8
    return (t2 - t1) / n
