"""State embedding (paper §3.4).

Each instruction becomes a vector of individually-embedded fields,
concatenated: control code (wait-barrier bits, read/write barrier index or
-1 when absent, yield flag, stall count), opcode (binary: memory vs
non-memory, -1 for non-memory), and operands (memory locations mapped to
their index in the memory table and normalized by the table size; registers
mapped through the register table; -1 padding up to the maximum operand
count of the file).  Rows stack into the state matrix; a leading validity
column marks padding rows so a fixed-size CNN can consume programs of any
length.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.analysis import Analysis
from repro.core.isa import Instruction, NUM_SEMAPHORES


# the analysis-independent leading columns of every embedding row:
# valid + 6 wait bits + read/write bar + yield + stall + is_mem + pred.
# The remaining ``analysis.max_operands`` operand columns vary per kernel,
# so cross-kernel consumers (the cost-model featurizer) aggregate over
# exactly this fixed prefix.
FIXED_FEATURES = 1 + NUM_SEMAPHORES + 2 + 1 + 1 + 1 + 1


def fixed_feature_dim() -> int:
    """Width of the kernel-independent embedding-row prefix."""
    return FIXED_FEATURES


def feature_dim(analysis: Analysis) -> int:
    return FIXED_FEATURES + analysis.max_operands


def embed_instruction(ins: Instruction, analysis: Analysis) -> np.ndarray:
    n_mem = max(len(analysis.mem_table), 1)
    n_reg = max(len(analysis.reg_table), 1)
    vec = [1.0]  # validity
    vec += [1.0 if i in ins.ctrl.wait_mask else 0.0
            for i in range(NUM_SEMAPHORES)]
    vec.append(-1.0 if ins.ctrl.read_bar is None else float(ins.ctrl.read_bar))
    vec.append(-1.0 if ins.ctrl.write_bar is None else float(ins.ctrl.write_bar))
    vec.append(1.0 if ins.ctrl.yield_flag else 0.0)
    vec.append(float(ins.ctrl.stall) / 16.0)
    vec.append(1.0 if ins.klass.name == "MEM" else -1.0)
    vec.append(-1.0 if ins.pred is None else (0.0 if ins.predicated_off() else 1.0))
    for k in range(analysis.max_operands):
        if k >= len(ins.operands):
            vec.append(-1.0)
            continue
        op = ins.operands[k]
        if op in analysis.mem_table:
            vec.append(analysis.mem_table[op] / n_mem)
        else:
            # register / immediate: register table index, -1 for immediates
            regs = sorted((ins.defs or frozenset()) | (ins.uses or frozenset()))
            first = op.split(".")[0]
            if first in analysis.reg_table:
                vec.append(analysis.reg_table[first] / n_reg)
            elif regs and first.startswith(("R", "UR")):
                vec.append(analysis.reg_table.get(first, 0) / n_reg)
            else:
                vec.append(-1.0)
    return np.asarray(vec, dtype=np.float32)


def embed_program(program: Sequence[Instruction], analysis: Analysis,
                  n_rows: Optional[int] = None) -> np.ndarray:
    """The state matrix S_i of the assembly game: one row per instruction,
    padded with invalid rows up to ``n_rows``."""
    f = feature_dim(analysis)
    n = len(program)
    rows = n_rows if n_rows is not None else n
    if n > rows:
        raise ValueError(f"program ({n}) longer than embedding rows ({rows})")
    out = np.full((rows, f), -1.0, dtype=np.float32)
    out[:, 0] = 0.0
    for i, ins in enumerate(program):
        out[i] = embed_instruction(ins, analysis)
    return out
