"""Tracing and rendering discovered optimization moves (paper §5.7).

The inference episode is deterministic and seedable; this module ranks its
steps by single-step reward and renders before/after windows like the
paper's Fig. 9 (HMMA scheduled before LDGSTS) and Fig. 13 (LDGSTS hoisted
above predicated-off LDS slots).
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

from repro.core.env import AssemblyGame, StepRecord
from repro.core.isa import Instruction


@dataclasses.dataclass
class Move:
    step: int
    record: StepRecord
    gain_pct: float               # single-step runtime reduction, % of T0
    window_before: List[str]
    window_after: List[str]
    kind: str

    def render(self) -> str:
        arrow = "↑" if self.record.direction == 0 else "↓"
        lines = [f"move #{self.step}: {self.record.moved.opcode} {arrow} "
                 f"({self.gain_pct:+.2f}% of T0)  [{self.kind}]"]
        lines.append("  before:")
        lines += [f"    {ln}" for ln in self.window_before]
        lines.append("  after:")
        lines += [f"    {ln}" for ln in self.window_after]
        return "\n".join(lines)


def _window(program: Sequence[Instruction], pos: int, radius: int = 2):
    lo = max(0, pos - radius)
    hi = min(len(program), pos + radius + 1)
    return [f"{program[i].opcode:<12} {', '.join(map(str, program[i].operands))}"
            + ("  " + program[i].pred if program[i].pred else "")
            for i in range(lo, hi)]


def classify_move(env: AssemblyGame, rec: StepRecord) -> str:
    """Heuristic labels matching the paper's discovered move classes."""
    moved = rec.moved
    p = rec.position
    neighbor_idx = p - 1 if rec.direction == 0 else p + 1
    neighbor = env.original[min(max(neighbor_idx, 0), env.n - 1)]
    if moved.base == "MXM" or neighbor.base == "MXM":
        return "mxu/dma interleave (reuse-cache class, §5.7.1)"
    if neighbor.predicated_off() or moved.predicated_off():
        return "hoist past predicated-off slot (§5.7.2)"
    if moved.base in ("CPYIN", "CPYOUT"):
        return "dma latency hiding"
    return "ilp interleave"


def top_moves(env: AssemblyGame, k: int = 5, radius: int = 2) -> List[Move]:
    """Rank the episode's steps by realized gain; reconstruct windows by
    replaying the recorded swaps on a fresh copy of the original program."""
    program = [ins for ins in env.original]
    slot_pos = {i: idx for i, idx in enumerate(env.slots)}
    moves: List[Move] = []
    for step, rec in enumerate(env.history):
        p = slot_pos[rec.slot]
        q0 = p if rec.direction == 0 else p + 1
        before = _window(program, q0 - 1, radius)
        for _ in range(max(rec.hops, 1)):
            pos = slot_pos[rec.slot]
            q = pos if rec.direction == 0 else pos + 1
            program[q - 1], program[q] = program[q], program[q - 1]
            for s, sp in slot_pos.items():
                if sp == q - 1:
                    slot_pos[s] = q
                elif sp == q:
                    slot_pos[s] = q - 1
        after = _window(program, q - 1, radius)
        gain = (rec.cycles_before - rec.cycles_after) / env.t0 * 100.0
        moves.append(Move(step, rec, gain, before, after,
                          classify_move(env, rec)))
    moves.sort(key=lambda m: -m.gain_pct)
    return moves[:k]


def lingering_fraction(env: AssemblyGame) -> float:
    """The paper observes the agent 'lingering' — repeatedly moving an
    instruction up then down after exhausting useful moves (§5.7.2).
    Fraction of consecutive step pairs that undo each other."""
    h = env.history
    if len(h) < 2:
        return 0.0
    undo = sum(1 for a, b in zip(h, h[1:])
               if a.slot == b.slot and a.direction != b.direction)
    return undo / (len(h) - 1)
