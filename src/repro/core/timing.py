"""Timing-only execution of TSASS programs — the fast reward loop.

:meth:`repro.core.machine.Machine.run` is the *dataflow oracle*: it threads
64-bit hashes through a delayed-commit store so that any dependency
violation corrupts the observable outputs.  Probabilistic testing needs
that; the RL reward only reads ``RunResult.cycles``.  This module
re-implements *just* the scoreboard rules — stall counts, wait-barrier
masks, DMA engines and their queue depths, VMEM ports, MXU issue intervals
and the operand-reuse buffer — over a compact per-instruction record, and
guarantees **bit-exact** agreement with ``Machine.run(...).cycles``
(property-tested in ``tests/test_timing_fast.py``).

Entry points:

* :func:`time_program` (surfaced as ``Machine.time``) — one-shot timing of
  a program, roughly an order of magnitude cheaper per instruction than
  ``run`` (no hash mixing, no register/memory stores);
* :func:`issue_times` (surfaced as ``Machine.issue_times``) — per-
  instruction issue cycles, for clock-style microbenchmarks;
* :class:`ScheduleTimer` — the assembly game's measurement engine.  Built
  once per instruction *identity* set, it checkpoints the full scoreboard
  state every ``checkpoint_every`` positions of the last-timed order, so
  re-timing after an adjacent swap at position ``p`` resumes from the
  nearest checkpoint at or below ``p - 1`` instead of cycle 0.

Like :mod:`repro.core.machine`, this module is machine-side: it may read
the private latency/bandwidth tables.  Optimizer-facing code still must
not import them (DESIGN.md §2.3).
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.isa import Instruction, NUM_SEMAPHORES
from repro.core.machine import (_DMA_BYTES_PER_CYCLE, _DMA_QUEUE_DEPTH,
                                _DMA_SETUP, _LDV_LAT, _MXU_ISSUE_INTERVAL,
                                _MXU_REUSE_INTERVAL, _NUM_IN_ENGINES,
                                _VMEM_PORT_HOLD, _VMEM_PORTS, _dma_bytes)

# Timing kinds: one per distinct scoreboard rule set.  PLAIN covers every
# instruction whose only timing effects are its wait mask and stall count
# (scalar/vector/MXU-free ops, NOP, SCLK, EXIT/BRA, and anything
# predicated off — ``@!PT`` never executes, so no hazards or effects).
_PLAIN, _MXM, _CPYIN, _CPYOUT, _LDV, _STV, _SEMWAIT, _LABEL = range(8)


def time_record(ins: Instruction) -> tuple:
    """The per-instruction timing record, computed once and cached on the
    instruction object (instructions are immutable during games; only their
    order changes — the same contract as ``machine.exec_info``).

    Layout: ``(kind, wait_tuple, step, dma_cycles, write_bar, read_bar,
    reuse_flag, uses_frozenset)`` with ``-1`` for absent barriers.
    """
    rec = getattr(ins, "_trec", None)
    if rec is not None:
        return rec
    base = ins.base
    if base == "LABEL":
        kind = _LABEL
    elif ins.predicated_off():
        kind = _PLAIN
    elif base == "MXM":
        kind = _MXM
    elif base == "CPYIN":
        kind = _CPYIN
    elif base == "CPYOUT":
        kind = _CPYOUT
    elif base == "LDV":
        kind = _LDV
    elif base == "STV":
        kind = _STV
    elif base == "SEMWAIT":
        kind = _SEMWAIT
    else:
        kind = _PLAIN
    ctrl = ins.ctrl
    dma_cycles = (_dma_bytes(ins.opcode) / _DMA_BYTES_PER_CYCLE
                  if kind in (_CPYIN, _CPYOUT) else 0.0)
    rec = (kind,
           tuple(ctrl.wait_mask),
           max(1, ctrl.stall),
           dma_cycles,
           -1 if ctrl.write_bar is None else ctrl.write_bar,
           -1 if ctrl.read_bar is None else ctrl.read_bar,
           (any(".reuse" in op for op in ins.operands)
            if kind == _MXM else False),
           frozenset(ins.uses or ()) if kind == _MXM else frozenset())
    ins._trec = rec
    return rec


class _State:
    """Full scoreboard state between instructions.  Snapshots (``freeze``)
    are the ScheduleTimer's checkpoints; DMA completion queues are pruned
    against the current time when frozen — ``t`` is monotonic, so entries
    at or before it can never influence a later queue-depth stall."""

    __slots__ = ("t", "end", "sem", "in_free", "out_free", "in_q", "out_q",
                 "vp", "mxu_ready", "last_srcs", "dma_since", "next_in")

    def __init__(self):
        self.t = 0.0
        self.end = 0.0
        self.sem = [0.0] * NUM_SEMAPHORES
        self.in_free = [0.0] * _NUM_IN_ENGINES
        self.out_free = 0.0
        self.in_q: List[List[float]] = [[] for _ in range(_NUM_IN_ENGINES)]
        self.out_q: List[float] = []
        self.vp = [0.0] * _VMEM_PORTS
        self.mxu_ready = 0.0
        self.last_srcs: frozenset = frozenset()
        self.dma_since = False
        self.next_in = 0

    def freeze(self) -> tuple:
        t = self.t
        return (t, self.end, tuple(self.sem), tuple(self.in_free),
                self.out_free,
                tuple(tuple(d for d in q if d > t) for q in self.in_q),
                tuple(d for d in self.out_q if d > t),
                tuple(self.vp), self.mxu_ready, self.last_srcs,
                self.dma_since, self.next_in)

    @classmethod
    def thaw(cls, snap: tuple) -> "_State":
        st = cls.__new__(cls)
        (st.t, st.end, sem, in_free, st.out_free, in_q, out_q, vp,
         st.mxu_ready, st.last_srcs, st.dma_since, st.next_in) = snap
        st.sem = list(sem)
        st.in_free = list(in_free)
        st.in_q = [list(q) for q in in_q]
        st.out_q = list(out_q)
        st.vp = list(vp)
        return st


def _advance(st: _State, recs, order, lo: int, hi: int,
             issues: Optional[list] = None) -> None:
    """Advance positions ``[lo, hi)`` of ``order`` (identity indices into
    ``recs``), mutating ``st`` in place.

    Every arithmetic step mirrors ``Machine.run`` operation-for-operation
    so the resulting floats are identical, with one representation change:
    per-engine DMA completion times are nondecreasing, so the queues stay
    sorted and the queue-depth stall (``while len([d for d in q if d > t])
    >= DEPTH: t = min(...)``) reduces to popping the sorted head.
    """
    t = st.t
    end = st.end
    sem = st.sem
    in_free = st.in_free
    out_free = st.out_free
    in_q = st.in_q
    out_q = st.out_q
    vp = st.vp
    mxu_ready = st.mxu_ready
    last_srcs = st.last_srcs
    dma_since = st.dma_since
    next_in = st.next_in

    for x in range(lo, hi):
        kind, waits, step, dma_cycles, wbar, rbar, reuse, uses = \
            recs[order[x]]
        if kind == _LABEL:
            if issues is not None:
                issues.append(t)
            continue

        for s in waits:
            b = sem[s]
            if b > t:
                t = b

        if kind == _PLAIN:
            issue = t

        elif kind == _CPYIN:
            q = in_q[next_in]
            while q and q[0] <= t:
                del q[0]
            while len(q) >= _DMA_QUEUE_DEPTH:
                t = q[0]
                while q and q[0] <= t:
                    del q[0]
            issue = t
            eng = next_in
            next_in = (next_in + 1) % _NUM_IN_ENGINES
            start = issue + _DMA_SETUP
            free = in_free[eng]
            if free > start:
                start = free
            done = start + dma_cycles
            in_free[eng] = done
            q.append(done)
            dma_since = True
            if wbar >= 0 and done > sem[wbar]:
                sem[wbar] = done
            if rbar >= 0 and start > sem[rbar]:
                sem[rbar] = start

        elif kind == _CPYOUT:
            q = out_q
            while q and q[0] <= t:
                del q[0]
            while len(q) >= _DMA_QUEUE_DEPTH:
                t = q[0]
                while q and q[0] <= t:
                    del q[0]
            issue = t
            start = issue + _DMA_SETUP
            if out_free > start:
                start = out_free
            done = start + dma_cycles
            out_free = done
            q.append(done)
            dma_since = True
            if wbar >= 0 and done > sem[wbar]:
                sem[wbar] = done
            if rbar >= 0 and start > sem[rbar]:
                sem[rbar] = start

        elif kind == _LDV or kind == _STV:
            p = 0
            for i in range(1, _VMEM_PORTS):
                if vp[i] < vp[p]:
                    p = i
            free = vp[p]
            if free > t:
                t = free
            vp[p] = t + _VMEM_PORT_HOLD
            issue = t
            if kind == _LDV:
                done = issue + _LDV_LAT
                if wbar >= 0 and done > sem[wbar]:
                    sem[wbar] = done
            else:
                rdone = issue + 2
                if rbar >= 0 and rdone > sem[rbar]:
                    sem[rbar] = rdone

        elif kind == _MXM:
            if mxu_ready > t:
                t = mxu_ready
            issue = t
            if reuse and not dma_since and (uses & last_srcs):
                mxu_ready = issue + _MXU_REUSE_INTERVAL
            else:
                mxu_ready = issue + _MXU_ISSUE_INTERVAL
            last_srcs = uses
            dma_since = False

        else:  # _SEMWAIT
            for b in sem:
                if b > t:
                    t = b
            issue = t

        if issues is not None:
            issues.append(issue)
        t = issue + step
        if t > end:
            end = t

    st.t = t
    st.end = end
    st.out_free = out_free
    st.mxu_ready = mxu_ready
    st.last_srcs = last_srcs
    st.dma_since = dma_since
    st.next_in = next_in


def _finalize(st: _State) -> float:
    """The program's cycle count from a fully-advanced state (matches the
    oracle's ``end = max([end, out_engine_free] + in_engine_free +
    sem_busy)``).  Read-only: the state stays resumable."""
    end = st.end
    if st.out_free > end:
        end = st.out_free
    for v in st.in_free:
        if v > end:
            end = v
    for v in st.sem:
        if v > end:
            end = v
    return float(end)


def time_program(program: Sequence[Instruction]) -> float:
    """Cycle count of ``program`` via the timing-only executor.  Bit-exact
    against ``Machine().run(program).cycles``."""
    recs = [time_record(ins) for ins in program]
    st = _State()
    _advance(st, recs, range(len(recs)), 0, len(recs))
    return _finalize(st)


def issue_times(program: Sequence[Instruction]) -> List[float]:
    """Per-instruction issue cycles (LABELs report the running cycle
    count).  The timing-only route for clock-style measurements: an
    ``SCLK`` destination register holds ``int(issue)``."""
    recs = [time_record(ins) for ins in program]
    st = _State()
    issues: List[float] = []
    _advance(st, recs, range(len(recs)), 0, len(recs), issues=issues)
    return issues


class ScheduleTimer:
    """Incremental, checkpointed timing over permutations of one
    instruction set — the assembly game's measurement engine.

    ``time_ids(order)`` times ``[instructions[i] for i in order]``.  The
    scoreboard state is checkpointed every ``checkpoint_every`` positions
    of the most recently timed order; a new order that shares a prefix
    (an adjacent swap at position ``p`` first differs at ``p - 1``)
    resumes from the nearest checkpoint at or below the first difference
    instead of from cycle 0, and rewrites only the checkpoints it
    invalidates.

    ``recs`` — the stall counts, wait masks, DMA durations and op kinds
    compiled once per instruction identity — is the program representation
    the interpreter loop runs on; positions only index into it.
    """

    def __init__(self, instructions: Sequence[Instruction],
                 checkpoint_every: int = 16):
        if checkpoint_every < 1:
            raise ValueError("checkpoint_every must be >= 1")
        self.recs = [time_record(ins) for ins in instructions]
        self.n = len(self.recs)
        self.k = int(checkpoint_every)
        self._last: Optional[np.ndarray] = None      # last timed order
        self._last_cycles: Optional[float] = None
        self._ckpts: List[tuple] = []                # [j] = state before j*k
        self.resumed_from = 0                        # diagnostics

    def time_ids(self, ids) -> float:
        """Cycles for the order ``ids`` (identity indices).  Bit-exact
        against timing the permuted program from scratch."""
        ids = np.asarray(ids, dtype=np.int64)
        if ids.shape[0] != self.n:
            raise ValueError(
                f"order length {ids.shape[0]} != program length {self.n}")
        if self._last is not None:
            if np.array_equal(ids, self._last):
                self.resumed_from = self.n
                return self._last_cycles
            first = int(np.argmax(ids != self._last))
        else:
            first = 0

        ci = min(first // self.k, len(self._ckpts) - 1)
        if ci < 0:
            st = _State()
            pos = 0
            self._ckpts = []
        else:
            st = _State.thaw(self._ckpts[ci])
            pos = ci * self.k
            del self._ckpts[ci + 1:]
        self.resumed_from = pos

        order = ids.tolist()
        recs = self.recs
        k = self.k
        n = self.n
        while pos < n:
            if pos // k == len(self._ckpts):
                self._ckpts.append(st.freeze())
            nxt = pos + k
            if nxt > n:
                nxt = n
            _advance(st, recs, order, pos, nxt)
            pos = nxt

        self._last = ids.copy()
        self._last_cycles = _finalize(st)
        return self._last_cycles

    def time_many(self, orders) -> List[float]:
        """Cycles for a batch of orders in one pass — the vectorized
        rollout's measurement path.

        The orders of one rollout step are near-permutations of each other
        (every env applied one adjacent swap to a shared-prefix
        trajectory), so they are grouped by sorting on their byte strings:
        lexicographic neighbors share the longest prefixes, which means
        each successive :meth:`time_ids` call resumes from the nearest
        shared checkpoint instead of cycle 0 — the suffix after the first
        divergence is all that gets re-timed.  Results come back in the
        input order; each is bit-exact against timing that order alone.
        """
        orders = [np.asarray(o, dtype=np.int64) for o in orders]
        by_prefix = sorted(range(len(orders)),
                           key=lambda i: orders[i].tobytes())
        out: List[Optional[float]] = [None] * len(orders)
        for i in by_prefix:
            out[i] = self.time_ids(orders[i])
        return out
