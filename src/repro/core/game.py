"""Assembly-game training driver (paper Fig. 3 loop + §4.2 workflow).

``train_on_program`` runs PPO over vectorized copies of the game for one
kernel schedule and returns the best schedule found across the whole run —
"the best optimized cubin found throughout the assembly game is written to
the file system" (§4.2).  Training statistics (episodic return, approximate
KL divergence, policy entropy — the paper's Fig. 8 / Fig. 12 time series) are
collected per update.

The rollout is a single vectorized path bounded by the agent, not the
simulator: observations are written in place into rollout buffers allocated
once per run (``AssemblyGame.write_obs``); every env applies its action
first (``begin_step``) so the step's measurement requests can be served
*batched* through one schedule->cycles memo shared by all envs — distinct
cache misses are timed once by the incremental :class:`ScheduleTimer` (and
optionally on a worker pool) and every other env hits the cache.  Memo
hit/miss totals are surfaced in each ``GameResult.stats`` row.
"""

from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core.env import AssemblyGame
from repro.core.isa import Instruction
from repro.core.machine import Machine
from repro.core.ppo import (PPOConfig, bootstrap_value, compute_gae,
                            greedy_action, init_agent, make_update_fn,
                            sample_action)
from repro.core.timing import ScheduleTimer


@dataclasses.dataclass
class GameResult:
    best_program: List[Instruction]
    best_cycles: float
    baseline_cycles: float
    params: Dict
    stats: List[Dict]
    config: PPOConfig

    @property
    def improvement(self) -> float:
        return (self.baseline_cycles - self.best_cycles) / self.baseline_cycles

    @property
    def speedup(self) -> float:
        return self.baseline_cycles / self.best_cycles


def train_on_program(program: Sequence[Instruction],
                     stall_db: Optional[Dict[str, int]] = None,
                     cfg: Optional[PPOConfig] = None,
                     machine_factory: Callable[[], Machine] = Machine,
                     log_every: int = 1,
                     verbose: bool = False,
                     use_fast_measure: bool = True,
                     measure_workers: Optional[int] = None,
                     measure_cache: Optional[Dict[bytes, float]] = None
                     ) -> GameResult:
    """PPO over ``cfg.num_envs`` vectorized games of one kernel schedule.

    ``use_fast_measure=False`` routes every reward measurement through the
    full dataflow oracle (``Machine.run``) — the pre-fast-path behaviour,
    kept for equivalence tests and benchmarking.  ``measure_workers``
    optionally sizes a thread pool over which distinct measurement cache
    misses are primed concurrently; the pure-Python timer is GIL-bound, so
    this pays off only for timing backends that release the GIL — default
    off.  ``measure_cache`` injects an external schedule->cycles memo (a
    session backend's cross-kernel view); default is a fresh run-local one.
    """
    cfg = cfg or PPOConfig()
    if measure_cache is None:
        measure_cache = {}
    envs = [AssemblyGame(program, stall_db=stall_db,
                         machine=machine_factory(), input_seed=i,
                         episode_length=cfg.episode_length,
                         warm_start=cfg.warm_start,
                         hop_sizes=cfg.hop_sizes,
                         use_fast_measure=use_fast_measure,
                         measure_cache=measure_cache)
            for i in range(cfg.num_envs)]
    n_rows, feat_dim = envs[0].n, envs[0].feature_dim
    num_actions = max(envs[0].num_actions, 2)

    key = jax.random.PRNGKey(cfg.seed)
    key, ik = jax.random.split(key)
    params = init_agent(ik, n_rows, feat_dim, num_actions)
    opt, update_fn = make_update_fn(cfg)
    opt_state = opt.init(params)

    pool = (ThreadPoolExecutor(max_workers=measure_workers)
            if measure_workers and measure_workers > 1 else None)
    # batched re-timing: all envs permute the SAME instruction set, so one
    # step's distinct measurement misses can run through a single dedicated
    # timer whose checkpoints are shared across the whole batch
    # (ScheduleTimer.time_many sorts the orders so lexicographic neighbors
    # resume from each other's prefixes).  A separate timer instance keeps
    # the envs' own incremental trajectories undisturbed.
    batch_timer = (ScheduleTimer(envs[0].original)
                   if pool is None and envs[0]._timer is not None else None)

    for env in envs:
        env.reset()
    ep_returns = [0.0] * cfg.num_envs
    finished_returns: List[float] = []
    stats: List[Dict] = []
    global_step = 0

    # rollout + bootstrap buffers, allocated once and rewritten in place
    T, B = cfg.num_steps, cfg.num_envs
    buf_state = np.zeros((T, B, n_rows, feat_dim), np.float32)
    buf_mask = np.zeros((T, B, num_actions), np.float32)
    buf_action = np.zeros((T, B), np.int32)
    buf_logprob = np.zeros((T, B), np.float32)
    buf_reward = np.zeros((T, B), np.float32)
    buf_done = np.zeros((T, B), np.float32)
    buf_value = np.zeros((T, B), np.float32)
    boot_state = np.zeros((B, n_rows, feat_dim), np.float32)
    keys: List[Optional[bytes]] = [None] * B
    no_act = [False] * B

    try:
        for update in range(cfg.num_updates):
            for t in range(T):
                for b, env in enumerate(envs):
                    env.write_obs(buf_state[t, b], buf_mask[t, b])
                key, sk = jax.random.split(key)
                action, logprob, value = sample_action(
                    params, sk, buf_state[t], buf_mask[t])
                action = np.asarray(action)
                buf_action[t] = action
                buf_logprob[t] = np.asarray(logprob)
                buf_value[t] = np.asarray(value)

                # apply every env's action first, so this step's measurements
                # can be served as one batch through the shared memo
                for b, env in enumerate(envs):
                    env_mask = buf_mask[t, b, :env.num_actions]
                    no_act[b] = env_mask.sum() == 0
                    if no_act[b]:
                        keys[b] = None
                        continue
                    a = int(action[b])
                    if a >= env.num_actions or env_mask[a] == 0:
                        a = int(np.argmax(env_mask))  # defensive fallback
                    keys[b] = env.begin_step(a)

                seen = set()
                owners = []          # first env to request each distinct miss
                for b, kb in enumerate(keys):
                    if kb is not None and kb not in seen:
                        seen.add(kb)
                        owners.append(b)
                if pool is not None and len(owners) > 1:
                    list(pool.map(lambda b: envs[b].prime_measure(), owners))
                elif batch_timer is not None and len(owners) > 1:
                    cycles = batch_timer.time_many(
                        [envs[b].id_at for b in owners])
                    for b, c in zip(owners, cycles):
                        envs[b].publish_measure(c)
                else:
                    for b in owners:
                        envs[b].prime_measure()

                for b, env in enumerate(envs):
                    if no_act[b]:
                        # "no actions available -> episode terminated" (§3.5)
                        reward, done = 0.0, True
                    else:
                        _, reward, done, _ = env.finish_step(want_obs=False)
                    ep_returns[b] += reward
                    buf_reward[t, b] = reward
                    buf_done[t, b] = float(done)
                    if done:
                        finished_returns.append(ep_returns[b])
                        ep_returns[b] = 0.0
                        env.reset()
                global_step += B

            for b, env in enumerate(envs):
                env.write_obs(boot_state[b])
            last_value = bootstrap_value(params, boot_state)
            adv, ret = compute_gae(buf_reward, buf_value, buf_done,
                                   np.asarray(last_value),
                                   cfg.gamma, cfg.gae_lambda)
            batch = {
                "state": buf_state.reshape(T * B, n_rows, feat_dim),
                "mask": buf_mask.reshape(T * B, num_actions),
                "action": buf_action.reshape(T * B),
                "logprob": buf_logprob.reshape(T * B),
                "adv": np.asarray(adv).reshape(T * B),
                "ret": np.asarray(ret).reshape(T * B),
                "value": buf_value.reshape(T * B),
            }
            key, uk = jax.random.split(key)
            params, opt_state, ustats = update_fn(params, opt_state, batch, uk)

            if update % log_every == 0:
                recent = finished_returns[-10 * cfg.num_envs:]
                measure_calls = sum(e.measure_calls for e in envs)
                memo_hits = sum(e.memo_hits for e in envs)
                row = {
                    "update": update,
                    "global_step": global_step,
                    "episodic_return": float(np.mean(recent)) if recent else 0.0,
                    "approx_kl": float(ustats.approx_kl),
                    "entropy": float(ustats.entropy),
                    "policy_loss": float(ustats.policy_loss),
                    "value_loss": float(ustats.value_loss),
                    "clip_frac": float(ustats.clip_frac),
                    "best_cycles": min(env.best_cycles for env in envs),
                    # reward-loop memo totals (cumulative across the run)
                    "measure_calls": measure_calls,
                    "memo_hits": memo_hits,
                    "memo_misses": sum(e.memo_misses for e in envs),
                    "memo_hit_rate": memo_hits / max(measure_calls, 1),
                    "time": time.time(),
                }
                stats.append(row)
                if verbose:
                    print(f"[game] upd={update} step={global_step} "
                          f"ret={row['episodic_return']:.3f} "
                          f"kl={row['approx_kl']:.4f} ent={row['entropy']:.3f} "
                          f"best={row['best_cycles']:.0f} "
                          f"memo={row['memo_hit_rate']:.2f}")

    finally:
        # release measurement workers even when an update raises
        if pool is not None:
            pool.shutdown(wait=True)
    best_env = min(envs, key=lambda e: e.best_cycles)
    return GameResult(
        best_program=[ins.copy() for ins in best_env.best_program],
        best_cycles=best_env.best_cycles,
        baseline_cycles=envs[0].t0,
        params=params,
        stats=stats,
        config=cfg,
    )


def run_inference(program: Sequence[Instruction], params: Dict,
                  stall_db: Optional[Dict[str, int]] = None,
                  episode_length: int = 32,
                  machine: Optional[Machine] = None) -> AssemblyGame:
    """Deterministic (greedy, seedable) inference replay — the paper's §5.7
    mode for tracing the discovered optimization moves."""
    env = AssemblyGame(program, stall_db=stall_db, machine=machine,
                       episode_length=episode_length)
    obs = env.reset()
    for _ in range(episode_length):
        mask = obs["mask"]
        if mask.sum() == 0:
            break
        action, _ = greedy_action(params, obs["state"][None], mask[None])
        a = int(np.asarray(action)[0])
        if mask[a] == 0:
            a = int(np.argmax(mask))
        obs, _, done, _ = env.step(a)
        if done:
            break
    return env
