"""Assembly-game training driver (paper Fig. 3 loop + §4.2 workflow).

``train_on_program`` runs PPO over vectorized copies of the game for one
kernel schedule and returns the best schedule found across the whole run —
"the best optimized cubin found throughout the assembly game is written to
the file system" (§4.2).  Training statistics (episodic return, approximate
KL divergence, policy entropy — the paper's Fig. 8 / Fig. 12 time series) are
collected per update.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core.env import AssemblyGame
from repro.core.isa import Instruction
from repro.core.machine import Machine
from repro.core.ppo import (PPOConfig, compute_gae, greedy_action, init_agent,
                            make_update_fn, policy_value, sample_action)


@dataclasses.dataclass
class GameResult:
    best_program: List[Instruction]
    best_cycles: float
    baseline_cycles: float
    params: Dict
    stats: List[Dict]
    config: PPOConfig

    @property
    def improvement(self) -> float:
        return (self.baseline_cycles - self.best_cycles) / self.baseline_cycles

    @property
    def speedup(self) -> float:
        return self.baseline_cycles / self.best_cycles


def _batch_obs(obs_list):
    return (np.stack([o["state"] for o in obs_list]),
            np.stack([o["mask"] for o in obs_list]))


def train_on_program(program: Sequence[Instruction],
                     stall_db: Optional[Dict[str, int]] = None,
                     cfg: Optional[PPOConfig] = None,
                     machine_factory: Callable[[], Machine] = Machine,
                     log_every: int = 1,
                     verbose: bool = False) -> GameResult:
    cfg = cfg or PPOConfig()
    envs = [AssemblyGame(program, stall_db=stall_db,
                         machine=machine_factory(), input_seed=i,
                         episode_length=cfg.episode_length,
                         warm_start=cfg.warm_start,
                         hop_sizes=cfg.hop_sizes)
            for i in range(cfg.num_envs)]
    n_rows, feat_dim = envs[0].n, envs[0].feature_dim
    num_actions = max(envs[0].num_actions, 2)

    key = jax.random.PRNGKey(cfg.seed)
    key, ik = jax.random.split(key)
    params = init_agent(ik, n_rows, feat_dim, num_actions)
    opt, update_fn = make_update_fn(cfg)
    opt_state = opt.init(params)

    obs_list = [env.reset() for env in envs]
    ep_returns = [0.0] * cfg.num_envs
    finished_returns: List[float] = []
    stats: List[Dict] = []
    global_step = 0

    for update in range(cfg.num_updates):
        T, B = cfg.num_steps, cfg.num_envs
        buf_state = np.zeros((T, B, n_rows, feat_dim), np.float32)
        buf_mask = np.zeros((T, B, num_actions), np.float32)
        buf_action = np.zeros((T, B), np.int32)
        buf_logprob = np.zeros((T, B), np.float32)
        buf_reward = np.zeros((T, B), np.float32)
        buf_done = np.zeros((T, B), np.float32)
        buf_value = np.zeros((T, B), np.float32)

        for t in range(T):
            state, mask = _batch_obs(obs_list)
            if mask.shape[1] < num_actions:  # degenerate tiny action spaces
                mask = np.pad(mask, ((0, 0), (0, num_actions - mask.shape[1])))
            key, sk = jax.random.split(key)
            action, logprob, value = sample_action(params, sk, state, mask)
            action = np.asarray(action)
            buf_state[t], buf_mask[t] = state, mask
            buf_action[t] = action
            buf_logprob[t] = np.asarray(logprob)
            buf_value[t] = np.asarray(value)
            for b, env in enumerate(envs):
                env_mask = mask[b, :env.num_actions]
                if env_mask.sum() == 0:
                    obs, reward, done = env.reset(), 0.0, True
                else:
                    a = int(action[b])
                    if a >= env.num_actions or env_mask[a] == 0:
                        a = int(np.argmax(env_mask))  # defensive fallback
                    obs, reward, done, _ = env.step(a)
                ep_returns[b] += reward
                buf_reward[t, b] = reward
                buf_done[t, b] = float(done)
                if done:
                    finished_returns.append(ep_returns[b])
                    ep_returns[b] = 0.0
                    obs = env.reset()
                obs_list[b] = obs
            global_step += B

        state, mask = _batch_obs(obs_list)
        if mask.shape[1] < num_actions:
            mask = np.pad(mask, ((0, 0), (0, num_actions - mask.shape[1])))
        _, last_value = jax.jit(policy_value)(params, state)
        adv, ret = compute_gae(buf_reward, buf_value, buf_done,
                               np.asarray(last_value),
                               cfg.gamma, cfg.gae_lambda)
        batch = {
            "state": buf_state.reshape(T * B, n_rows, feat_dim),
            "mask": buf_mask.reshape(T * B, num_actions),
            "action": buf_action.reshape(T * B),
            "logprob": buf_logprob.reshape(T * B),
            "adv": np.asarray(adv).reshape(T * B),
            "ret": np.asarray(ret).reshape(T * B),
            "value": buf_value.reshape(T * B),
        }
        key, uk = jax.random.split(key)
        params, opt_state, ustats = update_fn(params, opt_state, batch, uk)

        if update % log_every == 0:
            recent = finished_returns[-10 * cfg.num_envs:]
            row = {
                "update": update,
                "global_step": global_step,
                "episodic_return": float(np.mean(recent)) if recent else 0.0,
                "approx_kl": float(ustats.approx_kl),
                "entropy": float(ustats.entropy),
                "policy_loss": float(ustats.policy_loss),
                "value_loss": float(ustats.value_loss),
                "clip_frac": float(ustats.clip_frac),
                "best_cycles": min(env.best_cycles for env in envs),
                "time": time.time(),
            }
            stats.append(row)
            if verbose:
                print(f"[game] upd={update} step={global_step} "
                      f"ret={row['episodic_return']:.3f} "
                      f"kl={row['approx_kl']:.4f} ent={row['entropy']:.3f} "
                      f"best={row['best_cycles']:.0f}")

    best_env = min(envs, key=lambda e: e.best_cycles)
    return GameResult(
        best_program=[ins.copy() for ins in best_env.best_program],
        best_cycles=best_env.best_cycles,
        baseline_cycles=envs[0].t0,
        params=params,
        stats=stats,
        config=cfg,
    )


def run_inference(program: Sequence[Instruction], params: Dict,
                  stall_db: Optional[Dict[str, int]] = None,
                  episode_length: int = 32,
                  machine: Optional[Machine] = None) -> AssemblyGame:
    """Deterministic (greedy, seedable) inference replay — the paper's §5.7
    mode for tracing the discovered optimization moves."""
    env = AssemblyGame(program, stall_db=stall_db, machine=machine,
                       episode_length=episode_length)
    obs = env.reset()
    for _ in range(episode_length):
        mask = obs["mask"]
        if mask.sum() == 0:
            break
        action, _ = greedy_action(params, obs["state"][None], mask[None])
        a = int(np.asarray(action)[0])
        if mask[a] == 0:
            a = int(np.argmax(mask))
        obs, _, done, _ = env.step(a)
        if done:
            break
    return env
