"""TSASS: a TPU-flavored, statically-scheduled native assembly.

This is the adaptation layer of CuAsmRL's object of study (NVIDIA SASS,
undocumented, statically scheduled, §2.3 of the paper) to the TPU TensorCore:
an in-order scalar core issuing instructions with compiler-managed hazards
(stall counts), asynchronous DMA engines (HBM<->VMEM) signalling completion
through semaphores (the exact analogue of SASS write-barriers), a systolic
MXU and a VPU. See DESIGN.md §2 for the full SASS->TSASS mapping.

An instruction line round-trips through :mod:`repro.core.parser` as::

    [B--1---:R-:W2:-:S04] CPYIN.128 [R219+0x4000], desc[UR16][R10.64] ; // tile=a:0 grp=3

with the same control-code structure as SASS (§2.3): wait-barrier mask,
read barrier, write barrier, yield flag, stall count.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional


NUM_SEMAPHORES = 6  # SASS exposes barriers 0..5; we keep the same budget.


class OpClass(enum.Enum):
    SCALAR = "scalar"      # fixed-latency scalar core (address math)  ~ IADD3/IMAD/MOV
    VECTOR = "vector"      # fixed-latency VPU lanes                   ~ FFMA/FADD/MUFU
    MXU = "mxu"            # systolic matmul issue                     ~ HMMA
    MEM = "mem"            # variable-latency memory ops               ~ LDG/LDGSTS/STG/LDS/STS
    SYNC = "sync"          # scheduling fences: labels, waits, branches
    MISC = "misc"          # NOP, clock reads


# ---------------------------------------------------------------------------
# Opcode tables.
#
# Only the *classification* below is public to the optimizer.  The actual
# latency/bandwidth numbers live privately in repro.core.machine — exactly as
# SASS latencies are undocumented and must be microbenchmarked/inferred
# (paper §3.2, §4.3).
# ---------------------------------------------------------------------------

SCALAR_OPS = (
    "SADD",     # IADD3 analogue (address add)
    "SADDX",    # IADD3.X analogue (add with carry chain)
    "SMUL",     # IMAD analogue
    "SMULW",    # IMAD.WIDE analogue (64-bit result -> pair dst)
    "SMOV",     # MOV analogue
    "SLEA",     # LEA analogue (shift-add)
    "SSEL",     # SEL analogue
    "SMIN",     # IMNMX analogue
    "SSHL",     # shift
)

VECTOR_OPS = (
    "VADD",
    "VMUL",
    "VFMA",
    "VMAX",
    "VSUB",
    "VEXP",     # MUFU.EX2 analogue (transcendental, slower lane)
    "VRSQ",     # MUFU.RSQ analogue
    "VRECIP",
)

MXU_OPS = ("MXM",)  # HMMA analogue: one 128x128x128 MXU pass

# Memory ops.  CPYIN is the LDGSTS analogue (async DMA HBM->VMEM, bypassing
# registers); CPYOUT the STG analogue (VMEM->HBM DMA); LDV/STV the LDS/STS
# analogues (VMEM<->vector registers).
MEM_LOAD_OPS = ("CPYIN", "LDV")
MEM_STORE_OPS = ("CPYOUT", "STV")
MEM_OPS = MEM_LOAD_OPS + MEM_STORE_OPS

SYNC_OPS = ("SEMWAIT", "LABEL", "BRA", "EXIT")
MISC_OPS = ("NOP", "SCLK")  # SCLK ~ CS2R SR_CLOCKLO (cycle counter read)

# The action space of the assembly game: "memory load/store instructions,
# such as LDG, LDGSTS, and STG" (paper §3.5).
SCHEDULABLE_OPS = frozenset(MEM_OPS)

_CLASS_OF = {}
for _o in SCALAR_OPS:
    _CLASS_OF[_o] = OpClass.SCALAR
for _o in VECTOR_OPS:
    _CLASS_OF[_o] = OpClass.VECTOR
for _o in MXU_OPS:
    _CLASS_OF[_o] = OpClass.MXU
for _o in MEM_OPS:
    _CLASS_OF[_o] = OpClass.MEM
for _o in SYNC_OPS:
    _CLASS_OF[_o] = OpClass.SYNC
for _o in MISC_OPS:
    _CLASS_OF[_o] = OpClass.MISC


def base_opcode(opcode: str) -> str:
    """Strip modifiers: ``CPYIN.128.BYPASS`` -> ``CPYIN``.

    Like SASS, modifiers can change behaviour/latency (paper §5.2 notes
    IMAD vs IMAD.WIDE differ), so the *full* opcode is the latency-table key,
    while the base opcode decides the class.
    """
    return opcode.split(".")[0]


def opclass(opcode: str) -> OpClass:
    try:
        return _CLASS_OF[base_opcode(opcode)]
    except KeyError as e:
        raise ValueError(f"unknown TSASS opcode: {opcode!r}") from e


def is_memory_op(opcode: str) -> bool:
    return base_opcode(opcode) in MEM_OPS


def is_fixed_latency(opcode: str) -> bool:
    return opclass(opcode) in (OpClass.SCALAR, OpClass.VECTOR, OpClass.MXU)


def is_boundary(opcode: str) -> bool:
    """Instructions that delimit basic blocks / cannot be crossed (§3.5)."""
    return opclass(opcode) is OpClass.SYNC


@dataclasses.dataclass
class Control:
    """SASS-style control code ``[B......:R.:W.:Y:S..]`` (paper §2.3)."""

    wait_mask: frozenset = frozenset()       # barrier indices this instr waits on
    read_bar: Optional[int] = None           # read-barrier it sets (operand protection)
    write_bar: Optional[int] = None          # write-barrier it sets (result protection)
    yield_flag: bool = False
    stall: int = 1                           # cycles before the next instr may issue

    def copy(self) -> "Control":
        return Control(self.wait_mask, self.read_bar, self.write_bar,
                       self.yield_flag, self.stall)

    def text(self) -> str:
        bits = "".join(str(i) if i in self.wait_mask else "-"
                       for i in range(NUM_SEMAPHORES))
        r = "-" if self.read_bar is None else str(self.read_bar)
        w = "-" if self.write_bar is None else str(self.write_bar)
        y = "Y" if self.yield_flag else "-"
        return f"[B{bits}:R{r}:W{w}:{y}:S{self.stall:02d}]"


@dataclasses.dataclass
class Instruction:
    """One TSASS instruction.

    ``operands`` keep their surface syntax (``R10.64``, ``[R219+0x4000]``,
    ``desc[UR16][R44.64]``, immediates).  Parsed def/use sets are computed by
    :mod:`repro.core.parser` (operand expansion per the paper's Eq. 2) and
    cached on the instance.

    ``tile`` is the memory-alias token carried through lowering as a comment
    (``// tile=a:3``): ``(space, index)`` with index ``-1`` meaning unknown
    (conservatively aliases everything in its space).  ``group`` marks
    consecutive-DMA groups whose relative order is pinned (the paper's
    "additional dependencies" heuristic for LDGSTS sequences, §3.5).
    """

    opcode: str
    operands: list
    ctrl: Control = dataclasses.field(default_factory=Control)
    pred: Optional[str] = None               # "@P0" / "@!PT" style guard
    tile: Optional[tuple] = None             # (space, tile_index)
    group: Optional[int] = None              # consecutive-DMA group id
    comment: str = ""

    # --- caches filled by parser.analyze_operands -------------------------
    defs: Optional[frozenset] = None         # registers written
    uses: Optional[frozenset] = None         # registers read (incl. addresses)

    def copy(self) -> "Instruction":
        return Instruction(self.opcode, list(self.operands), self.ctrl.copy(),
                           self.pred, self.tile, self.group, self.comment,
                           self.defs, self.uses)

    @property
    def base(self) -> str:
        return base_opcode(self.opcode)

    @property
    def klass(self) -> OpClass:
        return opclass(self.opcode)

    def is_schedulable(self) -> bool:
        return self.base in SCHEDULABLE_OPS

    def predicated_off(self) -> bool:
        """``@!PT`` guards are constant-false: never executes (paper §5.7.2)."""
        return self.pred == "@!PT"

    def text(self) -> str:
        pred = f"{self.pred} " if self.pred else ""
        ops = ", ".join(str(o) for o in self.operands)
        meta = []
        if self.tile is not None:
            meta.append(f"tile={self.tile[0]}:{self.tile[1]}")
        if self.group is not None:
            meta.append(f"grp={self.group}")
        if self.comment:
            meta.append(self.comment)
        tail = f" ; // {' '.join(meta)}" if meta else " ;"
        return f"{self.ctrl.text()} {pred}{self.opcode} {ops}{tail}".rstrip()

    def __str__(self) -> str:  # pragma: no cover - convenience
        return self.text()


def program_text(program) -> str:
    return "\n".join(ins.text() for ins in program)
