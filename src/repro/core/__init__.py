"""CuAsmRL core: the paper's contribution as a composable library.

Pipeline: parse/lower a TSASS program -> static analysis (§3.2) ->
assembly game env (§3.3–3.6) -> PPO (§3.7) -> optimized schedule + trace.
"""

from repro.core.analysis import Analysis, analyze
from repro.core.env import AssemblyGame, can_swap
from repro.core.faults import (FaultSpec, FaultyMachine, HardFault,
                               MeasureError, MeasureTimeout,
                               schedule_fingerprint)
from repro.core.game import GameResult, run_inference, train_on_program
from repro.core.isa import Control, Instruction, program_text
from repro.core.machine import Machine, dataflow_reference
from repro.core.microbench import build_stall_table, clock_based_estimate
from repro.core.parser import parse_line, parse_program
from repro.core.ppo import PPOConfig

__all__ = [
    "Analysis", "analyze", "AssemblyGame", "can_swap", "GameResult",
    "run_inference", "train_on_program", "Control", "Instruction",
    "program_text", "Machine", "dataflow_reference", "build_stall_table",
    "clock_based_estimate", "parse_line", "parse_program", "PPOConfig",
    "FaultSpec", "FaultyMachine", "HardFault", "MeasureError",
    "MeasureTimeout", "schedule_fingerprint",
]
