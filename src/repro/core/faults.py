"""Deterministic fault injection over the TSASS machine.

The paper's reward channel is *real hardware*: mutated SASS schedules are
executed on an A100 and timed (§3.6), a channel that in practice raises
(driver hiccups), hangs (wedged kernels), crashes outright on illegal
schedules, and returns heavy-tailed timings — which is exactly why §4
leans on repeated measurement and probabilistic testing.  Our simulated
machine has none of these failure modes, so the retry / robust-statistics
/ circuit-breaker machinery in :mod:`repro.sched.resilience` would be
untestable.  :class:`FaultyMachine` closes that gap: a seeded,
deterministic wrapper over any :class:`~repro.core.machine.Machine` that
injects configurable faults into every measurement call:

* **transient raises** — :class:`MeasureError` with probability
  ``transient_rate`` (the flaky-channel mode retries must absorb);
* **hangs** — with probability ``hang_rate`` the call sleeps ``hang_s``
  wall seconds before returning, so a per-measure deadline
  (:class:`repro.sched.resilience.RetryPolicy.timeout_s`) can observe a
  latency spike past its budget;
* **hard crashes** — schedules whose :func:`schedule_fingerprint` is in
  ``crash_fingerprints`` always raise :class:`HardFault` (the
  kernel-kills-the-GPU mode retries must *not* absorb);
* **timing outliers** — with probability ``outlier_rate`` the returned
  cycle count is inflated by a Pareto-tailed factor (the
  noisy-neighbour mode median-of-k + MAD rejection must absorb).

Faults draw from one seeded ``random.Random`` stream advanced per
measurement, so a given (seed, call sequence) replays bit-identically —
every resilience path is testable without real hardware.  The wrapper
overrides ``run``, so the assembly game's fast-measure precondition
(``type(machine).run is Machine.run``) correctly falls back to the oracle
path and the fault channel is actually exercised.
"""

from __future__ import annotations

import dataclasses
import hashlib
import random
import time
from typing import FrozenSet, Iterable, List, Optional, Sequence

from repro.core.isa import Instruction
from repro.core.machine import Machine, RunResult


class MeasureError(RuntimeError):
    """A transient measurement failure — the channel flaked, the value is
    lost, retrying the same schedule may well succeed."""


class MeasureTimeout(MeasureError):
    """A measurement exceeded its wall-clock deadline (simulated hang).
    A subclass of :class:`MeasureError` because the retry policy treats
    both the same way: discard, back off, retry."""


class HardFault(RuntimeError):
    """A non-transient measurement failure — the schedule itself crashes
    the machine.  Retrying the identical schedule is futile; the
    resilience layer counts these toward its circuit breaker instead."""


def schedule_fingerprint(program: Sequence[Instruction]) -> str:
    """Stable, permutation-invariant fingerprint of a program.

    Hashes the *sorted multiset* of ``opcode operands`` lines (``.reuse``
    hints stripped — they are scheduler-assigned adjacency metadata, not
    identity), so every reordering the assembly game can reach from one
    lowered kernel shares a fingerprint.  That makes a fingerprint the
    identity of a *(kernel, config, scenario)* measurement cell: pinning
    one in :attr:`FaultSpec.crash_fingerprints` crashes that cell's every
    measurement — baseline, autotune grid point, search mutation and
    verification alike — without touching any sibling cell.
    """
    h = hashlib.sha256()
    for line in sorted(
            f"{ins.opcode} {','.join(ins.operands)}".replace(".reuse", "")
            for ins in program):
        h.update(line.encode())
        h.update(b"\n")
    return h.hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Configuration of one fault channel (see module docstring).

    Rates are independent per-measurement probabilities; a rate of 0
    draws nothing from the RNG stream, so enabling one mode never shifts
    another mode's deterministic sequence.
    """

    seed: int = 0
    transient_rate: float = 0.0
    hang_rate: float = 0.0
    hang_s: float = 0.0
    crash_fingerprints: FrozenSet[str] = frozenset()
    outlier_rate: float = 0.0
    outlier_scale: float = 10.0      # tail weight of the injected spike

    def __post_init__(self):
        object.__setattr__(self, "crash_fingerprints",
                           frozenset(self.crash_fingerprints))
        for name in ("transient_rate", "hang_rate", "outlier_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")

    def with_crashes(self, fingerprints: Iterable[str]) -> "FaultSpec":
        return dataclasses.replace(
            self, crash_fingerprints=frozenset(fingerprints)
            | self.crash_fingerprints)


class FaultyMachine(Machine):
    """A :class:`Machine` whose measurement calls fault per ``spec``.

    Wraps ``machine`` (a stock noise-free :class:`Machine` by default);
    when no fault fires, results are byte-identical to the wrapped
    machine's — which is what lets a resilient campaign over a faulty
    fleet reproduce a fault-free campaign bit-exactly once every
    transient has been retried away.  ``fault_counters`` tallies injected
    faults by mode for tests and benchmark reporting.
    """

    def __init__(self, spec: Optional[FaultSpec] = None,
                 machine: Optional[Machine] = None):
        inner = machine if machine is not None else Machine()
        super().__init__(noise=getattr(inner, "noise", 0.0), seed=0)
        self.inner = inner
        self.spec = spec if spec is not None else FaultSpec()
        self._frng = random.Random(self.spec.seed)
        self.fault_counters = {"measures": 0, "transients": 0, "hangs": 0,
                               "crashes": 0, "outliers": 0}

    def _inject(self, program: Sequence[Instruction]) -> None:
        spec = self.spec
        self.fault_counters["measures"] += 1
        if spec.crash_fingerprints \
                and schedule_fingerprint(program) in spec.crash_fingerprints:
            self.fault_counters["crashes"] += 1
            raise HardFault(
                f"schedule {schedule_fingerprint(program)} crashes the "
                f"machine (injected hard fault)")
        if spec.hang_rate and self._frng.random() < spec.hang_rate:
            self.fault_counters["hangs"] += 1
            time.sleep(spec.hang_s)
        if spec.transient_rate and self._frng.random() < spec.transient_rate:
            self.fault_counters["transients"] += 1
            raise MeasureError("transient measurement failure (injected)")

    def _maybe_outlier(self, cycles: float) -> float:
        spec = self.spec
        if spec.outlier_rate and self._frng.random() < spec.outlier_rate:
            self.fault_counters["outliers"] += 1
            # Pareto(alpha=1.5) - 1 >= 0 with a heavy right tail: rare
            # measurements come back inflated by orders of magnitude
            cycles *= 1.0 + spec.outlier_scale * \
                (self._frng.paretovariate(1.5) - 1.0)
        return cycles

    # -- the Machine measurement surface -------------------------------------

    def time(self, program: Sequence[Instruction],
             input_seed: int = 0) -> float:
        self._inject(program)
        return self._maybe_outlier(self.inner.time(program, input_seed))

    def run(self, program: Sequence[Instruction], input_seed: int = 0,
            _serialize: bool = False) -> RunResult:
        self._inject(program)
        res = self.inner.run(program, input_seed=input_seed,
                             _serialize=_serialize)
        cycles = self._maybe_outlier(res.cycles)
        if cycles != res.cycles:
            res = dataclasses.replace(res, cycles=cycles)
        return res

    def issue_times(self, program: Sequence[Instruction]) -> List[float]:
        self._inject(program)
        return self.inner.issue_times(program)
