"""TSASS parser: text <-> Instruction round-trip + operand def/use analysis.

Reproduces the paper's §3.2 "CuAsmRL has a parser to decode SASS
instructions": it separates control codes / opcode / operands, and *expands*
``.64`` register-pair operands to recover the true dependencies, using the
paper's Eq. (2)::

    base = reg_no // 2
    mod  = reg_no %  2
    flip = 1 - mod
    adj  = base * 2 + flip

so ``R10.64`` touches {R10, R11} and ``R11.64`` touches {R10, R11}.
"""

from __future__ import annotations

import re
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.core.isa import (Control, Instruction, MEM_STORE_OPS,
                            NUM_SEMAPHORES, is_memory_op, opclass)

_CTRL_RE = re.compile(
    r"\[B(?P<mask>[-0-9]{%d}):R(?P<r>[-0-9]):W(?P<w>[-0-9]):(?P<y>[Y-]):S(?P<s>\d+)\]"
    % NUM_SEMAPHORES
)
_REG_RE = re.compile(r"\b(U?R)(\d+|Z)(\.64|\.reuse)?\b")
_PRED_RE = re.compile(r"^@!?P(?:T|\d+)$")
_META_TILE_RE = re.compile(r"tile=([A-Za-z_]\w*):(-?\d+)")
_META_GRP_RE = re.compile(r"grp=(\d+)")


def adjacent_register(reg_no: int) -> int:
    """Paper Eq. (2): the other half of a ``.64`` register pair."""
    base = reg_no // 2
    mod = reg_no % 2
    flip = 1 - mod
    return base * 2 + flip


def expand_register(token: str) -> frozenset:
    """Expand one register token to the set of architectural registers it
    touches.  ``RZ``/``URZ`` are the zero registers (no dependency), and a
    ``.64`` suffix pulls in the adjacent register (paper §3.2)."""
    regs = set()
    for m in _REG_RE.finditer(token):
        bank, num, suffix = m.group(1), m.group(2), m.group(3)
        if num == "Z":
            continue  # RZ reads as zero: not a dependency
        n = int(num)
        regs.add(f"{bank}{n}")
        if suffix == ".64":
            regs.add(f"{bank}{adjacent_register(n)}")
    return frozenset(regs)


def _split_operands(text: str) -> List[str]:
    """Split on commas that are not inside brackets."""
    out, depth, cur = [], 0, []
    for ch in text:
        if ch == "[":
            depth += 1
        elif ch == "]":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    last = "".join(cur).strip()
    if last:
        out.append(last)
    return out


def parse_control(text: str) -> Control:
    m = _CTRL_RE.match(text)
    if not m:
        raise ValueError(f"bad control code: {text!r}")
    mask = frozenset(int(c) for c in m.group("mask") if c != "-")
    r = None if m.group("r") == "-" else int(m.group("r"))
    w = None if m.group("w") == "-" else int(m.group("w"))
    return Control(mask, r, w, m.group("y") == "Y", int(m.group("s")))


def parse_line(line: str) -> Optional[Instruction]:
    """Parse one TSASS text line; returns None for blank/comment lines."""
    line = line.strip()
    if not line or line.startswith("//"):
        return None
    body, _, meta = line.partition("//")
    body = body.strip().rstrip(";").strip()

    m = _CTRL_RE.match(body)
    if m:
        ctrl = parse_control(body[: m.end()])
        body = body[m.end():].strip()
    else:
        ctrl = Control()

    pred = None
    parts = body.split(None, 1)
    if parts and _PRED_RE.match(parts[0]):
        pred = parts[0]
        body = parts[1] if len(parts) > 1 else ""
        parts = body.split(None, 1)
    if not parts:
        raise ValueError(f"no opcode in line: {line!r}")
    opcode = parts[0]
    opclass(opcode)  # reject unknown opcodes early
    operands = _split_operands(parts[1]) if len(parts) > 1 else []

    tile = None
    group = None
    meta = meta.strip()
    if meta:
        tm = _META_TILE_RE.search(meta)
        if tm:
            tile = (tm.group(1), int(tm.group(2)))
        gm = _META_GRP_RE.search(meta)
        if gm:
            group = int(gm.group(1))
    ins = Instruction(opcode, operands, ctrl, pred, tile, group)
    analyze_operands(ins)
    return ins


def parse_program(text: str) -> List[Instruction]:
    out = []
    for line in text.splitlines():
        ins = parse_line(line)
        if ins is not None:
            out.append(ins)
    return out


# ---------------------------------------------------------------------------
# def/use analysis
# ---------------------------------------------------------------------------

def _operand_regs(op: str) -> frozenset:
    return expand_register(op)


def analyze_operands(ins: Instruction) -> Instruction:
    """Fill ``ins.defs`` / ``ins.uses``.

    Conventions (mirroring SASS):
      * first operand is the destination for scalar/vector/MXU/LDV ops;
      * memory operands ``[...]`` contribute their *address registers* as
        uses, never as defs (the memory cell itself is tracked via ``tile``);
      * store-class ops (STV/CPYOUT) and CPYIN have no register destination;
      * predicates ``@P3`` read P3 (``PT`` is constant-true, no dep);
      * MXM accumulates in place: destination is also a use.
    """
    defs: set = set()
    uses: set = set()
    if ins.pred and ins.pred.strip("@!") not in ("PT",):
        uses.add(ins.pred.strip("@!"))

    base = ins.base
    has_reg_dst = (
        ins.operands
        and not ins.operands[0].startswith("[")
        and base not in MEM_STORE_OPS
        and base != "CPYIN"
        and base not in ("SEMWAIT", "LABEL", "BRA", "EXIT", "NOP")
    )
    for i, op in enumerate(ins.operands):
        regs = _operand_regs(op)
        if op.startswith("["):
            uses |= regs  # address computation
        elif i == 0 and has_reg_dst:
            defs |= regs
            if base == "MXM":  # accumulator: read-modify-write
                uses |= regs
        else:
            uses |= regs
    ins.defs = frozenset(defs)
    ins.uses = frozenset(uses)
    return ins


def analyze_program(program: Sequence[Instruction]) -> List[Instruction]:
    for ins in program:
        if ins.defs is None or ins.uses is None:
            analyze_operands(ins)
    return list(program)


# ---------------------------------------------------------------------------
# basic blocks
# ---------------------------------------------------------------------------

def block_id_vector(program: Sequence[Instruction]) -> List[int]:
    """Block index per instruction; boundary instructions occupy their own
    block so nothing can be swapped past them (paper §3.5: no reordering
    across labels or barrier/synchronization instructions)."""
    out = []
    blk = 0
    for ins in program:
        if ins.klass.name == "SYNC":
            blk += 1
            out.append(blk)
            blk += 1
        else:
            out.append(blk)
    return out


def memory_effects(ins: Instruction) -> List[Tuple[tuple, bool]]:
    """Memory cells touched by ``ins`` as ``[(cell_key, is_write), ...]``.

    Cell keys are ``("tile", space, idx)`` when lowering attached an alias
    token, else ``("addr", <first [..] operand text>)`` — a textual fallback
    that is exact for lowered programs (addresses are stable strings) and
    conservative otherwise (idx ``-1`` aliases its whole space, handled by
    the caller).

      * CPYIN  : writes its VMEM tile (HBM source is read-only kernel input)
      * LDV    : reads its VMEM tile
      * STV    : writes its VMEM tile
      * CPYOUT : reads its VMEM tile and writes an HBM cell keyed by its
                 destination address operand
    """
    if not is_memory_op(ins.opcode):
        return []
    base = ins.base
    tile_key = (("tile",) + ins.tile) if ins.tile is not None else None
    addr_ops = [op for op in ins.operands if op.startswith("[")]

    def _key(which: int) -> tuple:
        if tile_key is not None:
            return tile_key
        if which < len(addr_ops):
            return ("addr", addr_ops[which])
        return ("addr", "?")  # unknown: caller treats as aliasing everything

    if base == "CPYIN":
        return [(_key(0), True)]
    if base == "LDV":
        return [(_key(0), False)]
    if base == "STV":
        return [(_key(0), True)]
    if base == "CPYOUT":
        # operands: [hbm_dst], src... ; VMEM side rides on ``tile``.
        vmem_read = (tile_key, False) if tile_key is not None else None
        hbm_key = ("addr", addr_ops[0]) if addr_ops else ("addr", "?")
        eff = [(hbm_key, True)]
        if vmem_read is not None:
            eff.append(vmem_read)
        return eff
    return []


def roundtrip(program: Iterable[Instruction]) -> List[Instruction]:
    """parse(text(program)) — used by tests to pin the text format."""
    from repro.core.isa import program_text
    return parse_program(program_text(list(program)))
