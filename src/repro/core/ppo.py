"""PPO with a CNN state encoder and invalid-action masking (paper §3.7).

Pure-JAX actor-critic (no external RL libraries): the state matrix from
:mod:`repro.core.embedding` is encoded by a 1-D CNN over the instruction
axis, followed by MLP actor/critic heads.  Hyperparameters and implementation
choices (orthogonal init, Adam eps 1e-5, advantage normalization, clipped
value loss, linear LR anneal) follow the "37 implementation details of PPO"
study the paper takes its defaults from [11].
"""

from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adam
from repro.optim.adamw import apply_updates

_NEG = -1e9


@dataclasses.dataclass
class PPOConfig:
    # defaults from Huang et al. [11] as used by the paper (§3.7, §5.5)
    lr: float = 2.5e-4
    num_envs: int = 8
    num_steps: int = 128            # rollout length per env per update
    total_timesteps: int = 16_384
    gamma: float = 0.99
    gae_lambda: float = 0.95
    num_minibatches: int = 4
    update_epochs: int = 4
    clip_coef: float = 0.2
    ent_coef: float = 0.01
    vf_coef: float = 0.5
    max_grad_norm: float = 0.5
    anneal_lr: bool = True
    seed: int = 0
    episode_length: int = 32    # §5.7.2: increase if no lingering observed
    warm_start: bool = False    # beyond-paper: episodes resume from the
                                # incumbent best schedule (see §Perf)
    hop_sizes: tuple = (1,)     # beyond-paper: macro moves (see §Perf)

    @property
    def batch_size(self) -> int:
        return self.num_envs * self.num_steps

    @property
    def minibatch_size(self) -> int:
        return self.batch_size // self.num_minibatches

    @property
    def num_updates(self) -> int:
        return max(1, self.total_timesteps // self.batch_size)


# ---------------------------------------------------------------------------
# network
# ---------------------------------------------------------------------------

def _orthogonal(key, shape, gain=1.0, dtype=jnp.float32):
    flat = (int(np.prod(shape[:-1])), shape[-1])
    a = jax.random.normal(key, flat, dtype)
    q, r = jnp.linalg.qr(a if flat[0] >= flat[1] else a.T)
    q = q * jnp.sign(jnp.diagonal(r))
    if flat[0] < flat[1]:
        q = q.T
    return (gain * q[: flat[0], : flat[1]]).reshape(shape).astype(dtype)


def init_agent(key, n_rows: int, feat_dim: int, num_actions: int,
               channels: int = 64, hidden: int = 256) -> Dict:
    ks = jax.random.split(key, 6)
    s2 = float(np.sqrt(2.0))
    return {
        "conv1_w": _orthogonal(ks[0], (5, feat_dim, channels), s2),
        "conv1_b": jnp.zeros((channels,)),
        "conv2_w": _orthogonal(ks[1], (5, channels, channels), s2),
        "conv2_b": jnp.zeros((channels,)),
        "fc_w": _orthogonal(ks[2], (2 * channels, hidden), s2),
        "fc_b": jnp.zeros((hidden,)),
        "actor_w": _orthogonal(ks[3], (hidden, num_actions), 0.01),
        "actor_b": jnp.zeros((num_actions,)),
        "critic_w": _orthogonal(ks[4], (hidden, 1), 1.0),
        "critic_b": jnp.zeros((1,)),
    }


def _conv1d(x, w, b, stride):
    """1-D conv as im2col + GEMM.  (lax.conv's strided backward lowers to a
    dilated conv, which is pathologically slow on the XLA CPU backend this
    container trains on; gather+matmul keeps fwd/bwd on the GEMM fast path
    and is mathematically identical.)  x: (B, N, C_in); w: (K, C_in, C_out).
    """
    B, N, _ = x.shape
    K = w.shape[0]
    pad_lo = (K - 1) // 2
    xp = jnp.pad(x, ((0, 0), (pad_lo, K - 1 - pad_lo), (0, 0)))
    n_out = -(-N // stride)  # ceil: SAME padding
    idx = jnp.arange(n_out) * stride
    cols = xp[:, idx[:, None] + jnp.arange(K)[None, :], :]  # (B, No, K, C)
    return jnp.einsum("bnkc,kco->bno", cols, w) + b


def policy_value(params, state):
    """state: (B, N, F) -> (logits (B, A), value (B,))."""
    x = _conv1d(state, params["conv1_w"], params["conv1_b"], 2)
    x = jax.nn.relu(x)
    x = _conv1d(x, params["conv2_w"], params["conv2_b"], 2)
    x = jax.nn.relu(x)
    feat = jnp.concatenate([x.mean(axis=1), x.max(axis=1)], axis=-1)
    h = jax.nn.relu(feat @ params["fc_w"] + params["fc_b"])
    logits = h @ params["actor_w"] + params["actor_b"]
    value = (h @ params["critic_w"] + params["critic_b"])[..., 0]
    return logits, value


def masked_logits(logits, mask):
    return jnp.where(mask > 0, logits, _NEG)


def masked_log_probs(logits, mask):
    ml = masked_logits(logits, mask)
    return jax.nn.log_softmax(ml, axis=-1)


def masked_entropy(logits, mask):
    lp = masked_log_probs(logits, mask)
    p = jnp.exp(lp)
    ent = -jnp.sum(jnp.where(mask > 0, p * lp, 0.0), axis=-1)
    return ent


@jax.jit
def sample_action(params, key, state, mask):
    """Batched action sampling under the mask (assigning 'an impossible
    probability' to invalid actions, §3.5)."""
    logits, value = policy_value(params, state)
    ml = masked_logits(logits, mask)
    action = jax.random.categorical(key, ml, axis=-1)
    lp = masked_log_probs(logits, mask)
    logprob = jnp.take_along_axis(lp, action[:, None], axis=-1)[:, 0]
    return action, logprob, value


@jax.jit
def greedy_action(params, state, mask):
    logits, value = policy_value(params, state)
    return jnp.argmax(masked_logits(logits, mask), axis=-1), value


@jax.jit
def bootstrap_value(params, state):
    """Critic-only forward for the GAE bootstrap.  Jitted once here beside
    ``sample_action``: re-wrapping ``jax.jit(policy_value)`` inside the
    update loop created a fresh trace cache (and a retrace) every update."""
    return policy_value(params, state)[1]


# ---------------------------------------------------------------------------
# GAE + update
# ---------------------------------------------------------------------------

def compute_gae(rewards, values, dones, last_value, gamma, lam):
    """rewards/values/dones: (T, B); returns advantages, returns (T, B)."""
    T = rewards.shape[0]

    def scan_fn(carry, xs):
        adv_next, v_next = carry
        r, v, d = xs
        nonterminal = 1.0 - d
        delta = r + gamma * v_next * nonterminal - v
        adv = delta + gamma * lam * nonterminal * adv_next
        return (adv, v), adv

    init = (jnp.zeros_like(last_value), last_value)
    _, advs = jax.lax.scan(scan_fn, init,
                           (rewards, values, dones), reverse=True)
    return advs, advs + values


class UpdateStats(NamedTuple):
    policy_loss: jnp.ndarray
    value_loss: jnp.ndarray
    entropy: jnp.ndarray
    approx_kl: jnp.ndarray
    clip_frac: jnp.ndarray


def make_update_fn(cfg: PPOConfig):
    opt = adam(lambda step: _lr_at(cfg, step), eps=1e-5,
               max_grad_norm=cfg.max_grad_norm)

    def loss_fn(params, mb):
        logits, value = policy_value(params, mb["state"])
        lp_all = masked_log_probs(logits, mb["mask"])
        logprob = jnp.take_along_axis(lp_all, mb["action"][:, None], axis=-1)[:, 0]
        ratio = jnp.exp(logprob - mb["logprob"])
        adv = mb["adv"]
        adv = (adv - adv.mean()) / (adv.std() + 1e-8)
        pg1 = -adv * ratio
        pg2 = -adv * jnp.clip(ratio, 1 - cfg.clip_coef, 1 + cfg.clip_coef)
        pg_loss = jnp.maximum(pg1, pg2).mean()
        # clipped value loss
        v_clip = mb["value"] + jnp.clip(value - mb["value"],
                                        -cfg.clip_coef, cfg.clip_coef)
        v_loss = 0.5 * jnp.maximum((value - mb["ret"]) ** 2,
                                   (v_clip - mb["ret"]) ** 2).mean()
        ent = masked_entropy(logits, mb["mask"]).mean()
        loss = pg_loss - cfg.ent_coef * ent + cfg.vf_coef * v_loss
        approx_kl = ((ratio - 1.0) - jnp.log(ratio)).mean()
        clip_frac = (jnp.abs(ratio - 1.0) > cfg.clip_coef).mean()
        return loss, UpdateStats(pg_loss, v_loss, ent, approx_kl, clip_frac)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    @jax.jit
    def update(params, opt_state, batch, key):
        B = batch["action"].shape[0]

        def epoch_body(carry, ek):
            params, opt_state = carry
            perm = jax.random.permutation(ek, B)

            def mb_body(carry, idx):
                params, opt_state = carry
                mb = {k: v[idx] for k, v in batch.items()}
                (_, stats), grads = grad_fn(params, mb)
                updates, opt_state = opt.update(grads, opt_state, params)
                params = apply_updates(params, updates)
                return (params, opt_state), stats

            idxs = perm.reshape(cfg.num_minibatches, cfg.minibatch_size)
            (params, opt_state), stats = jax.lax.scan(
                mb_body, (params, opt_state), idxs)
            return (params, opt_state), stats

        keys = jax.random.split(key, cfg.update_epochs)
        (params, opt_state), stats = jax.lax.scan(
            epoch_body, (params, opt_state), keys)
        mean_stats = jax.tree.map(lambda x: x.mean(), stats)
        return params, opt_state, mean_stats

    return opt, update


def _lr_at(cfg: PPOConfig, step):
    if not cfg.anneal_lr:
        return jnp.asarray(cfg.lr, jnp.float32)
    total = cfg.num_updates * cfg.update_epochs * cfg.num_minibatches
    frac = 1.0 - jnp.clip(step.astype(jnp.float32) / max(total, 1), 0.0, 1.0)
    return cfg.lr * jnp.maximum(frac, 0.0) + 1e-8
