"""The assembly game (paper §3.3–§3.6).

The environment holds a TSASS program (the state), exposes the action space
"pick a schedulable memory instruction, swap it with the instruction above or
below" (§3.5), computes a dynamic action mask from register / barrier /
stall-count / heuristic dependencies (§3.5 + Algorithm 1), and rewards with
the measured runtime delta ``R_i = (T_{i-1} - T_i) / T_0 * 100`` (§3.6).

Two masking implementations:

* :func:`can_swap` — the reference, a literal transcription of §3.5 +
  Algorithm 1 over instruction lists;
* the environment's fast path — identical semantics, O(1) amortized per
  action.  It exploits an invariant of masked games: the *relations*
  (nearest definition, consumers-before-redefinition, basic-block
  membership) cannot change under masked swaps — only positions do — so
  they are precomputed once and stall accumulations become prefix-sum
  lookups.  A property test drives thousands of random games asserting the
  two paths agree exactly.

The masking rules guarantee (and property tests verify) that any sequence of
masked actions preserves the observable dataflow semantics of the program on
the machine model.

Reward measurement has the same two-path structure as masking: the dataflow
oracle ``Machine.run`` stays the reference, while the default fast path
measures through :class:`repro.core.timing.ScheduleTimer` (timing-only
scoreboard, checkpointed so an adjacent swap re-times only the program
suffix) behind a schedule->cycles memo keyed by the position->identity
permutation — shareable across the vectorized training envs, with hit/miss
counters surfaced into ``GameResult.stats``.  The fast path is bit-exact
(property-tested in ``tests/test_timing_fast.py``), and ``step`` splits
into ``begin_step`` / ``prime_measure`` / ``finish_step`` so a driver can
batch one step's measurements for all envs through the shared memo.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import analysis as analysis_mod
from repro.core import embedding
from repro.core.isa import Instruction, OpClass, is_fixed_latency
from repro.core.machine import Machine
from repro.core.parser import block_id_vector, memory_effects
from repro.core.timing import ScheduleTimer

EPISODE_LENGTH = 32  # §5.7: sufficient for the paper's kernels


def _cells_alias(a: tuple, b: tuple) -> bool:
    if a == b:
        return True
    if a == ("addr", "?") or b == ("addr", "?"):
        return True
    if a[0] == "tile" and b[0] == "tile" and a[1] == b[1]:
        return a[2] == -1 or b[2] == -1 or a[2] == b[2]
    return False


def _sems_set(ins: Instruction) -> frozenset:
    s = set()
    if ins.ctrl.read_bar is not None:
        s.add(ins.ctrl.read_bar)
    if ins.ctrl.write_bar is not None:
        s.add(ins.ctrl.write_bar)
    return frozenset(s)


# ---------------------------------------------------------------------------
# reference masking (literal §3.5 + Algorithm 1)
# ---------------------------------------------------------------------------

def can_swap(program: Sequence[Instruction], p: int,
             stall_table: Dict[str, int],
             blocks: Optional[List[int]] = None) -> bool:
    """May positions ``p-1`` and ``p`` be exchanged?

    Implements every dependency class of §3.5: register, barrier, stall count
    (Algorithm 1, both the moving instruction's producers and the displaced
    neighbour's consumers), and the hard-coded heuristics (no crossing basic
    blocks / synchronization; consecutive-DMA groups keep their order).
    Unknown stall counts mask conservatively.
    """
    if p <= 0 or p >= len(program):
        return False
    a, b = program[p - 1], program[p]
    if blocks is None:
        blocks = block_id_vector(program)
    if blocks[p - 1] != blocks[p]:
        return False
    if a.klass is OpClass.SYNC or b.klass is OpClass.SYNC:
        return False

    # --- heuristic: consecutive-DMA group order is pinned (§3.5) ------------
    if a.group is not None and a.group == b.group:
        return False

    # --- register dependencies ----------------------------------------------
    a_defs, a_uses = a.defs or frozenset(), a.uses or frozenset()
    b_defs, b_uses = b.defs or frozenset(), b.uses or frozenset()
    if (a_defs & b_uses) or (a_uses & b_defs) or (a_defs & b_defs):
        return False

    # --- memory aliasing -----------------------------------------------------
    for cell_a, wa in memory_effects(a):
        for cell_b, wb in memory_effects(b):
            if (wa or wb) and _cells_alias(cell_a, cell_b):
                return False

    # --- barrier dependencies: a waiter never moves above its setter ---------
    if _sems_set(a) & b.ctrl.wait_mask:
        return False

    # --- stall-count dependencies (Algorithm 1, both directions) -------------
    if not _stall_ok_after_swap_up(program, blocks, p, b, stall_table):
        return False
    if is_fixed_latency(a.opcode) and a_defs:
        if not _stall_ok_neighbor_down(program, blocks, p, a, b, stall_table):
            return False
    return True


def _stall_ok_after_swap_up(program, blocks, p, b, stall_table) -> bool:
    """Algorithm 1 of the paper, evaluated in the post-swap order: walk
    upward from the moved instruction accumulating stall counts; on reaching
    a defining fixed-latency instruction, the accumulation must reach its
    minimum stall count."""
    b_uses = b.uses or frozenset()
    if not b_uses:
        return True
    blk = blocks[p]
    for reg in b_uses:
        if reg.startswith("UR"):
            continue  # uniform registers: prologue constants
        accum = 0
        for j in range(p - 2, -1, -1):       # post-swap predecessors of b
            ins = program[j]
            if blocks[j] != blk:
                break
            accum += max(1, ins.ctrl.stall)
            if reg in (ins.defs or ()):
                if is_fixed_latency(ins.opcode):
                    min_st = stall_table.get(ins.opcode)
                    if min_st is None or accum < min_st:
                        return False
                break  # nearest definition decides
    return True


def _stall_ok_neighbor_down(program, blocks, p, a, b, stall_table) -> bool:
    """The displaced neighbour ``a`` (fixed-latency) moves one slot down:
    its consumers below must still see enough accumulated stall."""
    min_st = stall_table.get(a.opcode)
    blk = blocks[p - 1]
    for reg in a.defs or ():
        accum = max(1, a.ctrl.stall)         # post-swap: a sits at p
        for j in range(p + 1, len(program)):
            ins = program[j]
            if blocks[j] != blk:
                break
            if reg in (ins.uses or ()):
                if min_st is None or accum < min_st:
                    return False
                break  # first use is binding (later uses accumulate more)
            if reg in (ins.defs or ()):
                break  # redefined: liveness ends
            accum += max(1, ins.ctrl.stall)
    return True


# ---------------------------------------------------------------------------
# fast masking: precomputed invariant relations + prefix sums
# ---------------------------------------------------------------------------

class _FastDeps:
    """Per-instruction-identity facts that are invariant under masked swaps."""

    def __init__(self, program: Sequence[Instruction],
                 stall_table: Dict[str, int], blocks: List[int]):
        n = len(program)
        self.n = n
        self.block = list(blocks)
        self.sync = [ins.klass is OpClass.SYNC for ins in program]
        self.stall = np.array([max(1, ins.ctrl.stall) for ins in program],
                              np.int64)
        self.stall_list = self.stall.tolist()   # plain ints for hot loops
        self.defs = [ins.defs or frozenset() for ins in program]
        self.uses = [ins.uses or frozenset() for ins in program]
        self.sems = [_sems_set(ins) for ins in program]
        self.wait = [ins.ctrl.wait_mask for ins in program]
        self.group = [ins.group for ins in program]
        self.effects = [memory_effects(ins) for ins in program]
        self.fixed = [is_fixed_latency(ins.opcode) for ins in program]
        self.min_st = [stall_table.get(ins.opcode) if self.fixed[i] else None
                       for i, ins in enumerate(program)]

        # nearest in-block fixed-latency producer per use register
        last_def: Dict[str, int] = {}
        self.producers: List[List[Tuple[int, Optional[int]]]] = \
            [[] for _ in range(n)]
        consumers: List[List[int]] = [[] for _ in range(n)]
        for i, ins in enumerate(program):
            if self.sync[i]:
                last_def.clear()
                continue
            for reg in self.uses[i]:
                if reg.startswith("UR"):
                    continue
                j = last_def.get(reg)
                if j is not None and self.fixed[j]:
                    self.producers[i].append((j, self.min_st[j]))
                    consumers[j].append(i)
            for reg in self.defs[i]:
                last_def[reg] = i
        # consumers of fixed-latency defs (before redefinition, same block)
        self.consumers = consumers

    def alias(self, ia: int, ib: int) -> bool:
        for cell_a, wa in self.effects[ia]:
            for cell_b, wb in self.effects[ib]:
                if (wa or wb) and _cells_alias(cell_a, cell_b):
                    return True
        return False


@dataclasses.dataclass
class StepRecord:
    slot: int
    direction: int           # 0 = up, 1 = down
    position: int            # position of the instruction before the move
    cycles_before: float
    cycles_after: float
    moved: Instruction = None
    hops: int = 1            # micro-swaps applied (macro-move option)


class AssemblyGame:
    """Gym-style interface (reset/step) for one kernel's schedule."""

    def __init__(self, program: Sequence[Instruction],
                 stall_db: Optional[Dict[str, int]] = None,
                 machine: Optional[Machine] = None,
                 episode_length: int = EPISODE_LENGTH,
                 input_seed: int = 0,
                 use_fast_mask: bool = True,
                 warm_start: bool = False,
                 hop_sizes: Tuple[int, ...] = (1,),
                 use_fast_measure: bool = True,
                 measure_cache: Optional[Dict[bytes, float]] = None,
                 checkpoint_every: int = 16):
        # warm_start: BEYOND-PAPER option (EXPERIMENTS.md §Perf): episodes
        # restart from the incumbent best schedule instead of the -O3
        # baseline (iterated-local-search flavor); the paper's vanilla game
        # always restarts from the baseline.
        # hop_sizes: BEYOND-PAPER option: action (slot, dir, hop) applies up
        # to ``hop`` consecutive single-slot swaps to the same instruction,
        # each individually masked (safety is inherited); the paper's game
        # is hop_sizes=(1,).
        # use_fast_measure: measure rewards through the timing-only
        # incremental executor plus a permutation-keyed memo instead of the
        # dataflow oracle.  Bit-exact (see repro.core.timing), so on by
        # default; auto-disabled for noisy machines (the memo would freeze
        # one noise draw) and for Machine subclasses that override run.
        # measure_cache: share a schedule -> cycles memo across games over
        # the *same* instruction list (train_on_program's vectorized envs
        # all measure the same baseline and early-episode schedules).  A
        # session backend passes a SharedMeasureMemo view here, which
        # namespaces the permutation keys by program fingerprint so the
        # memo is additionally shared across kernels and autotune phases.
        self.original = [ins.copy() for ins in program]
        self.machine = machine or Machine()
        self.episode_length = episode_length
        self.input_seed = input_seed
        self.use_fast_mask = use_fast_mask
        self.warm_start = warm_start
        self.hop_sizes = tuple(hop_sizes)
        self.analysis = analysis_mod.analyze(self.original, stall_db)
        self.blocks = list(self.analysis.blocks)
        self.n = len(self.original)
        self.slots = list(self.analysis.mem_slots)  # slot -> original index
        self.m = len(self.slots)
        self.num_actions = 2 * self.m * len(self.hop_sizes)
        self.feature_dim = embedding.feature_dim(self.analysis)
        self.deps = _FastDeps(self.original, self.analysis.stall_table,
                              self.blocks)
        self._swap_ok: Dict[int, bool] = {}  # ordered-pair static-mask memo
        # instruction content is immutable; only order changes -> embed once
        self._emb = embedding.embed_program(self.original, self.analysis,
                                            n_rows=self.n)
        # run-global best (survives episode resets — §4.2: "the best
        # optimized cubin found throughout the assembly game")
        self.best_cycles = float("inf")
        self.best_program = list(self.original)
        # fast measurement path: timing-only incremental executor + memo.
        # Bit-exactness only holds for the stock noise-free Machine.
        self._fast_measure = (use_fast_measure and self.machine.noise == 0
                              and type(self.machine).run is Machine.run)
        self._timer = (ScheduleTimer(self.original, checkpoint_every)
                       if self._fast_measure else None)
        self._memo: Dict[bytes, float] = \
            measure_cache if measure_cache is not None else {}
        self._prefetched: set = set()
        self._pending = None
        self.measure_calls = 0
        self.memo_hits = 0
        self.memo_misses = 0
        self._reset_state()

    # -- bookkeeping ----------------------------------------------------------

    def _reset_state(self):
        # instructions are immutable during the game (only order changes):
        # share objects so machine-side exec caches persist across episodes
        start = (self.best_program if self.warm_start
                 and getattr(self, "best_program", None) is not None
                 and not np.isinf(getattr(self, "best_cycles", np.inf))
                 else self.original)
        self.program = list(start)
        index_of = {id(ins): i for i, ins in enumerate(self.original)}
        ids = np.array([index_of[id(ins)] for ins in self.program])
        self.id_at = ids                          # position -> identity
        self._ids = ids.tolist()                  # plain-int mirror of id_at
        self.pos_of = np.argsort(ids).tolist()    # identity -> position
        self.slot_pos = {k: self.pos_of[idx]
                         for k, idx in enumerate(self.slots)}
        self.slot_at = [-1] * self.n              # position -> slot (or -1)
        for k, pos in self.slot_pos.items():
            self.slot_at[pos] = k
        # Algorithm-1 prefix sums (S[x] = stalls of positions < x), kept
        # incrementally: an adjacent swap at q only changes S[q]
        self._prefix = \
            [0] + np.cumsum(self.deps.stall[self.id_at]).tolist()
        self.t = 0
        self._mask_cache: Optional[np.ndarray] = None
        # incremental masking: per-position swap-ok cache (-1 = dirty).
        # A swap at q can only change the checks enumerated in _swap, so
        # everything else survives across steps instead of being recomputed
        # row-by-row (ROADMAP "incremental mask").
        self._ok_at = np.full(self.n + 1, -1, np.int8)
        start_cycles = self._measure()
        if not hasattr(self, "t0"):
            self.t0 = start_cycles       # Eq. 3's T_0: pinned to the -O3
                                         # baseline even under warm starts
        self.prev_cycles = start_cycles
        if start_cycles < self.best_cycles:
            self.best_cycles = start_cycles
            self.best_program = list(self.program)
        self.history: List[StepRecord] = []

    def _measure(self) -> float:
        self.measure_calls += 1
        if self._timer is None:
            return self.machine.run(self.program,
                                    input_seed=self.input_seed).cycles
        key = self.id_at.tobytes()
        cached = self._memo.get(key)
        if cached is not None:
            if key in self._prefetched:        # this env computed it in
                self._prefetched.discard(key)  # prime_measure: count a miss
                self.memo_misses += 1
            else:
                self.memo_hits += 1
            return cached
        self.memo_misses += 1
        cycles = self._timer.time_ids(self.id_at)
        self._memo[key] = cycles
        return cycles

    # -- gym interface ----------------------------------------------------------

    def reset(self) -> Dict[str, np.ndarray]:
        self._reset_state()
        return self._obs()

    def _obs(self) -> Dict[str, np.ndarray]:
        return {"state": self._emb[self.id_at], "mask": self.action_mask()}

    def write_obs(self, state_out: np.ndarray,
                  mask_out: Optional[np.ndarray] = None) -> None:
        """Fill preallocated observation buffers in place (the vectorized
        rollout path: no per-step (n, feat) allocation).  ``mask_out`` may
        be wider than ``num_actions``; the excess is zeroed."""
        np.take(self._emb, self.id_at, axis=0, out=state_out)
        if mask_out is not None:
            m = self.action_mask()
            mask_out[:m.shape[0]] = m
            mask_out[m.shape[0]:] = 0.0

    # -- masking ----------------------------------------------------------------

    def _pair_static_ok(self, ia: int, ib: int) -> bool:
        """Position-independent §3.5 checks for "``ia`` directly above
        ``ib`` may swap": basic-block/sync membership, DMA-group pinning,
        register dependencies, memory aliasing, barrier waits.  These are
        functions of the ordered identity *pair* only — invariant under
        masked swaps — so :meth:`_can_swap_fast` memoizes them."""
        d = self.deps
        if d.block[ia] != d.block[ib] or d.sync[ia] or d.sync[ib]:
            return False
        if d.group[ia] is not None and d.group[ia] == d.group[ib]:
            return False
        if (d.defs[ia] & d.uses[ib]) or (d.uses[ia] & d.defs[ib]) \
                or (d.defs[ia] & d.defs[ib]):
            return False
        if d.alias(ia, ib):
            return False
        if d.sems[ia] & d.wait[ib]:
            return False
        return True

    def _swap_ok_at(self, p: int) -> bool:
        """Cached "may positions p-1, p swap?" with incremental
        invalidation: entries survive across steps and only the positions
        :meth:`_swap` dirties are recomputed."""
        if p <= 0 or p >= self.n:
            return False
        v = self._ok_at[p]
        if v < 0:
            v = 1 if self._can_swap_fast(p, self._prefix) else 0
            self._ok_at[p] = v
        return bool(v)

    def _can_swap_fast(self, p: int, prefix) -> bool:
        if p <= 0 or p >= self.n:
            return False
        ids = self._ids
        ia, ib = ids[p - 1], ids[p]
        key = ia * self.n + ib
        ok = self._swap_ok.get(key)
        if ok is None:
            ok = self._pair_static_ok(ia, ib)
            self._swap_ok[key] = ok
        if not ok:
            return False
        d = self.deps
        pos_of = self.pos_of
        # Algorithm 1 via prefix sums: S[x] = sum of stalls of positions <x
        for (pid, mst) in d.producers[ib]:
            jpos = pos_of[pid]
            if jpos >= p - 1:
                continue  # adjacent producer: already masked by reg dep
            if mst is None or prefix[p - 1] - prefix[jpos] < mst:
                return False
        if d.fixed[ia] and d.consumers[ia]:
            mst = d.min_st[ia]
            base = d.stall_list[ia] - prefix[p + 1]
            for cid in d.consumers[ia]:
                cpos = pos_of[cid]
                if cpos <= p:
                    continue
                if mst is None or base + prefix[cpos] < mst:
                    return False
        return True

    def action_mask(self) -> np.ndarray:
        if self._mask_cache is not None:
            return self._mask_cache
        nh = len(self.hop_sizes)
        base = np.zeros(2 * self.m, dtype=np.float32)
        if self.use_fast_mask:
            for k in range(self.m):
                p = self.slot_pos[k]
                if self._swap_ok_at(p):
                    base[2 * k] = 1.0
                if self._swap_ok_at(p + 1):
                    base[2 * k + 1] = 1.0
        else:
            for k in range(self.m):
                p = self.slot_pos[k]
                if can_swap(self.program, p, self.analysis.stall_table,
                            self._position_blocks()):
                    base[2 * k] = 1.0
                if can_swap(self.program, p + 1, self.analysis.stall_table,
                            self._position_blocks()):
                    base[2 * k + 1] = 1.0
        mask = np.repeat(base.reshape(self.m, 2), nh, axis=1).reshape(-1) \
            if nh > 1 else base
        self._mask_cache = mask
        return mask

    def _position_blocks(self) -> List[int]:
        """Block ids in current position order (for the reference path)."""
        return [self.deps.block[int(i)] for i in self.id_at]

    # -- stepping ----------------------------------------------------------------

    def step(self, action: int):
        mask = self.action_mask()
        if not mask.any():
            # "If no actions are available, the episode is terminated" (§3.5)
            return self._obs(), 0.0, True, {"cycles": self.prev_cycles,
                                            "terminated": "no_actions"}
        self.begin_step(action)
        return self.finish_step()

    def begin_step(self, action: int) -> Optional[bytes]:
        """Apply the action's swap(s) without measuring (the batched
        rollout path: the driver collects measurement requests from every
        env, serves distinct cache misses once through the shared memo,
        then calls :meth:`finish_step`).

        Returns the memo key of the resulting schedule when a fast-path
        measurement is still needed, else ``None`` (memo hit / oracle
        path).  The caller must have handled the empty-mask termination.
        """
        mask = self.action_mask()
        if not (0 <= action < self.num_actions) or mask[action] == 0.0:
            raise ValueError(f"invalid (masked) action {action}")
        nh = len(self.hop_sizes)
        k, rem = divmod(int(action), 2 * nh)
        direction, hop_idx = divmod(rem, nh)
        hops = self.hop_sizes[hop_idx]
        p = self.slot_pos[k]
        hops_done = 0
        for h in range(hops):
            pos = self.slot_pos[k]
            q = pos if direction == 0 else pos + 1
            if h > 0 and not self._can_swap_fast(q, self._prefix):
                break
            self._swap(q)
            hops_done += 1
        self._pending = (k, direction, p, self.prev_cycles, hops_done)
        if self._timer is not None:
            key = self.id_at.tobytes()
            if key not in self._memo:
                return key
        return None

    def prime_measure(self) -> None:
        """Compute and publish the pending schedule's cycles into the
        shared memo (called once per distinct ``begin_step`` key by the
        batched driver, possibly from a worker pool — each env owns its
        timer, so distinct envs prime concurrently without contention)."""
        key = self.id_at.tobytes()
        if key not in self._memo:
            self._memo[key] = self._timer.time_ids(self.id_at)
            self._prefetched.add(key)

    def publish_measure(self, cycles: float) -> None:
        """Publish an externally timed result for the pending schedule
        (the batched driver re-times one step's distinct misses through a
        single :class:`~repro.core.timing.ScheduleTimer` pass —
        ``ScheduleTimer.time_many`` — and hands each owner env its
        cycles).  Accounting matches :meth:`prime_measure`: the owner's
        later :meth:`_measure` read counts as the miss it caused."""
        key = self.id_at.tobytes()
        if key not in self._memo:
            self._memo[key] = cycles
            self._prefetched.add(key)

    def finish_step(self, want_obs: bool = True):
        """Measure the pending schedule and close out the step begun by
        :meth:`begin_step`.  ``want_obs=False`` skips building the
        observation dict (the vectorized driver reads it later through
        :meth:`write_obs` into preallocated buffers)."""
        k, direction, p, before, hops_done = self._pending
        self._pending = None
        cycles = self._measure()
        reward = (before - cycles) / self.t0 * 100.0  # Eq. (3)
        self.prev_cycles = cycles
        if cycles < self.best_cycles:
            self.best_cycles = cycles
            self.best_program = list(self.program)
        self.t += 1
        done = self.t >= self.episode_length
        moved = self.program[self.slot_pos[k]]
        self.history.append(StepRecord(k, direction, p, before, cycles,
                                       moved, hops_done))
        obs = self._obs() if want_obs else None
        return obs, float(reward), done, {"cycles": cycles,
                                          "best": self.best_cycles}

    def _swap(self, q: int) -> None:
        self.program[q - 1], self.program[q] = self.program[q], self.program[q - 1]
        ids = self._ids
        ia, ib = ids[q - 1], ids[q]
        ids[q - 1], ids[q] = ib, ia
        self.id_at[q - 1], self.id_at[q] = ib, ia
        self.pos_of[ia], self.pos_of[ib] = q, q - 1
        sa, sb = self.slot_at[q - 1], self.slot_at[q]
        self.slot_at[q - 1], self.slot_at[q] = sb, sa
        if sb >= 0:
            self.slot_pos[sb] = q - 1
        if sa >= 0:
            self.slot_pos[sa] = q
        # only S[q] depends on the relative order of positions q-1 and q
        self._prefix[q] = self._prefix[q - 1] + self.deps.stall_list[ib]
        self._mask_cache = None
        # Incremental invalidation of the per-position swap-ok cache.
        # A check at position p reads: the identity pair (p-1, p) — changed
        # only at q-1/q/q+1; prefix sums at p-1, p+1 and at its
        # producer/consumer positions — an adjacent swap changes only
        # S[q] (interval sums spanning q are permutation-invariant), which
        # those checks read iff p ∈ {q-1, q+1} or the producer/consumer
        # sits exactly at q, i.e. is one of the two moved identities; and
        # pos_of of its Algorithm-1 producers/consumers — changed only for
        # the moved identities.  So the dirty set is the three positions
        # around q plus every check anchored to a moved identity's
        # dependency partners.
        ok = self._ok_at
        n = self.n
        for p in (q - 1, q, q + 1):
            if 0 < p < n:
                ok[p] = -1
        d = self.deps
        pos_of = self.pos_of
        for x in (ia, ib):
            for cid in d.consumers[x]:          # checks where x is producer
                pp = pos_of[cid]
                if 0 < pp < n:
                    ok[pp] = -1
            for pid, _ in d.producers[x]:       # checks where x is consumer
                pp = pos_of[pid] + 1
                if 0 < pp < n:
                    ok[pp] = -1

    # -- utilities ----------------------------------------------------------------

    def probe_swap(self, q: int) -> float:
        """Cycles of the schedule with positions ``q-1``/``q`` exchanged,
        leaving the game state untouched (adjacent swaps are self-inverse).
        The measurement goes through the normal path (timer + memo, or the
        oracle), so strategies can candidate-evaluate without stepping."""
        self._swap(q)
        try:
            return self._measure()
        finally:
            self._swap(q)

    def set_order(self, ids: Sequence[int]) -> None:
        """Teleport the game to an arbitrary schedule given as a
        position -> identity permutation (the same encoding as ``id_at``),
        rebuilding every incremental structure from scratch.

        The beam / lookahead strategies use this to jump between candidate
        schedules instead of replaying swap sequences.  The caller is
        responsible for only supplying orders *reachable by masked swaps*
        (e.g. produced by expanding ``valid_actions`` from another reached
        order) — legality is not re-checked here, exactly like
        ``begin_step`` trusts its mask.
        """
        ids = np.asarray(ids, dtype=np.int64)
        if sorted(ids.tolist()) != list(range(self.n)):
            raise ValueError("set_order wants a permutation of "
                             f"range({self.n})")
        self.program = [self.original[i] for i in ids]
        self.id_at = ids.copy()
        self._ids = ids.tolist()
        self.pos_of = np.argsort(ids).tolist()
        self.slot_pos = {k: self.pos_of[idx]
                         for k, idx in enumerate(self.slots)}
        self.slot_at = [-1] * self.n
        for k, pos in self.slot_pos.items():
            self.slot_at[pos] = k
        self._prefix = \
            [0] + np.cumsum(self.deps.stall[self.id_at]).tolist()
        self._mask_cache = None
        self._ok_at[:] = -1
        self._pending = None

    def measure_schedule(self) -> float:
        """Measure the current schedule through the normal path (timer +
        memo, or the oracle), updating the run-global best.  The verified
        measurement the guided-search strategies route their top-k
        candidates through — never a model prediction."""
        cycles = self._measure()
        self.prev_cycles = cycles
        if cycles < self.best_cycles:
            self.best_cycles = cycles
            self.best_program = list(self.program)
        return cycles

    def action_swap_pos(self, action: int) -> int:
        """The swap boundary the action's *first* hop exchanges (positions
        ``pos-1``/``pos``), decoded exactly as :meth:`begin_step` does."""
        nh = len(self.hop_sizes)
        k, rem = divmod(int(action), 2 * nh)
        direction, _ = divmod(rem, nh)
        p = self.slot_pos[k]
        return p if direction == 0 else p + 1

    def valid_actions(self) -> List[int]:
        return [a for a, v in enumerate(self.action_mask()) if v > 0]

    def improvement(self) -> float:
        """Relative improvement of the best schedule over the -O3 start."""
        return (self.t0 - self.best_cycles) / self.t0
