"""Pre-game static analysis (paper §3.2).

Three passes over the disassembled TSASS program:

1. **Stall-count resolution.**  For every memory instruction that consumes
   the output of a fixed-latency instruction *in the same basic block*, walk
   its preceding instructions looking for the defining instruction.  The
   accumulated stall count between the use-def pair is a safe (exact or
   over-) estimate of the producer's latency, because the original -O3
   schedule is always valid.  Each dependency is classified as

     * ``db``      — producer opcode present in the microbenchmarked stall
                      table (paper Table 1 / §4.3),
     * ``infer``   — resolved by this pass,
     * ``denylist``— the defining instruction was not found before a label /
                      block boundary: the memory instruction is denylisted
                      permanently masked out of the action space.

   (These three fractions are exactly what the paper's Figure 7 reports.)

2. **Embedding tables** (§3.4): register->int and memory-operand->int maps,
   and the maximum operand count (shorter instructions get -1 padding).

3. **Action space**: indices of schedulable memory instructions minus the
   denylist (§3.5).

The analysis never touches :mod:`repro.core.machine` internals — it sees the
program text only, exactly like the paper's optimizer facing undocumented
SASS.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.core.isa import Instruction, OpClass, is_fixed_latency
from repro.core.parser import block_id_vector


@dataclasses.dataclass
class Analysis:
    stall_table: Dict[str, int]             # full opcode -> min stall count
    resolution: Dict[Tuple[int, int], str]  # (mem_idx, def_idx) -> db|infer|denylist
    denylist: FrozenSet[int]                # memory instruction indices
    mem_slots: List[int]                    # action-space instruction indices
    reg_table: Dict[str, int]
    mem_table: Dict[str, int]
    max_operands: int
    blocks: List[int]

    def resolution_fractions(self) -> Dict[str, float]:
        """Fractions for the Figure-7 reproduction."""
        total = max(len(self.resolution), 1)
        out = {"db": 0, "infer": 0, "denylist": 0}
        for v in self.resolution.values():
            out[v] += 1
        return {k: v / total for k, v in out.items()}


def _defining_index(program: Sequence[Instruction], blocks: List[int],
                    idx: int, reg: str) -> Optional[int]:
    """Nearest preceding definition of ``reg`` inside the same basic block;
    None if a block boundary is reached first (paper: 'If a label is
    encountered first, the analysis pass aborts')."""
    blk = blocks[idx]
    for j in range(idx - 1, -1, -1):
        if blocks[j] != blk:
            return None
        if reg in (program[j].defs or ()):
            return j
    return None


def accumulated_stall(program: Sequence[Instruction], lo: int, hi: int) -> int:
    """Sum of issue-slot stalls from ``lo`` (inclusive) to ``hi`` (exclusive):
    a lower bound on the cycle distance between the two issues."""
    return sum(max(1, program[k].ctrl.stall) for k in range(lo, hi))


def analyze(program: Sequence[Instruction],
            stall_db: Optional[Dict[str, int]] = None) -> Analysis:
    """Run all pre-game passes.  ``stall_db`` is the microbenchmarked table
    (:func:`repro.core.microbench.build_stall_table`)."""
    stall_db = dict(stall_db or {})
    blocks = block_id_vector(program)

    stall_table: Dict[str, int] = dict(stall_db)
    resolution: Dict[Tuple[int, int], str] = {}
    denylist = set()

    # ---- pass 1: stall-count resolution over memory instructions ----------
    for i, ins in enumerate(program):
        if ins.klass is not OpClass.MEM:
            continue
        for reg in sorted(ins.uses or ()):
            if reg.startswith("UR"):
                # uniform/descriptor registers are written once in the
                # prologue and constant thereafter: not a scheduling hazard
                continue
            j = _defining_index(program, blocks, i, reg)
            if j is None:
                # defined across a label (or a kernel parameter): cannot be
                # reasoned about without control-flow analysis -> denylist.
                resolution[(i, reg)] = "denylist"
                denylist.add(i)
                continue
            producer = program[j]
            if not is_fixed_latency(producer.opcode):
                continue  # variable-latency producers sync via semaphores
            if producer.opcode in stall_db:
                resolution[(i, j)] = "db"
                continue
            inferred = accumulated_stall(program, j, i)
            prev = stall_table.get(producer.opcode)
            stall_table[producer.opcode] = (inferred if prev is None
                                            else min(prev, inferred))
            resolution[(i, j)] = "infer"

    # a memory instruction with any unresolved producer is denylisted; all
    # others are schedulable (§3.5)
    mem_slots = [i for i, ins in enumerate(program)
                 if ins.is_schedulable() and i not in denylist]

    # ---- pass 2: embedding tables ------------------------------------------
    reg_table: Dict[str, int] = {}
    mem_table: Dict[str, int] = {}
    max_operands = 0
    for ins in program:
        max_operands = max(max_operands, len(ins.operands))
        for r in sorted((ins.defs or frozenset()) | (ins.uses or frozenset())):
            reg_table.setdefault(r, len(reg_table))
        for op in ins.operands:
            if op.startswith("[") or "desc[" in op:
                mem_table.setdefault(op, len(mem_table))

    return Analysis(
        stall_table=stall_table,
        resolution=resolution,
        denylist=frozenset(denylist),
        mem_slots=mem_slots,
        reg_table=reg_table,
        mem_table=mem_table,
        max_operands=max_operands,
        blocks=blocks,
    )


def min_stall(analysis: Analysis, opcode: str) -> Optional[int]:
    """Known minimum stall count for a fixed-latency opcode (db ∪ inferred);
    None = unknown (consumers of it must stay denylisted/masked)."""
    return analysis.stall_table.get(opcode)
