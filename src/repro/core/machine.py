"""The TSASS machine: a deterministic scoreboard model of a TPU TensorCore.

This module plays the role of the *A100 in the paper's reward loop* (§3.6):
the assembly game executes mutated schedules here and is rewarded by the
returned cycle count.  Two invariants keep the reproduction honest
(DESIGN.md §2.3):

1. **The latency/bandwidth tables below are private.**  The optimizer-facing
   code (analysis, masking, the agent) never imports them; like real SASS,
   they must be *measured* by dependency-based microbenchmarking
   (:mod:`repro.core.microbench`, paper §4.3) or *inferred* from valid
   schedules (:mod:`repro.core.analysis`, paper §3.2).  Tests are the only
   licensed peekers.

2. **Execution is statically scheduled with no interlocks** (post-Kepler
   semantics, paper §2.3.1): a consumer issued before its producer's latency
   has elapsed reads a *stale* value.  Registers and memory carry 64-bit
   dataflow hashes, so any dependency violation corrupts the final output —
   which is how probabilistic testing (§4.1) and the masking property tests
   catch invalid reorderings.

Timing model (in-order, single-issue scalar core):

  * issue of instruction ``i`` waits for: its stall-count slot, every
    semaphore in its wait mask, and structural hazards (DMA queue depth,
    MXU issue interval, VMEM ports);
  * fixed-latency ops commit their register result LAT cycles after issue;
  * DMA ops (CPYIN/CPYOUT) run on engines (2 inbound / 1 outbound) with a
    setup cost plus size/bandwidth, and clear their write/read barriers at
    completion — the LDGSTS analogue;
  * LDV/STV contend for VMEM ports; LDV sets a write barrier (LDS analogue);
  * back-to-back ``MXM`` with a ``.reuse`` operand hit an operand-forwarding
    buffer (lower issue interval) unless a DMA issue intervened — the
    TPU-idiomatic re-model of the paper's §5.7.1 operand-reuse-cache
    discovery.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import parser as tsass_parser
from repro.core.isa import Instruction, OpClass, base_opcode

# ---------------------------------------------------------------------------
# PRIVATE ground truth.  Only tests and this module may look.
# ---------------------------------------------------------------------------

_TRUE_FIXED_LAT: Dict[str, int] = {
    # scalar core (paper Table 1: common integer ops 4, wide ops 5)
    "SADD": 4, "SADDX": 4, "SMUL": 4, "SMOV": 4, "SLEA": 4, "SSEL": 4,
    "SMIN": 4, "SSHL": 4,
    "SMULW": 5,
    # VPU lanes
    "VADD": 4, "VSUB": 4, "VMUL": 4, "VFMA": 4, "VMAX": 4,
    "VEXP": 8, "VRSQ": 8, "VRECIP": 8,
    # MXU result latency (systolic drain)
    "MXM": 24,
    # cycle-counter read
    "SCLK": 2,
}

_MXU_ISSUE_INTERVAL = 8          # cycles between MXM issues (throughput)
_MXU_REUSE_INTERVAL = 6          # ... when the operand-forwarding buffer hits
_DMA_SETUP = 48                  # per-copy engine setup cycles
_DMA_BYTES_PER_CYCLE = 32        # per-engine sustained bandwidth
_DMA_QUEUE_DEPTH = 6             # outstanding copies per engine
_NUM_IN_ENGINES = 2
_LDV_LAT = 12                    # VMEM->VREG (LDS analogue)
_STV_LAT = 4
_VMEM_PORTS = 2                  # concurrent LDV/STV issue slots
_VMEM_PORT_HOLD = 2              # cycles a port stays busy per access
_DEFAULT_DMA_BYTES = 16          # CPYIN without a size modifier = 128-bit
_SERIAL_STALL = 1024             # > any single-instruction latency; used by
                                 # the dataflow reference executor


def _dma_bytes(opcode: str) -> int:
    for part in opcode.split(".")[1:]:
        if part.isdigit():
            return int(part)
    return _DEFAULT_DMA_BYTES


def true_fixed_latency(opcode: str) -> Optional[int]:
    """TEST-ONLY oracle; optimizer code must not call this."""
    if opcode in _TRUE_FIXED_LAT:
        return _TRUE_FIXED_LAT[opcode]
    return _TRUE_FIXED_LAT.get(base_opcode(opcode))


# ---------------------------------------------------------------------------
# dataflow value domain: 64-bit hashes
# ---------------------------------------------------------------------------

_MASK64 = (1 << 64) - 1


def _mix_str(s: str) -> int:
    h = 1469598103934665603
    for ch in s.encode():
        h = ((h ^ ch) * 1099511628211) & _MASK64
    return h


def _mix(*vals) -> int:
    h = 0x9E3779B97F4A7C15
    for v in vals:
        if isinstance(v, str):
            v = _mix_str(v)
        h ^= (v + 0x9E3779B97F4A7C15 + ((h << 6) & _MASK64) + (h >> 2)) & _MASK64
        h = (h * 0xBF58476D1CE4E5B9) & _MASK64
        h ^= h >> 27
    return h & _MASK64


class _ExecInfo:
    """Per-instruction execution metadata, computed once and cached on the
    instruction object (instructions are immutable during games; only their
    order changes), keeping the reward loop fast."""

    __slots__ = ("base", "klass", "uses", "defs", "effects", "read_cells",
                 "write_cells", "hbm_src", "nbytes", "pred_off", "lat",
                 "imm", "ldv_dst", "reuse_op")

    def __init__(self, ins: Instruction):
        self.base = ins.base
        self.klass = ins.klass
        self.uses = tuple(sorted(ins.uses or ()))
        self.defs = tuple(sorted(ins.defs or ()))
        self.effects = tuple(tsass_parser.memory_effects(ins))
        self.read_cells = tuple(c for c, w in self.effects if not w)
        self.write_cells = tuple(c for c, w in self.effects if w)
        self.hbm_src = _hbm_source_cell(ins) if self.base == "CPYIN" else None
        self.nbytes = _dma_bytes(ins.opcode) if self.base in ("CPYIN", "CPYOUT") else 0
        self.pred_off = ins.predicated_off()
        self.lat = true_fixed_latency(ins.opcode)
        self.imm = (ins.operands[-1]
                    if self.base == "SMOV" and ins.operands
                    and not ins.operands[-1].startswith(("R", "UR", "["))
                    else None)
        dst = ins.operands[0] if ins.operands else None
        self.ldv_dst = (tuple(sorted(tsass_parser.expand_register(dst)))
                        if self.base == "LDV" and dst is not None
                        and not dst.startswith("[") else ())
        self.reuse_op = any(".reuse" in op for op in ins.operands)


def exec_info(ins: Instruction) -> _ExecInfo:
    info = getattr(ins, "_exec", None)
    if info is None:
        info = _ExecInfo(ins)
        ins._exec = info
    return info


@dataclasses.dataclass
class RunResult:
    cycles: float
    outputs: Dict[tuple, int]          # observable HBM cells -> final hash
    counters: Dict[str, float]
    reg_values: Dict[str, int]         # final committed register file


class _DelayedStore:
    """Name -> value store with delayed commit: a read before a pending
    write's ready time observes the stale committed value (no interlock)."""

    def __init__(self, uninit_tag: str, seed: int):
        self._committed: Dict = {}
        self._pending: Dict[object, List[Tuple[float, int, int]]] = {}
        self._tag = uninit_tag
        self._seed = seed
        self._seq = 0

    def read(self, key, t: float):
        pend = self._pending.get(key)
        if pend:
            keep = []
            for ready, seq, val in sorted(pend):
                if ready <= t:
                    self._committed[key] = val
                else:
                    keep.append((ready, seq, val))
            if keep:
                self._pending[key] = keep
            else:
                del self._pending[key]
        if key not in self._committed:
            self._committed[key] = _mix(self._tag, self._seed, str(key))
        return self._committed[key]

    def write(self, key, val: int, ready: float) -> None:
        self._seq += 1
        self._pending.setdefault(key, []).append((ready, self._seq, val))

    def finalize(self) -> Dict:
        for key in list(self._pending):
            self.read(key, float("inf"))
        return dict(self._committed)


def _hbm_source_cell(ins: Instruction) -> tuple:
    """The HBM cell a CPYIN reads.  Lowering identifies logical tiles, so a
    tile token gives ``("hbm", space, idx)``; otherwise fall back to the
    textual source operand (exact for hand-written microbenchmarks)."""
    if ins.tile is not None:
        return ("hbm",) + ins.tile
    srcs = [op for op in ins.operands[1:]] or ["?"]
    return ("hbm", "|".join(srcs))


class Machine:
    """Cycle-level scoreboard executor for TSASS programs.

    ``run`` is the full-fidelity oracle (timing + dataflow hashes);
    ``time`` is the timing-only fast path (:mod:`repro.core.timing`),
    bit-exact against ``run(...).cycles`` and the one the reward loop uses.
    """

    def __init__(self, noise: float = 0.0, seed: int = 0):
        self.noise = noise
        self._rng = random.Random(seed)

    def time(self, program: Sequence[Instruction],
             input_seed: int = 0) -> float:
        """Cycle count via the scoreboard rules alone — no dataflow hashes,
        no delayed stores.  Bit-exact against ``run(program).cycles``
        (property-tested).  ``input_seed`` is accepted for signature parity
        with ``run``; timing is independent of input values because reads
        never stall (no interlocks).  Measurement noise is applied exactly
        as in ``run`` (and draws from the same RNG stream)."""
        from repro.core import timing
        cycles = timing.time_program(program)
        if self.noise:
            cycles *= 1.0 + self._rng.gauss(0.0, self.noise)
        return cycles

    def issue_times(self, program: Sequence[Instruction]) -> List[float]:
        """Per-instruction issue cycles via the timing-only path (LABELs
        report the running cycle count).  An ``SCLK`` destination register
        ends up holding ``int(issue)``, so clock-style microbenchmarks can
        run here instead of through the dataflow oracle."""
        from repro.core import timing
        return timing.issue_times(program)

    def run(self, program: Sequence[Instruction], input_seed: int = 0,
            _serialize: bool = False) -> RunResult:
        regs = _DelayedStore("uninit-reg", input_seed)
        mem = _DelayedStore("uninit-mem", input_seed)
        sem_busy = [0.0] * 6
        in_engine_free = [0.0] * _NUM_IN_ENGINES
        out_engine_free = 0.0
        in_done: List[List[float]] = [[] for _ in range(_NUM_IN_ENGINES)]
        out_done: List[float] = []
        vmem_port_free = [0.0] * _VMEM_PORTS
        mxu_ready = 0.0
        last_mxm_srcs: frozenset = frozenset()
        dma_since_mxm = False
        next_in_engine = 0

        c = {
            "issued": 0, "exec_issued": 0, "cycles": 0.0,
            "stall_sem": 0.0, "stall_queue": 0.0, "stall_port": 0.0,
            "stall_mxu": 0.0, "stall_count_cycles": 0.0,
            "dma_bytes_in": 0, "dma_bytes_out": 0,
            "dma_busy_in": 0.0, "dma_busy_out": 0.0,
            "mxm_issues": 0, "mxm_reuse_hits": 0,
            "ldv": 0, "stv": 0, "cpyin": 0, "cpyout": 0,
        }

        t = 0.0
        end = 0.0
        for ins in program:
            info = exec_info(ins)
            base = info.base
            klass = info.klass
            if base == "LABEL":
                continue  # zero-size marker

            # -- semaphore waits (SASS wait-barrier mask) ---------------------
            t0 = t
            for s in ins.ctrl.wait_mask:
                t = max(t, sem_busy[s])
            c["stall_sem"] += t - t0

            executes = not info.pred_off

            # -- structural hazards -------------------------------------------
            if executes and base == "MXM":
                t1 = t
                t = max(t, mxu_ready)
                c["stall_mxu"] += t - t1
            if executes and base == "CPYIN":
                t1 = t
                q = in_done[next_in_engine]
                while len([d for d in q if d > t]) >= _DMA_QUEUE_DEPTH:
                    t = min(d for d in q if d > t)
                c["stall_queue"] += t - t1
            if executes and base == "CPYOUT":
                t1 = t
                while len([d for d in out_done if d > t]) >= _DMA_QUEUE_DEPTH:
                    t = min(d for d in out_done if d > t)
                c["stall_queue"] += t - t1
            if executes and base in ("LDV", "STV"):
                t1 = t
                p = min(range(_VMEM_PORTS), key=lambda i: vmem_port_free[i])
                t = max(t, vmem_port_free[p])
                c["stall_port"] += t - t1
                vmem_port_free[p] = t + _VMEM_PORT_HOLD

            # -- issue + effects ----------------------------------------------
            issue = t
            c["issued"] += 1
            if executes:
                c["exec_issued"] += 1
                srcs = [regs.read(r, issue) for r in info.uses]

                if klass in (OpClass.SCALAR, OpClass.VECTOR) or base == "SCLK":
                    lat = info.lat or 4
                    if base == "SCLK":
                        val = int(issue)
                    elif info.imm is not None:
                        val = _mix("imm", info.imm, input_seed)
                    else:
                        val = _mix(ins.opcode, *srcs)
                    for d in info.defs:
                        regs.write(d, val, issue + lat)

                elif base == "MXM":
                    lat = info.lat
                    srcs_set = frozenset(info.uses)
                    hit = (info.reuse_op and not dma_since_mxm
                           and bool(srcs_set & last_mxm_srcs))
                    if hit:
                        c["mxm_reuse_hits"] += 1
                    mxu_ready = issue + (_MXU_REUSE_INTERVAL if hit
                                         else _MXU_ISSUE_INTERVAL)
                    last_mxm_srcs = srcs_set
                    dma_since_mxm = False
                    c["mxm_issues"] += 1
                    val = _mix("MXM", *srcs)
                    for d in info.defs:
                        regs.write(d, val, issue + lat)

                elif base == "CPYIN":
                    nbytes = info.nbytes
                    eng = next_in_engine
                    next_in_engine = (next_in_engine + 1) % _NUM_IN_ENGINES
                    start = max(issue + _DMA_SETUP, in_engine_free[eng])
                    done = start + nbytes / _DMA_BYTES_PER_CYCLE
                    in_engine_free[eng] = done
                    in_done[eng].append(done)
                    c["dma_busy_in"] += done - start
                    c["dma_bytes_in"] += nbytes
                    c["cpyin"] += 1
                    dma_since_mxm = True
                    val = _mix("CPYIN",
                               mem.read(info.hbm_src, issue), *srcs)
                    for cell in info.write_cells:
                        mem.write(cell, val, done)
                    if ins.ctrl.write_bar is not None:
                        sem_busy[ins.ctrl.write_bar] = max(
                            sem_busy[ins.ctrl.write_bar], done)
                    if ins.ctrl.read_bar is not None:
                        sem_busy[ins.ctrl.read_bar] = max(
                            sem_busy[ins.ctrl.read_bar], start)

                elif base == "CPYOUT":
                    nbytes = info.nbytes
                    start = max(issue + _DMA_SETUP, out_engine_free)
                    done = start + nbytes / _DMA_BYTES_PER_CYCLE
                    out_engine_free = done
                    out_done.append(done)
                    c["dma_busy_out"] += done - start
                    c["dma_bytes_out"] += nbytes
                    c["cpyout"] += 1
                    dma_since_mxm = True
                    data = [mem.read(cell, start) for cell in info.read_cells]
                    val = _mix("CPYOUT", *(data + srcs))
                    for cell in info.write_cells:
                        mem.write(cell, val, done)
                    if ins.ctrl.write_bar is not None:
                        sem_busy[ins.ctrl.write_bar] = max(
                            sem_busy[ins.ctrl.write_bar], done)
                    if ins.ctrl.read_bar is not None:
                        sem_busy[ins.ctrl.read_bar] = max(
                            sem_busy[ins.ctrl.read_bar], start)

                elif base == "LDV":
                    done = issue + _LDV_LAT
                    c["ldv"] += 1
                    data = [mem.read(cell, issue) for cell in info.read_cells]
                    val = _mix("LDV", *(data + srcs))
                    for r in info.ldv_dst:
                        regs.write(r, val, done)
                    if ins.ctrl.write_bar is not None:
                        sem_busy[ins.ctrl.write_bar] = max(
                            sem_busy[ins.ctrl.write_bar], done)

                elif base == "STV":
                    done = issue + _STV_LAT
                    c["stv"] += 1
                    val = _mix("STV", *srcs)
                    for cell in info.write_cells:
                        mem.write(cell, val, done)
                    if ins.ctrl.read_bar is not None:
                        sem_busy[ins.ctrl.read_bar] = max(
                            sem_busy[ins.ctrl.read_bar], issue + 2)

                elif base == "SEMWAIT":
                    t = max([t] + sem_busy)
                    issue = t

            # -- advance by the stall count ------------------------------------
            step = max(1, _SERIAL_STALL if _serialize else ins.ctrl.stall)
            c["stall_count_cycles"] += max(0, ins.ctrl.stall - 1)
            t = issue + step
            end = max(end, t)

        end = max([end, out_engine_free] + list(in_engine_free) + sem_busy)
        cycles = float(end)
        if self.noise:
            cycles *= 1.0 + self._rng.gauss(0.0, self.noise)

        reg_final = regs.finalize()
        mem_final = mem.finalize()
        outputs = {cell: v for cell, v in mem_final.items()
                   if cell[0] == "addr"
                   or (cell[0] == "tile" and str(cell[1]).startswith("out"))}
        c["cycles"] = cycles
        c["ipc"] = c["exec_issued"] / max(cycles, 1.0)
        c["bw_in_Bpc"] = c["dma_bytes_in"] / max(cycles, 1.0)
        c["bw_out_Bpc"] = c["dma_bytes_out"] / max(cycles, 1.0)
        c["dma_busy_in_frac"] = c["dma_busy_in"] / max(cycles * _NUM_IN_ENGINES, 1.0)
        c["dma_busy_out_frac"] = c["dma_busy_out"] / max(cycles, 1.0)
        return RunResult(cycles, outputs, c, reg_final)


def dataflow_reference(program: Sequence[Instruction],
                       input_seed: int = 0) -> Dict[tuple, int]:
    """Oracle semantics: the program executed with every latency trivially
    satisfied (each instruction fully completes before the next issues).
    Any *valid* reordering must reproduce exactly this observable HBM state —
    the contract behind the paper's probabilistic testing (§4.1)."""
    return Machine().run(program, input_seed=input_seed,
                         _serialize=True).outputs
