"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

Training path uses the chunked SSD oracle (repro.kernels.ref.ssd_chunk; the
Pallas version is repro.kernels.ssd); decode carries an O(1) recurrent state
(B, H, P, N) plus a depthwise-conv tail — the property that makes the
``long_500k`` cell tractable for mamba2/zamba2.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.nn.core import ParamSpec, dense
from repro.nn.layers import apply_rmsnorm, rmsnorm_spec

CONV_WIDTH = 4


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_inner: int            # usually 2 * d_model
    n_heads: int            # d_inner // head_p
    head_p: int             # channels per head (P)
    n_groups: int           # B/C groups (G)
    d_state: int            # N


def ssm_spec(cfg: SSMConfig) -> Dict:
    conv_dim = cfg.d_inner + 2 * cfg.n_groups * cfg.d_state
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.n_groups * cfg.d_state + cfg.n_heads
    return {
        "in_proj": dense(cfg.d_model, d_in_proj, ("embed", "mlp")),
        "conv_w": ParamSpec((CONV_WIDTH, conv_dim), (None, "mlp"), "normal",
                            scale=0.5),
        "conv_b": ParamSpec((conv_dim,), ("mlp",), "zeros"),
        "a_log": ParamSpec((cfg.n_heads,), ("heads",), "zeros"),
        "d_skip": ParamSpec((cfg.n_heads,), ("heads",), "ones"),
        "dt_bias": ParamSpec((cfg.n_heads,), ("heads",), "zeros"),
        "norm": rmsnorm_spec(cfg.d_inner, "mlp"),
        "out_proj": dense(cfg.d_inner, cfg.d_model, ("mlp", "embed")),
    }


def _split_proj(cfg: SSMConfig, zxbcdt: jax.Array):
    gn = cfg.n_groups * cfg.d_state
    z, x, b, c, dt = jnp.split(
        zxbcdt,
        [cfg.d_inner, 2 * cfg.d_inner, 2 * cfg.d_inner + gn,
         2 * cfg.d_inner + 2 * gn],
        axis=-1)
    return z, x, b, c, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, width CONV_WIDTH.  x: (B, S, C); w: (W, C)."""
    pads = jnp.pad(x, ((0, 0), (CONV_WIDTH - 1, 0), (0, 0)))
    out = sum(pads[:, i: i + x.shape[1], :] * w[i][None, None, :]
              for i in range(CONV_WIDTH))
    return out + b[None, None, :]


def apply_ssm(p: Dict, x: jax.Array, cfg: SSMConfig) -> jax.Array:
    """Training/prefill forward.  x: (B, S, d_model)."""
    from repro.nn.core import apply_dense
    B, S, _ = x.shape
    zxbcdt = apply_dense(p["in_proj"], x)
    z, xs, b, c, dt = _split_proj(cfg, zxbcdt)
    gn = cfg.n_groups * cfg.d_state
    conv_in = jnp.concatenate([xs, b, c], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"].astype(x.dtype),
                                        p["conv_b"].astype(x.dtype)))
    xs, b, c = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + gn], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))     # (B,S,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32)) * dt            # log decay
    xh = xs.reshape(B, S, cfg.n_heads, cfg.head_p)
    xh = xh * dt[..., None].astype(xh.dtype)                     # dt-scaled input
    bh = b.reshape(B, S, cfg.n_groups, cfg.d_state)
    ch = c.reshape(B, S, cfg.n_groups, cfg.d_state)
    y = kref.ssd_chunk(xh, a, bh, ch)                            # (B,S,H,P)
    y = y + xh * p["d_skip"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(B, S, cfg.d_inner)
    y = apply_rmsnorm(p["norm"], y * jax.nn.silu(z))
    return apply_dense(p["out_proj"], y)


# ---------------------------------------------------------------------------
# decode: O(1) state per step
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg: SSMConfig, batch: int, dtype=jnp.float32) -> Dict:
    conv_dim = cfg.d_inner + 2 * cfg.n_groups * cfg.d_state
    return {
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, cfg.n_heads, cfg.head_p, cfg.d_state),
                           jnp.float32),
    }


def apply_ssm_decode(p: Dict, x: jax.Array, cache: Dict,
                     cfg: SSMConfig) -> Tuple[jax.Array, Dict]:
    """One-token step.  x: (B, 1, d_model)."""
    from repro.nn.core import apply_dense
    B = x.shape[0]
    zxbcdt = apply_dense(p["in_proj"], x)
    z, xs, b, c, dt = _split_proj(cfg, zxbcdt)
    gn = cfg.n_groups * cfg.d_state
    conv_in = jnp.concatenate([xs, b, c], axis=-1)               # (B,1,C)
    window = jnp.concatenate([cache["conv"], conv_in], axis=1)   # (B,W,C)
    w = p["conv_w"].astype(x.dtype)
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window, w)[:, None, :]
        + p["conv_b"].astype(x.dtype)[None, None, :])
    xs, b, c = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + gn], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))[:, 0]   # (B,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32)) * dt                # (B,H)
    xh = xs.reshape(B, cfg.n_heads, cfg.head_p) * dt[..., None].astype(xs.dtype)
    rep = cfg.n_heads // cfg.n_groups
    bh = jnp.repeat(b.reshape(B, cfg.n_groups, cfg.d_state), rep, axis=1)
    ch = jnp.repeat(c.reshape(B, cfg.n_groups, cfg.d_state), rep, axis=1)

    decay = jnp.exp(a)[..., None, None]                              # (B,H,1,1)
    state = cache["state"] * decay + (xh.astype(jnp.float32)[..., None]
                                      * bh.astype(jnp.float32)[..., None, :])
    y = jnp.einsum("bhpn,bhn->bhp", state, ch.astype(jnp.float32))
    y = y.astype(x.dtype) + xh * p["d_skip"].astype(xh.dtype)[None, :, None]
    y = y.reshape(B, 1, cfg.d_inner)
    y = apply_rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = apply_dense(p["out_proj"], y)
    new_cache = {"conv": window[:, 1:], "state": state}
    return out, new_cache
