"""Pure-JAX NN substrate (no flax): params-as-pytrees + (init, apply)."""

from repro.nn.attention import (NO_WINDOW, chunked_attention,
                                decode_attention, gather_page_window,
                                gather_pages, gqa_spec,
                                masked_decode_attention, out_project,
                                paged_decode_attention, paged_flat_index,
                                paged_update_cache, qkv_project, update_cache)
from repro.nn.core import (ParamSpec, apply_dense, dense, init_params,
                           logical_axes, stack_specs)
from repro.nn.layers import (apply_embedding, apply_gelu_mlp, apply_layernorm,
                             apply_lm_head, apply_rmsnorm, apply_swiglu,
                             embedding_spec, gelu_mlp_spec, layernorm_spec,
                             lm_head_spec, rmsnorm_spec, swiglu_spec, unembed)
from repro.nn.mla import (MLAConfig, apply_mla, apply_mla_decode,
                          apply_mla_paged_decode, init_mla_cache,
                          init_paged_mla_cache, mla_spec)
from repro.nn.moe import MoEConfig, apply_moe, apply_moe_dense, moe_spec
from repro.nn.rope import apply_rope
from repro.nn.ssm import (SSMConfig, apply_ssm, apply_ssm_decode,
                          init_ssm_cache, ssm_spec)
