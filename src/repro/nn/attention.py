"""GQA attention with online-softmax KV chunking.

One implementation serves every attention arch here:
  * training / prefill: ``chunked_attention`` — lax.scan over KV chunks with
    a running (max, sum, acc), so activation memory is O(S·chunk) instead of
    O(S²) and the HLO stays compact for the 512-device dry-run;
  * decode: ``decode_attention`` — one query against the KV cache (masked to
    the current position / sliding window).  Under pjit the cache may be
    sharded on heads or on sequence; the SPMD partitioner inserts the
    partial-softmax combine collectives for the latter.

Sliding windows are expressed as a (possibly traced, per-layer) scalar with
``NO_WINDOW`` meaning global — one code path covers gemma-style 5:1
local:global stacks inside a scan over layers.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.nn.core import dense

NO_WINDOW = 1 << 30
_NEG = -1e30


def gqa_spec(d_model: int, n_heads: int, n_kv: int, head_dim: int,
             qkv_bias: bool = False) -> Dict:
    return {
        "wq": dense(d_model, n_heads * head_dim, ("embed", "heads"),
                    bias=qkv_bias),
        "wk": dense(d_model, n_kv * head_dim, ("embed", "kv_heads"),
                    bias=qkv_bias),
        "wv": dense(d_model, n_kv * head_dim, ("embed", "kv_heads"),
                    bias=qkv_bias),
        "wo": dense(n_heads * head_dim, d_model, ("heads", "embed")),
    }


def qkv_project(p: Dict, x: jax.Array, n_heads: int, n_kv: int,
                head_dim: int):
    from repro.nn.core import apply_dense
    B, S, _ = x.shape
    q = apply_dense(p["wq"], x).reshape(B, S, n_heads, head_dim)
    k = apply_dense(p["wk"], x).reshape(B, S, n_kv, head_dim)
    v = apply_dense(p["wv"], x).reshape(B, S, n_kv, head_dim)
    return q, k, v


def out_project(p: Dict, o: jax.Array,
                tp_axis: Optional[str] = None) -> jax.Array:
    """``tp_axis`` (explicit tensor parallelism inside a ``shard_map``):
    ``o`` holds this rank's head shard, ``wo`` the matching row shard, and
    the partial output projection is assembled by a ``psum``."""
    from repro.nn.core import apply_dense
    B, S, H, D = o.shape
    y = apply_dense(p["wo"], o.reshape(B, S, H * D))
    if tp_axis is not None:
        y = jax.lax.psum(y, tp_axis)
    return y


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True,
                      window=NO_WINDOW,
                      chunk: int = 1024,
                      q_offset: int = 0,
                      scale: Optional[float] = None) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, KH, D) with H % KH == 0.

    Online softmax over KV chunks (flash-attention recurrence in XLA ops —
    the Pallas kernel version of the same math lives in repro.kernels).
    """
    B, Sq, H, D = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]                      # may differ from D (MLA)
    G = H // KH
    if scale is None:
        scale = D ** -0.5
    chunk = min(chunk, Sk)
    assert Sk % chunk == 0, (Sk, chunk)
    n_chunks = Sk // chunk

    qf = q.astype(jnp.float32) * scale
    q_pos = q_offset + jnp.arange(Sq)

    kc = jnp.moveaxis(k.reshape(B, n_chunks, chunk, KH, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, chunk, KH, Dv), 1, 0)

    def body(carry, xs):
        m, ell, acc = carry
        kb, vb, cidx = xs
        k_pos = cidx * chunk + jnp.arange(chunk)
        # (B, KH, G, Sq, C)
        logits = jnp.einsum("bqhgd,bchd->bhgqc",
                            qf.reshape(B, Sq, KH, G, D).transpose(0, 1, 2, 3, 4),
                            kb.astype(jnp.float32))
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        logits = jnp.where(mask[None, None, None], logits, _NEG)
        m_new = jnp.maximum(m, logits.max(axis=-1, keepdims=True))
        p_ = jnp.exp(logits - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = ell * alpha + p_.sum(axis=-1, keepdims=True)
        pv = jnp.einsum("bhgqc,bchd->bhgqd", p_, vb.astype(jnp.float32))
        acc_new = acc * alpha + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KH, G, Sq, 1), _NEG, jnp.float32)
    l0 = jnp.zeros((B, KH, G, Sq, 1), jnp.float32)
    a0 = jnp.zeros((B, KH, G, Sq, Dv), jnp.float32)
    (m, ell, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(ell, 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)


def masked_decode_attention(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, mask: jax.Array, *,
                            scale: Optional[float] = None) -> jax.Array:
    """One-query attention against a gathered cache under an explicit mask.

    q: (B, 1, H, D); caches: (B, S, KH, Dv); mask: (S,) shared across rows
    or (B, S) per-row (ragged positions).  This is THE decode softmax —
    the dense slot path and the paged block-table path both call it, so
    their outputs are bit-identical whenever the gathered (k, v, mask)
    triples match.  Masked positions contribute exactly 0.0 regardless of
    the cache values there (``where`` replaces their logits with -1e30 and
    ``exp(-1e30 - m)`` underflows), so garbage in never-written or
    clamped-gather positions cannot perturb the output."""
    B, _, H, D = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = H // KH
    if scale is None:
        scale = D ** -0.5
    qf = q.astype(jnp.float32) * scale
    logits = jnp.einsum("bqhgd,bshd->bhgqs", qf.reshape(B, 1, KH, G, D),
                        k_cache.astype(jnp.float32))
    maskb = (mask[None, None, None, None] if mask.ndim == 1
             else mask[:, None, None, None, :])
    logits = jnp.where(maskb, logits, _NEG)
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    ell = p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhgqs,bshd->bhgqd", p, v_cache.astype(jnp.float32)) / ell
    return o.transpose(0, 3, 1, 2, 4).reshape(B, 1, H, Dv).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window=NO_WINDOW,
                     scale: Optional[float] = None) -> jax.Array:
    """q: (B, 1, H, D); caches: (B, S, KH, D); pos: scalar index of the
    current token.  One masked softmax over the cache (linear per step)."""
    S = k_cache.shape[1]
    k_pos = jnp.arange(S)
    mask = (k_pos <= pos) & (k_pos > pos - window)
    return masked_decode_attention(q, k_cache, v_cache, mask, scale=scale)


def update_cache(cache: jax.Array, new: jax.Array, pos) -> jax.Array:
    """Write ``new`` (B, 1, KH, D) into (B, S, KH, D) at ``pos``.

    ``pos`` may be a scalar (every batch row writes the same position —
    the static-batch generate path, via dynamic_update_slice touching
    O(slice) bytes) or a ``(B,)`` vector (each row writes its own
    position — the continuous-batching ragged decode path, via a
    per-row scatter)."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim >= 1:
        rows = jnp.arange(cache.shape[0])
        return cache.at[rows, pos].set(new[:, 0].astype(cache.dtype))
    zero = jnp.zeros((), jnp.int32)
    return jax.lax.dynamic_update_slice(
        cache, new.astype(cache.dtype), (zero, pos, zero, zero))


# ---------------------------------------------------------------------------
# paged KV: physical pages indexed through per-request block tables
# ---------------------------------------------------------------------------
#
# The paged layout stores KV block-major — ``pages`` is
# ``(num_blocks, block_size, *rest)`` shared by every request — and each
# request addresses its sequence through a row of physical block ids
# (``block_table``: (B, blocks_per_slot) int32, padded with 0 past the
# granted blocks; reads there are masked, writes suppressed).  Absolute
# position ``p`` of row ``b`` lives at page slot
# ``(block_table[b, p // block_size], p % block_size)``.


def paged_flat_index(block_table: jax.Array, pos: jax.Array,
                     block_size: int) -> jax.Array:
    """(B,) flattened page-slot index of absolute position ``pos`` per row
    (into ``pages.reshape(num_blocks * block_size, ...)``)."""
    pos = jnp.asarray(pos, jnp.int32)
    rows = jnp.arange(block_table.shape[0])
    blk = block_table[rows, pos // block_size]
    return blk * block_size + pos % block_size


def paged_update_cache(pages: jax.Array, new: jax.Array,
                       block_table: jax.Array, pos: jax.Array, *,
                       write_mask: Optional[jax.Array] = None) -> jax.Array:
    """Write ``new`` (B, 1, *rest) into block-major ``pages``
    (num_blocks, block_size, *rest) at per-row absolute positions ``pos``.

    One masked scatter: lanes with ``write_mask`` False (idle lanes padded
    into the fixed-width batch, or shared-prefix re-run passes whose
    target position is owned by a shared block) are routed to an
    out-of-range index and dropped (``mode="drop"``) — no scratch row, no
    duplicate writes, safe under buffer donation.  Active lanes write
    distinct page slots by construction (each writes into a block its
    request owns exclusively — copy-on-write forks shared blocks first)."""
    N, bs = pages.shape[:2]
    flat = paged_flat_index(block_table, pos, bs)
    if write_mask is not None:
        flat = jnp.where(write_mask, flat, N * bs)
    rest = pages.shape[2:]
    out = pages.reshape(N * bs, *rest).at[flat].set(
        new[:, 0].astype(pages.dtype), mode="drop")
    return out.reshape(N, bs, *rest)


def gather_pages(pages: jax.Array, block_table: jax.Array,
                 width: int) -> jax.Array:
    """Gather absolute positions ``[0, width)`` of every row:
    (B, width, *rest).  ``width`` may be below the table's coverage
    (``max_seq`` not a multiple of ``block_size``) — the tail page slots
    are simply never materialized into the attention operand, keeping the
    contraction width identical to the dense layer's cache."""
    B, nb = block_table.shape
    bs = pages.shape[1]
    g = pages[block_table].reshape(B, nb * bs, *pages.shape[2:])
    return g[:, :width]


def gather_page_window(pages: jax.Array, block_table: jax.Array,
                       pos: jax.Array, width: int) -> jax.Array:
    """Gather the trailing window — absolute positions
    ``pos - width + 1 .. pos`` per row — as (B, width, *rest).

    This reconstructs exactly what the dense sliding-window ring buffer
    holds after its shift-and-append, so windowed layers stay bit-exact
    under paging.  Negative positions clamp to 0; callers mask them
    (``k_positions >= 0``), and masked garbage contributes exactly 0."""
    N, bs = pages.shape[:2]
    pos = jnp.asarray(pos, jnp.int32)
    abs_pos = jnp.maximum(pos[:, None] + jnp.arange(width)[None, :]
                          - (width - 1), 0)                      # (B, W)
    blk = jnp.take_along_axis(block_table, abs_pos // bs, axis=1)
    flat = blk * bs + abs_pos % bs
    return pages.reshape(N * bs, *pages.shape[2:])[flat]


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_table: jax.Array,
                           pos: jax.Array, *, window: int = NO_WINDOW,
                           width: Optional[int] = None,
                           scale: Optional[float] = None) -> jax.Array:
    """One-token attention through the block table.

    q: (B, 1, H, D); k/v pages: (num_blocks, block_size, KH, D);
    block_table: (B, blocks_per_slot) physical ids; pos: (B,) per-row
    ragged positions.  ``window``/``width`` must be static ints (they pick
    the gather shape — one compile per layer geometry): bounded windows
    gather the ``width``-sized trailing window, global attention gathers
    absolute positions ``[0, width)``.  The gathered operands — and hence
    the outputs — are bit-identical to the dense slot path's whenever
    ``width`` matches the dense layer's cache length."""
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None]
    if window < NO_WINDOW and width is not None and width <= window:
        S = width
        k_g = gather_page_window(k_pages, block_table, pos, S)
        v_g = gather_page_window(v_pages, block_table, pos, S)
        mask = (positions - (S - 1) + jnp.arange(S)[None]) >= 0
    else:
        S = width if width is not None \
            else block_table.shape[1] * k_pages.shape[1]
        k_g = gather_pages(k_pages, block_table, S)
        v_g = gather_pages(v_pages, block_table, S)
        k_positions = jnp.arange(S)[None]
        mask = (k_positions <= positions) & (k_positions > positions - window)
    return masked_decode_attention(q, k_g, v_g, mask, scale=scale)
