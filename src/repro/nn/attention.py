"""GQA attention with online-softmax KV chunking.

One implementation serves every attention arch here:
  * training / prefill: ``chunked_attention`` — lax.scan over KV chunks with
    a running (max, sum, acc), so activation memory is O(S·chunk) instead of
    O(S²) and the HLO stays compact for the 512-device dry-run;
  * decode: ``decode_attention`` — one query against the KV cache (masked to
    the current position / sliding window).  Under pjit the cache may be
    sharded on heads or on sequence; the SPMD partitioner inserts the
    partial-softmax combine collectives for the latter.

Sliding windows are expressed as a (possibly traced, per-layer) scalar with
``NO_WINDOW`` meaning global — one code path covers gemma-style 5:1
local:global stacks inside a scan over layers.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.nn.core import dense

NO_WINDOW = 1 << 30
_NEG = -1e30


def gqa_spec(d_model: int, n_heads: int, n_kv: int, head_dim: int,
             qkv_bias: bool = False) -> Dict:
    return {
        "wq": dense(d_model, n_heads * head_dim, ("embed", "heads"),
                    bias=qkv_bias),
        "wk": dense(d_model, n_kv * head_dim, ("embed", "kv_heads"),
                    bias=qkv_bias),
        "wv": dense(d_model, n_kv * head_dim, ("embed", "kv_heads"),
                    bias=qkv_bias),
        "wo": dense(n_heads * head_dim, d_model, ("heads", "embed")),
    }


def qkv_project(p: Dict, x: jax.Array, n_heads: int, n_kv: int,
                head_dim: int):
    from repro.nn.core import apply_dense
    B, S, _ = x.shape
    q = apply_dense(p["wq"], x).reshape(B, S, n_heads, head_dim)
    k = apply_dense(p["wk"], x).reshape(B, S, n_kv, head_dim)
    v = apply_dense(p["wv"], x).reshape(B, S, n_kv, head_dim)
    return q, k, v


def out_project(p: Dict, o: jax.Array,
                tp_axis: Optional[str] = None) -> jax.Array:
    """``tp_axis`` (explicit tensor parallelism inside a ``shard_map``):
    ``o`` holds this rank's head shard, ``wo`` the matching row shard, and
    the partial output projection is assembled by a ``psum``."""
    from repro.nn.core import apply_dense
    B, S, H, D = o.shape
    y = apply_dense(p["wo"], o.reshape(B, S, H * D))
    if tp_axis is not None:
        y = jax.lax.psum(y, tp_axis)
    return y


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True,
                      window=NO_WINDOW,
                      chunk: int = 1024,
                      q_offset: int = 0,
                      scale: Optional[float] = None) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, KH, D) with H % KH == 0.

    Online softmax over KV chunks (flash-attention recurrence in XLA ops —
    the Pallas kernel version of the same math lives in repro.kernels).
    """
    B, Sq, H, D = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]                      # may differ from D (MLA)
    G = H // KH
    if scale is None:
        scale = D ** -0.5
    chunk = min(chunk, Sk)
    assert Sk % chunk == 0, (Sk, chunk)
    n_chunks = Sk // chunk

    qf = q.astype(jnp.float32) * scale
    q_pos = q_offset + jnp.arange(Sq)

    kc = jnp.moveaxis(k.reshape(B, n_chunks, chunk, KH, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, chunk, KH, Dv), 1, 0)

    def body(carry, xs):
        m, ell, acc = carry
        kb, vb, cidx = xs
        k_pos = cidx * chunk + jnp.arange(chunk)
        # (B, KH, G, Sq, C)
        logits = jnp.einsum("bqhgd,bchd->bhgqc",
                            qf.reshape(B, Sq, KH, G, D).transpose(0, 1, 2, 3, 4),
                            kb.astype(jnp.float32))
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        logits = jnp.where(mask[None, None, None], logits, _NEG)
        m_new = jnp.maximum(m, logits.max(axis=-1, keepdims=True))
        p_ = jnp.exp(logits - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = ell * alpha + p_.sum(axis=-1, keepdims=True)
        pv = jnp.einsum("bhgqc,bchd->bhgqd", p_, vb.astype(jnp.float32))
        acc_new = acc * alpha + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KH, G, Sq, 1), _NEG, jnp.float32)
    l0 = jnp.zeros((B, KH, G, Sq, 1), jnp.float32)
    a0 = jnp.zeros((B, KH, G, Sq, Dv), jnp.float32)
    (m, ell, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(ell, 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window=NO_WINDOW,
                     scale: Optional[float] = None) -> jax.Array:
    """q: (B, 1, H, D); caches: (B, S, KH, D); pos: scalar index of the
    current token.  One masked softmax over the cache (linear per step)."""
    B, _, H, D = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    if scale is None:
        scale = D ** -0.5
    qf = q.astype(jnp.float32) * scale
    k_pos = jnp.arange(S)
    logits = jnp.einsum("bqhgd,bshd->bhgqs",
                        qf.reshape(B, 1, KH, G, D),
                        k_cache.astype(jnp.float32))
    mask = (k_pos <= pos) & (k_pos > pos - window)
    logits = jnp.where(mask[None, None, None, None], logits, _NEG)
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    ell = p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhgqs,bshd->bhgqd", p, v_cache.astype(jnp.float32)) / ell
    return o.transpose(0, 3, 1, 2, 4).reshape(B, 1, H, D).astype(q.dtype)


def update_cache(cache: jax.Array, new: jax.Array, pos) -> jax.Array:
    """Write ``new`` (B, 1, KH, D) into (B, S, KH, D) at ``pos``.

    ``pos`` may be a scalar (every batch row writes the same position —
    the static-batch generate path, via dynamic_update_slice touching
    O(slice) bytes) or a ``(B,)`` vector (each row writes its own
    position — the continuous-batching ragged decode path, via a
    per-row scatter)."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim >= 1:
        rows = jnp.arange(cache.shape[0])
        return cache.at[rows, pos].set(new[:, 0].astype(cache.dtype))
    zero = jnp.zeros((), jnp.int32)
    return jax.lax.dynamic_update_slice(
        cache, new.astype(cache.dtype), (zero, pos, zero, zero))
