"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV is compressed to a ``kv_lora_rank``-dim latent + a shared RoPE key part;
the decode cache stores only the latent (+rope key) — the MLA memory win.
Training materializes full K/V and reuses the chunked-attention path; decode
uses the weight-absorbed latent-space form.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.nn.attention import (chunked_attention, gather_pages,
                                paged_update_cache)
from repro.nn.core import ParamSpec, apply_dense, dense
from repro.nn.layers import apply_rmsnorm, rmsnorm_spec
from repro.nn.rope import apply_rope


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0

    @property
    def qk_dim(self) -> int:
        return self.qk_nope_dim + self.qk_rope_dim


def mla_spec(cfg: MLAConfig) -> Dict:
    H = cfg.n_heads
    return {
        "wq": dense(cfg.d_model, H * cfg.qk_dim, ("embed", "heads")),
        "w_dkv": dense(cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_dim,
                       ("embed", None)),
        "kv_norm": rmsnorm_spec(cfg.kv_lora_rank, None),
        "w_uk": ParamSpec((cfg.kv_lora_rank, H, cfg.qk_nope_dim),
                          (None, "heads", None)),
        "w_uv": ParamSpec((cfg.kv_lora_rank, H, cfg.v_head_dim),
                          (None, "heads", None)),
        "wo": dense(H * cfg.v_head_dim, cfg.d_model, ("heads", "embed")),
    }


def _latent(p: Dict, x: jax.Array, cfg: MLAConfig, positions: jax.Array):
    """Compressed latent + rope key part for a span of positions."""
    dkv = apply_dense(p["w_dkv"], x)
    c_kv, k_rope = jnp.split(dkv, [cfg.kv_lora_rank], axis=-1)
    c_kv = apply_rmsnorm(p["kv_norm"], c_kv)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)   # (B,S,rope_dim)
    return c_kv, k_rope


def _queries(p: Dict, x: jax.Array, cfg: MLAConfig, positions: jax.Array):
    B, S, _ = x.shape
    q = apply_dense(p["wq"], x).reshape(B, S, cfg.n_heads, cfg.qk_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def apply_mla(p: Dict, x: jax.Array, cfg: MLAConfig, *, causal: bool = True,
              q_offset: int = 0, chunk: int = 1024) -> jax.Array:
    """Training/prefill path: decompress K/V, run chunked attention."""
    B, S, _ = x.shape
    positions = q_offset + jnp.arange(S)
    c_kv, k_rope = _latent(p, x, cfg, positions[None, :])
    q_nope, q_rope = _queries(p, x, cfg, positions[None, :])

    k_nope = jnp.einsum("bsr,rhd->bshd", c_kv.astype(jnp.float32),
                        p["w_uk"].astype(jnp.float32)).astype(x.dtype)
    v = jnp.einsum("bsr,rhd->bshd", c_kv.astype(jnp.float32),
                   p["w_uv"].astype(jnp.float32)).astype(x.dtype)
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (B, S, cfg.n_heads, cfg.qk_rope_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h.astype(x.dtype)], axis=-1)
    o = chunked_attention(q, k, v, causal=causal, chunk=chunk,
                          q_offset=q_offset, scale=cfg.qk_dim ** -0.5)
    return apply_dense(p["wo"], o.reshape(B, S, -1))


# ---------------------------------------------------------------------------
# decode: latent cache + absorbed weights
# ---------------------------------------------------------------------------

def init_mla_cache(cfg: MLAConfig, batch: int, max_seq: int,
                   dtype=jnp.bfloat16) -> Dict:
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dtype),
    }


def apply_mla_decode(p: Dict, x: jax.Array, cache: Dict, pos,
                     cfg: MLAConfig) -> Tuple[jax.Array, Dict]:
    """One-token step against the latent cache (weight-absorbed form:
    scores and values both live in the kv_lora latent space).

    ``pos`` is a scalar (all rows at the same position) or a ``(B,)``
    vector of per-row positions (continuous-batching ragged decode)."""
    B = x.shape[0]
    pos32 = jnp.asarray(pos, jnp.int32)
    ragged = pos32.ndim >= 1
    positions = pos32[:, None] if ragged else jnp.full((B, 1), pos)
    c_new, kr_new = _latent(p, x, cfg, positions)
    if ragged:
        rows = jnp.arange(B)
        cache = {
            "c_kv": cache["c_kv"].at[rows, pos32].set(
                c_new[:, 0].astype(cache["c_kv"].dtype)),
            "k_rope": cache["k_rope"].at[rows, pos32].set(
                kr_new[:, 0].astype(cache["k_rope"].dtype)),
        }
    else:
        zero = jnp.zeros((), jnp.int32)
        cache = {
            "c_kv": jax.lax.dynamic_update_slice(
                cache["c_kv"], c_new.astype(cache["c_kv"].dtype),
                (zero, pos32, zero)),
            "k_rope": jax.lax.dynamic_update_slice(
                cache["k_rope"], kr_new.astype(cache["k_rope"].dtype),
                (zero, pos32, zero)),
        }
    k_pos = jnp.arange(cache["c_kv"].shape[1])
    if ragged:
        mask = (k_pos[None] <= positions)[:, None, None, :]   # (B,1,1,S)
    else:
        mask = (k_pos <= pos)[None, None, None]
    o = _latent_attention(p, x, cfg, positions,
                          cache["c_kv"], cache["k_rope"], mask)
    return apply_dense(p["wo"], o), cache


def _latent_attention(p: Dict, x: jax.Array, cfg: MLAConfig,
                      positions: jax.Array, c_kv: jax.Array,
                      k_rope: jax.Array, mask: jax.Array) -> jax.Array:
    """Weight-absorbed latent attention over a (gathered) latent cache.

    Shared by the dense slot path and the paged block-table path — with
    identical ``(c_kv, k_rope, mask)`` operands the outputs are
    bit-identical, which is what makes paged MLA serving exact."""
    B = x.shape[0]
    q_nope, q_rope = _queries(p, x, cfg, positions)   # (B,1,H,*)
    # absorb W_uk into the query: q_lat (B,1,H,R)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       p["w_uk"].astype(jnp.float32))
    s_lat = jnp.einsum("bqhr,bsr->bhqs", q_lat, c_kv.astype(jnp.float32))
    s_rope = jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                        k_rope.astype(jnp.float32))
    s = (s_lat + s_rope) * (cfg.qk_dim ** -0.5)
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", w,
                       c_kv.astype(jnp.float32))             # latent values
    o = jnp.einsum("bqhr,rhd->bqhd", o_lat, p["w_uv"].astype(jnp.float32))
    return o.reshape(B, 1, -1).astype(x.dtype)


# ---------------------------------------------------------------------------
# paged decode: the latent cache as physical pages behind a block table
# ---------------------------------------------------------------------------

def init_paged_mla_cache(cfg: MLAConfig, num_blocks: int, block_size: int,
                         dtype=jnp.bfloat16) -> Dict:
    """Block-major latent cache: pages shared by every request, addressed
    through per-request block tables (see ``nn.attention`` paged helpers)."""
    return {
        "c_kv": jnp.zeros((num_blocks, block_size, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((num_blocks, block_size, cfg.qk_rope_dim), dtype),
    }


def apply_mla_paged_decode(p: Dict, x: jax.Array, cache: Dict,
                           block_table: jax.Array, pos, cfg: MLAConfig, *,
                           width: int,
                           write_mask=None) -> Tuple[jax.Array, Dict]:
    """One-token MLA step against the paged latent cache.

    ``pos`` is (B,) ragged per-row positions; ``width`` (static) is the
    gather width — the dense layer's ``max_seq`` — so the attention
    operands, and hence the outputs, are bit-identical to
    :func:`apply_mla_decode` on the equivalent dense cache."""
    pos32 = jnp.asarray(pos, jnp.int32)
    positions = pos32[:, None]
    c_new, kr_new = _latent(p, x, cfg, positions)
    cache = {
        "c_kv": paged_update_cache(cache["c_kv"], c_new, block_table, pos32,
                                   write_mask=write_mask),
        "k_rope": paged_update_cache(cache["k_rope"], kr_new, block_table,
                                     pos32, write_mask=write_mask),
    }
    c_g = gather_pages(cache["c_kv"], block_table, width)
    kr_g = gather_pages(cache["k_rope"], block_table, width)
    mask = (jnp.arange(width)[None] <= positions)[:, None, None, :]
    o = _latent_attention(p, x, cfg, positions, c_g, kr_g, mask)
    return apply_dense(p["wo"], o), cache
