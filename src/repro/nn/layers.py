"""Norms, MLPs, embeddings — the dense substrate shared by all archs."""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.nn.core import ParamSpec, apply_dense, dense


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_spec(d: int, name_axis: str = "embed") -> Dict:
    return {"scale": ParamSpec((d,), (name_axis,), "ones")}


def apply_rmsnorm(p: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
            ).astype(x.dtype)


def layernorm_spec(d: int) -> Dict:
    return {"scale": ParamSpec((d,), ("embed",), "ones"),
            "bias": ParamSpec((d,), ("embed",), "zeros")}


def apply_layernorm(p: Dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_spec(d_model: int, d_ff: int) -> Dict:
    return {
        "gate": dense(d_model, d_ff, ("embed", "mlp")),
        "up": dense(d_model, d_ff, ("embed", "mlp")),
        "down": dense(d_ff, d_model, ("mlp", "embed")),
    }


def apply_swiglu(p: Dict, x: jax.Array,
                 tp_axis: Optional[str] = None) -> jax.Array:
    """``tp_axis`` enables explicit tensor parallelism for callers inside a
    ``shard_map`` over that axis: gate/up hold a ``d_ff / TP`` column shard
    (partial hidden works elementwise), down holds the matching row shard,
    and the down matmul's partial sum is assembled by a ``psum`` — the
    Megatron column→row pattern with the collective written out.  Under
    ``jax.grad`` the psum transposes back to a psum (shard_map with
    replication checking off), which routes each rank's partial input
    cotangent exactly like Megatron's conjugate ``f`` operator."""
    g = apply_dense(p["gate"], x)
    u = apply_dense(p["up"], x)
    h = jax.nn.silu(g) * u
    if tp_axis is None:
        return apply_dense(p["down"], h)
    return jax.lax.psum(h @ p["down"]["w"].astype(h.dtype), tp_axis)


def gelu_mlp_spec(d_model: int, d_ff: int, bias: bool = True) -> Dict:
    return {
        "up": dense(d_model, d_ff, ("embed", "mlp"), bias=bias),
        "down": dense(d_ff, d_model, ("mlp", "embed"), bias=bias),
    }


def apply_gelu_mlp(p: Dict, x: jax.Array,
                   tp_axis: Optional[str] = None) -> jax.Array:
    h = jax.nn.gelu(apply_dense(p["up"], x))
    if tp_axis is None:
        return apply_dense(p["down"], h)
    # the down bias is replicated over the TP axis: add it once, after the
    # partial-sum psum (folding it into apply_dense would count it TP times)
    y = jax.lax.psum(h @ p["down"]["w"].astype(h.dtype), tp_axis)
    if "w_b" in p["down"]:
        y = y + p["down"]["w_b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embedding_spec(vocab: int, d_model: int) -> Dict:
    return {"table": ParamSpec((vocab, d_model), ("vocab", "embed"),
                               "embed", scale=1.0)}


def apply_embedding(p: Dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p: Dict, x: jax.Array) -> jax.Array:
    """Tied unembedding: logits in f32 for a stable softmax/loss."""
    return x.astype(jnp.float32) @ p["table"].astype(jnp.float32).T


def lm_head_spec(d_model: int, vocab: int) -> Dict:
    return {"out": dense(d_model, vocab, ("embed", "vocab"))}


def apply_lm_head(p: Dict, x: jax.Array) -> jax.Array:
    return apply_dense(p["out"], x.astype(jnp.float32))
