"""Mixture-of-Experts: top-k routing with two execution paths.

* ``dense`` — every expert computed, outputs masked by the gates.  Exact,
  used by CPU smoke tests and as the correctness oracle for the EP path.
* ``ep`` — production expert parallelism: tokens are sorted by expert,
  packed into fixed-capacity per-expert buffers, exchanged with
  ``all_to_all`` over the ``model`` mesh axis inside ``shard_map``, run
  through the local experts, and combined back.  Capacity overflow drops
  tokens (standard Switch/GShard semantics); with a generous capacity
  factor the two paths agree exactly, which the integration tests assert.

Expert weights carry the ``("experts", ...)`` logical axis -> sharded over
the ``model`` axis by the dist layer.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import dp_axes
from repro.nn.core import ParamSpec
from repro.nn.layers import apply_swiglu


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int
    n_shared: int = 0          # always-on shared experts (DeepSeek style)
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_scale: bool = False  # normalize top-k gate weights to sum to 1


def moe_spec(cfg: MoEConfig) -> Dict:
    spec = {
        "router": {"w": ParamSpec((cfg.d_model, cfg.n_experts),
                                  ("embed", None))},
        "experts": {
            "gate": ParamSpec((cfg.n_experts, cfg.d_model, cfg.d_ff),
                              ("experts", "embed", "mlp")),
            "up": ParamSpec((cfg.n_experts, cfg.d_model, cfg.d_ff),
                            ("experts", "embed", "mlp")),
            "down": ParamSpec((cfg.n_experts, cfg.d_ff, cfg.d_model),
                              ("experts", "mlp", "embed")),
        },
    }
    if cfg.n_shared:
        d_sh = cfg.shared_d_ff or cfg.n_shared * cfg.d_ff
        spec["shared"] = {
            "gate": {"w": ParamSpec((cfg.d_model, d_sh), ("embed", "mlp"))},
            "up": {"w": ParamSpec((cfg.d_model, d_sh), ("embed", "mlp"))},
            "down": {"w": ParamSpec((d_sh, cfg.d_model), ("mlp", "embed"))},
        }
    return spec


def router_probs(p: Dict, x: jax.Array, cfg: MoEConfig):
    logits = x.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.router_scale:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    return gate_vals, gate_idx, probs


def _expert_ffn(experts: Dict, xb: jax.Array) -> jax.Array:
    """xb: (E, C, d) -> (E, C, d) through each expert's SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", xb, experts["gate"].astype(xb.dtype))
    u = jnp.einsum("ecd,edf->ecf", xb, experts["up"].astype(xb.dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                      experts["down"].astype(xb.dtype))


def apply_moe_dense(p: Dict, x: jax.Array, cfg: MoEConfig) -> jax.Array:
    """Oracle path: all experts on all tokens, gate-combined."""
    B, S, D = x.shape
    gate_vals, gate_idx, _ = router_probs(p, x, cfg)
    xt = x.reshape(B * S, D)
    # (E, T, d): every expert sees every token
    y_all = _expert_ffn(p["experts"],
                        jnp.broadcast_to(xt, (cfg.n_experts, B * S, D)))
    onehot = jax.nn.one_hot(gate_idx.reshape(B * S, cfg.top_k),
                            cfg.n_experts, dtype=jnp.float32)
    weights = jnp.einsum("tk,tke->te", gate_vals.reshape(B * S, cfg.top_k)
                         .astype(jnp.float32), onehot)
    y = jnp.einsum("te,etd->td", weights, y_all.astype(jnp.float32))
    out = y.reshape(B, S, D).astype(x.dtype)
    if cfg.n_shared:
        out = out + apply_swiglu(p["shared"], x)
    return out


def _pack_dispatch(xt, gate_vals, gate_idx, n_experts, capacity):
    """Sort-free capacity dispatch: rank tokens within their expert via a
    cumulative count, drop beyond capacity, scatter into (E, C, d)."""
    T, D = xt.shape
    k = gate_idx.shape[-1]
    flat_e = gate_idx.reshape(T * k)                    # expert of each slot
    flat_g = gate_vals.reshape(T * k).astype(jnp.float32)
    flat_t = jnp.repeat(jnp.arange(T), k)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)  # (T*k, E)
    rank = (jnp.cumsum(onehot, axis=0) * onehot).sum(axis=-1) - 1
    # rank = 0-based position of the slot within its expert's buffer
    keep = rank < capacity
    slot = jnp.where(keep, flat_e * capacity + rank, n_experts * capacity)
    buf = jnp.zeros((n_experts * capacity + 1, D), xt.dtype)
    buf = buf.at[slot].set(xt[flat_t])                  # drops land in slot -1
    return (buf[:-1].reshape(n_experts, capacity, D),
            slot, flat_t, flat_g * keep.astype(jnp.float32))


def apply_moe_ep(p: Dict, x: jax.Array, cfg: MoEConfig, mesh,
                 axis: str = "model") -> jax.Array:
    """Expert-parallel path via shard_map + all_to_all over ``axis``."""
    ep = mesh.shape[axis]
    assert cfg.n_experts % ep == 0, (cfg.n_experts, ep)
    e_local = cfg.n_experts // ep

    def local_fn(xs, router_w, experts):
        B, S, D = xs.shape
        T = B * S
        xt = xs.reshape(T, D)
        logits = xt.astype(jnp.float32) @ router_w.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)
        if cfg.router_scale:
            gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
        capacity = max(int(T * cfg.top_k * cfg.capacity_factor
                           // cfg.n_experts), 4)
        buf, slot, flat_t, flat_g = _pack_dispatch(
            xt, gate_vals, gate_idx, cfg.n_experts, capacity)
        # (E, C, d) -> exchange: every peer sends my local experts' rows.
        # After all_to_all, dim 0 indexes the SOURCE rank: transpose it next
        # to capacity before flattening per local expert.
        buf = buf.reshape(ep, e_local, capacity, D)
        buf = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0,
                                 tiled=False)            # (src, e_local, C, d)
        buf = buf.transpose(1, 0, 2, 3).reshape(e_local, ep * capacity, D)
        y = _expert_ffn(experts, buf)                    # local experts
        y = y.reshape(e_local, ep, capacity, D).transpose(1, 0, 2, 3)
        y = jax.lax.all_to_all(y, axis, split_axis=0, concat_axis=0,
                               tiled=False)              # (home, e_local, C, d)
        y = y.reshape(cfg.n_experts * capacity, D)       # e = home*e_local+j
        y = jnp.concatenate([y, jnp.zeros((1, D), y.dtype)], axis=0)
        gathered = y[jnp.minimum(slot, cfg.n_experts * capacity)]
        contrib = gathered.astype(jnp.float32) * flat_g[:, None]
        out = jnp.zeros((T, D), jnp.float32).at[flat_t].add(contrib)
        return out.reshape(B, S, D).astype(xs.dtype)

    dp = dp_axes(mesh)
    fn = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp, axis, None),
                  P(None, None),
                  jax.tree.map(lambda _: P(axis, None, None), p["experts"])),
        out_specs=P(dp, axis, None),
        check_vma=False)
    out = fn(x, p["router"]["w"], p["experts"])
    if cfg.n_shared:
        out = out + apply_swiglu(p["shared"], x)
    return out


def apply_moe_ep_replicated(p: Dict, x: jax.Array, cfg: MoEConfig, mesh,
                            axis: str = "model") -> jax.Array:
    """EP for token counts too small to shard on the model axis (decode):
    activations replicate over ``axis``, experts stay sharded; each rank
    computes its local experts on every token and the combine is a psum."""
    ep = mesh.shape[axis]
    e_local = cfg.n_experts // ep

    def local_fn(xs, router_w, experts):
        B, S, D = xs.shape
        T = B * S
        xt = xs.reshape(T, D)
        logits = xt.astype(jnp.float32) @ router_w.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)
        if cfg.router_scale:
            gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
        rank = jax.lax.axis_index(axis)
        lo = rank * e_local
        onehot = jax.nn.one_hot(gate_idx, cfg.n_experts, dtype=jnp.float32)
        weights = jnp.einsum("tk,tke->te", gate_vals.astype(jnp.float32),
                             onehot)                       # (T, E)
        w_local = jax.lax.dynamic_slice(weights, (0, lo), (T, e_local))
        y_local = _expert_ffn(experts,
                              jnp.broadcast_to(xt, (e_local, T, D)))
        y = jnp.einsum("te,etd->td", w_local, y_local.astype(jnp.float32))
        y = jax.lax.psum(y, axis)
        return y.reshape(B, S, D).astype(xs.dtype)

    dp = dp_axes(mesh)
    fn = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None),
                  jax.tree.map(lambda _: P(axis, None, None), p["experts"])),
        out_specs=P(dp, None, None),
        check_vma=False)
    out = fn(x, p["router"]["w"], p["experts"])
    if cfg.n_shared:
        out = out + apply_swiglu(p["shared"], x)
    return out


def apply_moe(p: Dict, x: jax.Array, cfg: MoEConfig,
              mesh=None, axis: str = "model") -> jax.Array:
    if mesh is not None and axis in mesh.shape and mesh.shape[axis] > 1 \
            and cfg.n_experts % mesh.shape[axis] == 0:
        if x.shape[1] % mesh.shape[axis] == 0:
            return apply_moe_ep(p, x, cfg, mesh, axis)
        return apply_moe_ep_replicated(p, x, cfg, mesh, axis)
    return apply_moe_dense(p, x, cfg)
