"""Rotary position embeddings (shared by every attention family here)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, D) or (..., S, D); positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    if x.ndim == ang.ndim + 1:                        # head axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
