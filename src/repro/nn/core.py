"""Functional NN substrate: parameters are plain pytrees (nested dicts of
jnp arrays), modules are (init, apply) function pairs.  No flax/haiku in the
container — and for a sharding-heavy framework, explicit pytrees keep the
logical-axis annotation story simple (see repro.dist.sharding).

Every parameter leaf is annotated with *logical axes* via a parallel tree of
name tuples produced by the ``Init`` helpers; the dist layer maps logical
axes -> mesh axes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ParamSpec:
    """Shape + logical axis names + initializer for one parameter leaf."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed
    scale: Optional[float] = None

    def make(self, key, dtype) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        fan_in = self.shape[0] if len(self.shape) >= 2 else max(self.shape[0], 1)
        scale = self.scale if self.scale is not None else 1.0 / np.sqrt(fan_in)
        if self.init == "embed":
            scale = self.scale if self.scale is not None else 1.0
        return (jax.random.normal(key, self.shape, jnp.float32) * scale
                ).astype(dtype)


def init_params(specs: Dict, key: jax.Array, dtype=jnp.float32) -> Dict:
    """Instantiate a (nested) dict of ParamSpec into parameters."""
    flat, treedef = jax.tree.flatten(specs,
                                     is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(flat))
    leaves = [s.make(k, dtype) for s, k in zip(flat, keys)]
    return jax.tree.unflatten(treedef, leaves)


def logical_axes(specs: Dict) -> Dict:
    """The parallel tree of logical-axis tuples."""
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def stack_specs(specs: Dict, n: int, axis_name: str = "layers") -> Dict:
    """Stack a per-layer spec tree along a leading 'layers' dimension for
    scan-over-layers (the MaxText pattern: one traced layer body)."""
    def stack_one(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale)
    return jax.tree.map(stack_one, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def dense(d_in: int, d_out: int, axes=("embed", "mlp"),
          bias: bool = False, name: str = "w") -> Dict:
    spec = {name: ParamSpec((d_in, d_out), axes)}
    if bias:
        spec[name + "_b"] = ParamSpec((d_out,), (axes[-1],), "zeros")
    return spec


def apply_dense(p: Dict, x: jax.Array, name: str = "w") -> jax.Array:
    y = x @ p[name].astype(x.dtype)
    if name + "_b" in p:
        y = y + p[name + "_b"].astype(x.dtype)
    return y
