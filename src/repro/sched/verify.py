"""Probabilistic testing of optimized schedules (paper §4.1).

"Probabilistic testing generates randomized inputs and reference outputs and
then compares with the output of the program."  Formal verification of SASS
is impossible (no official semantics) and bitwise enumeration intractable —
both statements carry over to TSASS verbatim, so the sanity check is the
same: seed the input hash domain randomly, run the dataflow reference of the
*original* schedule, and compare the optimized schedule's machine execution
against it.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.core.isa import Instruction
from repro.core.machine import Machine, dataflow_reference


@dataclasses.dataclass
class VerifyResult:
    ok: bool
    n_seeds: int
    failures: List[int]


def probabilistic_test(original: Sequence[Instruction],
                       optimized: Sequence[Instruction],
                       n_seeds: int = 8,
                       machine: Optional[Machine] = None) -> VerifyResult:
    machine = machine or Machine()
    failures = []
    for seed in range(n_seeds):
        expected = dataflow_reference(original, input_seed=seed)
        got = machine.run(optimized, input_seed=seed).outputs
        if got != expected:
            failures.append(seed)
    return VerifyResult(ok=not failures, n_seeds=n_seeds, failures=failures)
