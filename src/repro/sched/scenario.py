"""Scenario and machine-target axes of the schedule optimizer.

The paper optimizes one kernel for one shape on one GPU.  Production
serving does not look like that: the same kernel runs under many traffic
mixes (batch size, sequence length, dtype, occupancy) on several machine
generations, and a schedule tuned for one point serves every other point
stale.  This module makes both axes first-class, typed values that the
whole optimize -> cache -> serve stack plumbs through instead of assuming
a single implicit global:

* :class:`Scenario` — one workload point.  Scenarios quantize into
  **buckets** (power-of-two edges on batch and sequence length, exact
  dtype / occupancy class), which are the cache-index keys: tuning happens
  per bucket, and serve-time dispatch resolves a request's shape to the
  *nearest* tuned bucket (:func:`nearest_bucket`) as a pure index lookup.
* :class:`MachineTarget` — the machine-model identity that replaces the
  bare ``cache.TARGET`` string: the cache-partition name plus the machine
  configuration (noise / seed — and, for downstream machine models, a
  factory override) that stall tables and measurements are built from.
  Targets register in :data:`TARGETS`; campaign CLIs resolve names through
  :func:`require_target` so typos fail loudly, while :func:`get_target`
  still admits ad-hoc names for tests and private cache partitions.

``scenario=None`` everywhere means the legacy single-point behaviour: the
``"default"`` bucket, byte-identical cache keys, identical specs.  That is
what lets pre-scenario (v1/v2) cache directories load through unchanged.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Iterable, List, Optional, Tuple, Union

from repro.core.machine import Machine

# the bucket every pre-scenario artifact lives in, and the bucket a
# scenario-less optimize/deploy resolves to
DEFAULT_BUCKET = "default"

_OCCUPANCIES = ("low", "half", "full")
_DTYPE_ALIASES = {"bfloat16": "bf16", "float32": "f32", "float16": "f16",
                  "fp32": "f32", "fp16": "f16", "int8": "i8", "int32": "i32"}


def _pow2_bucket(n: int) -> int:
    """Round up to the bucket's power-of-two edge (1, 2, 4, ...)."""
    n = max(int(n), 1)
    return 1 << max(n - 1, 0).bit_length()


# ---------------------------------------------------------------------------
# Scenario
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scenario:
    """One workload point a kernel is tuned for.

    ``batch``/``seq_len`` describe the traffic shape, ``dtype`` the tile
    element type the kernel moves, ``occupancy`` the load class of the
    serving replica ("low" = trickle/long-context decode, "half" = steady
    decode, "full" = saturated train/prefill).  Two scenarios inside the
    same bucket share one tuned schedule.
    """

    batch: int = 1
    seq_len: int = 4096
    dtype: str = "bf16"
    occupancy: str = "full"

    def __post_init__(self):
        object.__setattr__(self, "dtype",
                           _DTYPE_ALIASES.get(self.dtype, self.dtype))
        if self.occupancy not in _OCCUPANCIES:
            raise ValueError(f"unknown occupancy {self.occupancy!r}; "
                             f"one of {_OCCUPANCIES}")
        if self.batch < 1 or self.seq_len < 1:
            raise ValueError(f"batch/seq_len must be >= 1, got "
                             f"{self.batch}/{self.seq_len}")

    @property
    def rows(self) -> int:
        """Total rows of work the scenario streams through a row-tiled
        kernel (the trip-count driver for spec construction)."""
        return self.batch * self.seq_len

    @property
    def bucket(self) -> str:
        """Canonical bucket key: power-of-two batch/seq edges, exact
        dtype and occupancy — the cache-index scenario key."""
        return (f"b{_pow2_bucket(self.batch)}_s{_pow2_bucket(self.seq_len)}"
                f"_{self.dtype}_{self.occupancy}")

    @classmethod
    def parse(cls, text: str) -> "Scenario":
        """Parse the CLI form ``BATCHxSEQ[xDTYPE[xOCCUPANCY]]``
        (e.g. ``256x4096``, ``8x32768xbf16xhalf``)."""
        parts = text.lower().split("x")
        if len(parts) < 2 or len(parts) > 4:
            raise ValueError(
                f"bad scenario {text!r}: expected BATCHxSEQ[xDTYPE[xOCC]], "
                f"e.g. 256x4096xbf16xfull")
        try:
            batch, seq = int(parts[0]), int(parts[1])
        except ValueError:
            raise ValueError(f"bad scenario {text!r}: batch/seq must be "
                             f"integers") from None
        kw = {}
        if len(parts) >= 3:
            kw["dtype"] = parts[2]
        if len(parts) == 4:
            kw["occupancy"] = parts[3]
        return cls(batch=batch, seq_len=seq, **kw)

    def describe(self) -> str:
        return (f"batch={self.batch} seq={self.seq_len} dtype={self.dtype} "
                f"occupancy={self.occupancy} -> {self.bucket}")


def bucket_of(scenario: Union[Scenario, str, None]) -> str:
    """Normalize a scenario / bucket string / None to a bucket key."""
    if scenario is None:
        return DEFAULT_BUCKET
    if isinstance(scenario, Scenario):
        return scenario.bucket
    return str(scenario)


def parse_bucket(bucket: str) -> Optional[Tuple[int, int, str, str]]:
    """``b8_s4096_bf16_full`` -> (8, 4096, "bf16", "full"); ``None`` for
    the default bucket or anything unparseable (treated as infinitely far
    by :func:`nearest_bucket`, reachable only as a fallback)."""
    parts = bucket.split("_")
    if len(parts) != 4 or not parts[0].startswith("b") \
            or not parts[1].startswith("s"):
        return None
    try:
        return (int(parts[0][1:]), int(parts[1][1:]), parts[2], parts[3])
    except ValueError:
        return None


def bucket_distance(scenario: Scenario, bucket: str) -> float:
    """Dispatch metric: log2 distance on batch and seq, a large penalty
    for a dtype mismatch (wrong tile bytes), a small one for occupancy."""
    parsed = parse_bucket(bucket)
    if parsed is None:
        return math.inf
    b, s, dtype, occ = parsed
    d = abs(math.log2(_pow2_bucket(scenario.batch)) - math.log2(b)) \
        + abs(math.log2(_pow2_bucket(scenario.seq_len)) - math.log2(s))
    if dtype != scenario.dtype:
        d += 16.0
    if occ != scenario.occupancy:
        d += 1.0
    return d


def nearest_bucket(buckets: Iterable[str],
                   scenario: Union[Scenario, str, None]) -> Optional[str]:
    """The tuned bucket a request shape dispatches to.

    Exact bucket match wins; otherwise the nearest by
    :func:`bucket_distance` (ties break lexicographically, so dispatch is
    deterministic across processes); the default bucket is the fallback of
    last resort.  ``None`` when nothing is tuned at all.
    """
    buckets = sorted(set(buckets))
    if not buckets:
        return None
    want = bucket_of(scenario)
    if want in buckets:
        return want
    if not isinstance(scenario, Scenario):
        # a raw bucket string with no exact match: re-parse it so distance
        # dispatch still works for index-to-index migration tools
        parsed = parse_bucket(want)
        if parsed is None:
            return DEFAULT_BUCKET if DEFAULT_BUCKET in buckets else buckets[0]
        scenario = Scenario(batch=parsed[0], seq_len=parsed[1],
                            dtype=parsed[2], occupancy=parsed[3])
    scored = [(bucket_distance(scenario, b), b) for b in buckets]
    finite = [x for x in scored if math.isfinite(x[0])]
    if finite:
        return min(finite)[1]
    return DEFAULT_BUCKET if DEFAULT_BUCKET in buckets else buckets[0]


def scenario_steps(scenario: Optional[Scenario], rows_per_step: int,
                   default: int) -> int:
    """Steady-state trip count to materialize for a scenario: how many
    row tiles the workload streams per core, clamped to the 2..8 window
    the lowering unrolls.  ``scenario=None`` keeps the kernel's legacy
    single-point default (bit-identical specs, the v2 compat guarantee);
    low occupancy halves the materialized window (fewer resident tiles)."""
    if scenario is None:
        return default
    steps = scenario.rows // max(rows_per_step * 1024, 1)
    if scenario.occupancy == "low":
        steps //= 2
    return max(2, min(8, steps if steps else 2))


# ---------------------------------------------------------------------------
# MachineTarget
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MachineTarget:
    """Identity + machine model of one optimization target.

    Replaces the bare ``cache.TARGET`` string: ``name`` is still the cache
    partition key (on-disk layout is unchanged for the default target), but
    the target now also *carries* the machine configuration its stall table
    and measurements are built from — so a session can hold per-target
    stall tables keyed by the target itself, and a campaign over several
    targets never mixes their measurements.

    ``machine_factory`` admits downstream machine models (a subclassed
    :class:`Machine` with different latency tables); it is excluded from
    equality/hash so two handles to the same named target compare equal.
    """

    name: str = "tpu-tsass-v1"
    noise: float = 0.0
    seed: int = 0
    machine_factory: Optional[Callable[[], Machine]] = \
        dataclasses.field(default=None, compare=False)

    def new_machine(self) -> Machine:
        if self.machine_factory is not None:
            return self.machine_factory()
        return Machine(noise=self.noise, seed=self.seed)

    def __str__(self) -> str:       # cache paths / log lines
        return self.name


# the registered fleet of machine targets campaigns can address by name.
# Both built-ins run the same TSASS simulator (the repo has exactly one
# machine model); v2 is the sibling pod generation's cache partition —
# real table differences arrive via MachineTarget.machine_factory.
TARGETS: Dict[str, MachineTarget] = {}


def register_target(target: MachineTarget) -> MachineTarget:
    """Register ``target`` under its name (last registration wins, so
    tests can shadow and restore entries).  Returns the target."""
    if not isinstance(target, MachineTarget):
        raise TypeError(f"register_target expects a MachineTarget, "
                        f"got {target!r}")
    TARGETS[target.name] = target
    return target


def unregister_target(name: str) -> None:
    TARGETS.pop(name, None)


DEFAULT_TARGET = register_target(MachineTarget("tpu-tsass-v1"))
register_target(MachineTarget("tpu-tsass-v2", seed=1))


def get_target(target: Union[str, MachineTarget, None]) -> MachineTarget:
    """Normalize to a :class:`MachineTarget`.  Registered names resolve to
    their registered entry; unknown names become ad-hoc stock-machine
    targets (private cache partitions, tests) — campaign CLIs that must
    reject typos use :func:`require_target` instead."""
    if target is None:
        return DEFAULT_TARGET
    if isinstance(target, MachineTarget):
        return target
    known = TARGETS.get(str(target))
    return known if known is not None else MachineTarget(str(target))


def require_target(name: Union[str, MachineTarget]) -> MachineTarget:
    """Like :func:`get_target` but unknown names fail loudly, listing the
    registered targets — the ``--targets`` CLI contract."""
    if isinstance(name, MachineTarget):
        return name
    try:
        return TARGETS[str(name)]
    except KeyError:
        raise KeyError(
            f"unknown machine target {name!r}; registered targets: "
            f"{sorted(TARGETS)} (register_target() adds more)") from None


def build_spec(make_spec: Callable, config: Dict,
               scenario: Optional[Scenario] = None):
    """Construct a kernel spec, passing ``scenario`` through to
    scenario-aware ``make_spec`` builders (those declaring a ``scenario``
    parameter) and silently omitting it for legacy single-point builders —
    the one place the optional-axis dispatch lives."""
    if scenario is not None and _accepts_scenario(make_spec):
        return make_spec(config, scenario=scenario)
    return make_spec(config)


def _accepts_scenario(fn: Callable) -> bool:
    import inspect
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return False
    if "scenario" in sig.parameters:
        return True
    return any(p.kind is inspect.Parameter.VAR_KEYWORD
               for p in sig.parameters.values())


__all__: List[str] = [
    "DEFAULT_BUCKET", "DEFAULT_TARGET", "MachineTarget", "Scenario",
    "TARGETS", "bucket_distance", "bucket_of", "build_spec", "get_target",
    "nearest_bucket", "parse_bucket", "register_target", "require_target",
    "scenario_steps", "unregister_target",
]
