"""The optimization session — backend-pluggable, fleet-scale successor of
the monolithic ``CuAsmRL`` class (paper §4 "transparent integration",
re-architected around three small protocols):

* :class:`repro.sched.backends.MeasureBackend` — how schedules are timed
  (dataflow oracle / timing-only fast path / fast path + worker pool), and
  the cross-kernel measurement memo;
* :class:`SearchStrategy` — how the schedule space is searched (PPO over
  the assembly game, plus cheap greedy-swap and random-search baselines for
  A/B tests and CI);
* :class:`OptimizeRequest` / :class:`OptimizeResult` — declarative inputs
  and outputs replacing the old tangle of constructor kwargs.

:class:`OptimizationSession` owns the per-target stall table (Table 1,
built once and shared by every kernel), the shared memo (via its backend)
and a versioned :class:`repro.sched.cache.ScheduleCache`, and exposes

    session = OptimizationSession()
    res  = session.optimize(OptimizeRequest(kernel="rmsnorm"))
    fleet = session.optimize_many(["rmsnorm", "softmax", "fused_ff"])
    art  = session.deploy("rmsnorm")        # index lookup; no autotune,
                                            # no machine execution

``optimize_many`` runs a whole kernel fleet through one session — serially
by default (exact memo statistics), or concurrently with ``max_workers`` —
while every kernel reuses the same stall table and measurement memo.
Kernel names resolve through the ``@register_kernel`` registry in
:mod:`repro.kernels`.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import (Dict, Iterable, List, Optional, Protocol, Sequence,
                    Union, runtime_checkable)

import numpy as np

from repro.core.env import AssemblyGame
from repro.core.game import GameResult, train_on_program
from repro.core.isa import Instruction
from repro.core.microbench import build_stall_table
from repro.core.ppo import PPOConfig
from repro.sched import autotune as autotune_mod
from repro.sched import baseline, lowering, verify
from repro.sched.backends import (FastTimingBackend, MeasureBackend,
                                  make_backend)
from repro.sched.cache import DEFAULT_CACHE_DIR, TARGET, Artifact, ScheduleCache
from repro.sched.scenario import (MachineTarget, Scenario, bucket_of,
                                  build_spec, get_target)
from repro.sched.spec import KernelSpec


@dataclasses.dataclass
class KernelDef:
    """One optimizable kernel: its Pallas/ref callables plus the schedule
    spec constructor and the autotuner's configuration space."""
    name: str
    make_spec: "callable"
    configs: List[Dict]
    pallas_fn: Optional["callable"] = None
    ref_fn: Optional["callable"] = None


# ---------------------------------------------------------------------------
# requests / results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OptimizeRequest:
    """Declarative description of one kernel optimization.

    ``kernel`` is a registry name or a :class:`KernelDef`; ``config=None``
    autotunes the kernel's config grid first (§3.1 hierarchical search),
    a pinned config skips autotune.  ``strategy`` overrides the session
    default (a name from :data:`STRATEGIES` or a strategy instance);
    ``ppo`` configures the PPO strategy when it is the one running.

    ``scenario`` tunes the kernel for one workload point — the scenario
    flows into autotune and spec construction, and the artifact lands in
    the scenario's bucket of the cache index (``None`` keeps the legacy
    single-point behaviour bit-exactly: default bucket, identical spec).
    ``target`` overrides the session's machine target for this request —
    a campaign can sweep targets through one session, each measured on its
    own machine against its own stall table.
    """
    kernel: Union[str, KernelDef]
    config: Optional[Dict] = None
    ppo: Optional[PPOConfig] = None
    strategy: Optional[Union[str, "SearchStrategy"]] = None
    verify_seeds: Optional[int] = None
    force: bool = False
    verbose: bool = False
    scenario: Optional[Scenario] = None
    target: Optional[Union[str, MachineTarget]] = None

    @property
    def kernel_name(self) -> str:
        return self.kernel if isinstance(self.kernel, str) else self.kernel.name


@dataclasses.dataclass
class OptimizeResult:
    kernel: str
    artifact: Artifact
    config: Dict
    from_cache: bool
    strategy: str
    backend: str
    stats: List[Dict]                       # per-update / per-step search rows
    tune: Optional[autotune_mod.TuneResult] = None
    game: Optional[GameResult] = None       # populated by the PPO strategy
    seconds: float = 0.0
    scenario: Optional[str] = None          # bucket key (None = default)
    target: str = TARGET
    degraded: bool = False                  # measured with an open breaker

    @property
    def speedup(self) -> float:
        return self.artifact.speedup

    @property
    def ok(self) -> bool:
        return True


@dataclasses.dataclass
class OptimizeFailure:
    """One cell's captured failure from a supervised ``optimize_many``
    (``on_error="collect"``): the fleet keeps going, the error rides
    along.  ``attempts`` counts this cell's failures across resumable
    campaign passes (from the :class:`repro.sched.resilience.FailureLedger`
    when one is attached); ``skipped=True`` marks a cell whose retry
    budget was already exhausted, so this pass did not re-run it."""
    kernel: str
    error: str
    error_type: str
    attempts: int = 1
    scenario: Optional[str] = None          # bucket key (None = default)
    target: str = TARGET
    request: Optional[OptimizeRequest] = None
    seconds: float = 0.0
    skipped: bool = False

    @property
    def ok(self) -> bool:
        return False


# ---------------------------------------------------------------------------
# search strategies
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SearchOutcome:
    """What any strategy must produce from one program's search."""
    best_program: List[Instruction]
    best_cycles: float
    baseline_cycles: float
    stats: List[Dict]
    game: Optional[GameResult] = None


@runtime_checkable
class SearchStrategy(Protocol):
    name: str

    def search(self, program: Sequence[Instruction], *,
               stall_db: Dict[str, int], backend: MeasureBackend,
               owner: str = "", verbose: bool = False) -> SearchOutcome:
        ...


class PPOStrategy:
    """The paper's assembly game: PPO over vectorized envs
    (:func:`repro.core.game.train_on_program`), measuring through the
    backend's machine/memo."""

    name = "ppo"

    def __init__(self, ppo: Optional[PPOConfig] = None):
        self.ppo = ppo or PPOConfig()

    def search(self, program, *, stall_db, backend, owner="", verbose=False):
        game = train_on_program(
            program, stall_db=stall_db, cfg=self.ppo,
            machine_factory=backend.new_machine,
            use_fast_measure=backend.fast_measure,
            measure_workers=backend.measure_workers,
            measure_cache=backend.memo_view(program, owner),
            verbose=verbose)
        return SearchOutcome(best_program=game.best_program,
                             best_cycles=game.best_cycles,
                             baseline_cycles=game.baseline_cycles,
                             stats=game.stats, game=game)


def _strategy_env(program, stall_db, backend, owner, episode_length):
    return AssemblyGame(program, stall_db=stall_db,
                        machine=backend.new_machine(),
                        episode_length=episode_length,
                        use_fast_measure=backend.fast_measure,
                        measure_cache=backend.memo_view(program, owner))


class GreedySwapStrategy:
    """Steepest-descent baseline: evaluate every currently-legal swap
    (probe / revert — adjacent swaps are self-inverse), take the best
    strictly-improving one, stop when none improves or the step budget
    runs out.  Deterministic; useful for A/B against PPO and in CI."""

    name = "greedy"

    def __init__(self, max_steps: int = 64):
        self.max_steps = int(max_steps)

    def search(self, program, *, stall_db, backend, owner="", verbose=False):
        env = _strategy_env(program, stall_db, backend, owner,
                            episode_length=self.max_steps + 1)
        env.reset()
        stats: List[Dict] = []
        for step in range(self.max_steps):
            actions = env.valid_actions()
            best_a, best_c = None, env.prev_cycles
            for a in actions:
                c = env.probe_swap(env.action_swap_pos(a))
                if c < best_c:
                    best_a, best_c = a, c
            if best_a is None:
                break
            env.step(best_a)
            stats.append({"step": step, "cycles": best_c,
                          "candidates": len(actions), "time": time.time()})
            if verbose:
                print(f"[greedy] step={step} cycles={best_c:.0f} "
                      f"(of {len(actions)} candidates)")
        return SearchOutcome(
            best_program=[ins.copy() for ins in env.best_program],
            best_cycles=env.best_cycles, baseline_cycles=env.t0, stats=stats)


class RandomSearchStrategy:
    """Uniform random masked walks with episode restarts — the sanity floor
    any learned policy must beat."""

    name = "random"

    def __init__(self, episodes: int = 8, episode_length: int = 32,
                 seed: int = 0):
        self.episodes = int(episodes)
        self.episode_length = int(episode_length)
        self.seed = int(seed)

    def search(self, program, *, stall_db, backend, owner="", verbose=False):
        env = _strategy_env(program, stall_db, backend, owner,
                            episode_length=self.episode_length)
        rng = np.random.default_rng(self.seed)
        stats: List[Dict] = []
        for ep in range(self.episodes):
            env.reset()
            for _ in range(self.episode_length):
                actions = env.valid_actions()
                if not actions:
                    break
                _, _, done, _ = env.step(int(rng.choice(actions)))
                if done:
                    break
            stats.append({"episode": ep, "best_cycles": env.best_cycles,
                          "time": time.time()})
            if verbose:
                print(f"[random] ep={ep} best={env.best_cycles:.0f}")
        return SearchOutcome(
            best_program=[ins.copy() for ins in env.best_program],
            best_cycles=env.best_cycles, baseline_cycles=env.t0, stats=stats)


# values are classes, or "module:Class" strings resolved lazily — the
# model-guided strategies live in repro.costmodel.search, which imports
# SearchOutcome from this module, so eager registration would be an
# import cycle
STRATEGIES = {
    "ppo": PPOStrategy,
    "greedy": GreedySwapStrategy,
    "random": RandomSearchStrategy,
    "beam": "repro.costmodel.search:BeamSearchStrategy",
    "lookahead": "repro.costmodel.search:GreedyLookaheadStrategy",
}


def _strategy_cls(name: str):
    try:
        cls = STRATEGIES[name]
    except KeyError:
        raise KeyError(f"unknown strategy {name!r}; one of {sorted(STRATEGIES)}")
    if isinstance(cls, str):
        import importlib
        mod_name, _, cls_name = cls.partition(":")
        cls = getattr(importlib.import_module(mod_name), cls_name)
        STRATEGIES[name] = cls            # resolve once
    return cls


def make_strategy(name: str, **kwargs) -> SearchStrategy:
    return _strategy_cls(name)(**kwargs)


def make_budgeted_strategy(name: str, timesteps: int = 8192,
                           episode_length: int = 32,
                           num_envs: int = 8) -> SearchStrategy:
    """A strategy instance whose search budget honours the launcher-style
    ``--timesteps`` / ``--episode-length`` flags, for every strategy (not
    just PPO).  One definition so the CLI, the examples and the CI smoke
    stay in lockstep: PPO clamps its rollout length to the budget; greedy
    applies up to one episode of steepest-descent moves; random search
    spends the timestep budget across restarts."""
    if name == "ppo":
        return PPOStrategy(PPOConfig(
            total_timesteps=timesteps, num_envs=num_envs,
            num_steps=max(8, min(128, timesteps // num_envs)),
            episode_length=episode_length))
    if name == "greedy":
        return GreedySwapStrategy(max_steps=episode_length)
    if name == "random":
        return RandomSearchStrategy(
            episodes=max(1, timesteps // max(episode_length, 1)),
            episode_length=episode_length)
    if name == "beam":
        # CLI beam defaults to the oracle ranker (no trained model on
        # hand); the timestep budget caps real measurements
        return make_strategy(name, depth=episode_length,
                             max_measurements=timesteps)
    if name == "lookahead":
        return make_strategy(name, ranker="oracle",
                             max_steps=episode_length,
                             max_measurements=timesteps)
    return make_strategy(name)


# ---------------------------------------------------------------------------
# the session
# ---------------------------------------------------------------------------

class OptimizationSession:
    """Fleet-scale optimization driver over pluggable backend + strategy.

    One session amortizes the expensive per-target state across every
    kernel it optimizes: the microbenchmarked stall table is built once,
    measurements flow through the backend's shared memo (identical
    schedules — across envs, autotune/training phases and even kernels —
    are timed once), and finished artifacts land in a spec-hash-indexed
    :class:`ScheduleCache` so deployment is pure lookup.
    """

    def __init__(self, backend: Union[str, MeasureBackend, None] = None,
                 strategy: Union[str, SearchStrategy] = "ppo",
                 cache_dir: str = DEFAULT_CACHE_DIR,
                 target: Union[str, MachineTarget] = TARGET,
                 stall_db: Optional[Dict[str, int]] = None,
                 verify_seeds: int = 4,
                 cache: Optional[ScheduleCache] = None):
        if backend is None:
            backend = FastTimingBackend()
        elif isinstance(backend, str):
            backend = make_backend(backend)
        self.backend = backend
        self.strategy = strategy
        self.target = get_target(target)
        self.verify_seeds = verify_seeds
        self.cache = cache if cache is not None else \
            ScheduleCache(cache_dir, self.target)
        self._stall_tables: Dict[MachineTarget, Dict[str, int]] = {}
        if stall_db is not None:
            self._stall_tables[self.target] = stall_db
        self._stall_lock = threading.Lock()
        self._backend_lock = threading.Lock()
        self._target_backends: Dict[MachineTarget, MeasureBackend] = {}

    # -- shared per-target state ---------------------------------------------

    @property
    def memo(self):
        """The backend's cross-kernel measurement memo (``None`` for
        backends that do not share measurements)."""
        return getattr(self.backend, "memo", None)

    def stall_table(self, target: Union[str, MachineTarget, None] = None
                    ) -> Dict[str, int]:
        """Table 1 for ``target``, microbenchmarked once per session on
        the target's own machine (tables are keyed by the
        :class:`MachineTarget` itself, so a campaign over several targets
        never mixes their stall counts)."""
        target = get_target(target) if target is not None else self.target
        with self._stall_lock:
            db = self._stall_tables.get(target)
            if db is None:
                db = build_stall_table(
                    machine=self.backend_for(target).new_machine())
                self._stall_tables[target] = db
            return db

    def backend_for(self, target: Union[str, MachineTarget, None]
                    ) -> MeasureBackend:
        """The measurement backend for ``target``: the session backend for
        the session's own target (legacy path, including custom machine
        factories), a memo-sharing sibling re-pointed at the target's
        machine for every other — so one campaign's measurements all flow
        through one memo while never mixing machines."""
        target = get_target(target) if target is not None else self.target
        if target == self.target:
            return self.backend
        with self._backend_lock:
            be = self._target_backends.get(target)
            if be is None:
                for_target = getattr(self.backend, "for_target", None)
                if for_target is None:
                    raise TypeError(
                        f"backend {self.backend.name!r} cannot re-point at "
                        f"target {target.name!r}: it defines no "
                        f"for_target(machine_factory) (see "
                        f"repro.sched.backends.MeasureBackend)")
                be = for_target(target.new_machine)
                self._target_backends[target] = be
            return be

    # -- resolution -----------------------------------------------------------

    @staticmethod
    def _resolve_kernel(kernel: Union[str, KernelDef]) -> KernelDef:
        if isinstance(kernel, KernelDef):
            return kernel
        from repro import kernels as kernels_mod   # registry; import cycle
        return kernels_mod.get_kernel(kernel)

    def _resolve_strategy(self, req: OptimizeRequest) -> SearchStrategy:
        s = req.strategy if req.strategy is not None else self.strategy
        if isinstance(s, str):
            if s == "ppo":
                return PPOStrategy(req.ppo)
            return make_strategy(s)
        if req.ppo is not None and isinstance(s, PPOStrategy):
            return PPOStrategy(req.ppo)
        return s

    # -- §4.2 Listing 5: invoke optimization ----------------------------------

    def optimize(self, request: Union[OptimizeRequest, str, KernelDef]
                 ) -> OptimizeResult:
        if not isinstance(request, OptimizeRequest):
            request = OptimizeRequest(kernel=request)
        t_start = time.time()
        kdef = self._resolve_kernel(request.kernel)
        strategy = self._resolve_strategy(request)
        scenario = request.scenario
        bucket = scenario.bucket if scenario is not None else None
        target = (get_target(request.target) if request.target is not None
                  else self.target)
        backend = self.backend_for(target)

        tune = None
        if request.config is not None:
            cfg = dict(request.config)
        else:
            # §3.1 stage 1 — grid timings flow through the shared memo, so
            # a fleet re-times each distinct candidate schedule only once;
            # the scenario shapes the specs, so each bucket scores the
            # grid on its own workload point
            tune = autotune_mod.autotune(
                kdef.make_spec, kdef.configs,
                time_fn=backend.autotune_time_fn(kdef.name),
                scenario=scenario)
            cfg = tune.best.config

        if not request.force:
            art = self.cache.lookup(kdef.name, cfg, scenario=scenario,
                                    target=target)
            if art is not None:
                return OptimizeResult(
                    kernel=kdef.name, artifact=art, config=cfg,
                    from_cache=True, strategy=strategy.name,
                    backend=backend.name, stats=[], tune=tune,
                    seconds=time.time() - t_start,
                    scenario=bucket, target=target.name,
                    degraded=bool(getattr(backend, "circuit_open", False)))

        spec: KernelSpec = build_spec(kdef.make_spec, cfg, scenario)
        o3 = baseline.schedule(lowering.lower(spec))
        outcome = strategy.search(o3, stall_db=self.stall_table(target),
                                  backend=backend, owner=kdef.name,
                                  verbose=request.verbose)

        n_seeds = (request.verify_seeds if request.verify_seeds is not None
                   else self.verify_seeds)
        check = verify.probabilistic_test(o3, outcome.best_program,
                                          n_seeds=n_seeds,
                                          machine=backend.new_machine())
        if not check.ok:
            raise RuntimeError(
                f"probabilistic testing FAILED for {kdef.name}: "
                f"seeds {check.failures} — masking bug, refusing to cache")

        art = Artifact(
            kernel=kdef.name, target=target.name, config=cfg,
            program=outcome.best_program,
            baseline_cycles=outcome.baseline_cycles,
            optimized_cycles=outcome.best_cycles,
            scenario=bucket,
            meta={
                "autotune": ([dataclasses.asdict(e) for e in tune.entries]
                             if tune is not None else []),
                "improvement": ((outcome.baseline_cycles - outcome.best_cycles)
                                / outcome.baseline_cycles),
                "ppo_updates": len(outcome.stats),
                "verify_seeds": check.n_seeds,
                "strategy": strategy.name,
                "backend": backend.name,
                "scenario": (dataclasses.asdict(scenario)
                             if scenario is not None else {}),
            })
        # a pinned config is an entry, not necessarily the bucket's chosen
        # deploy config; autotuned runs define (or refresh) the index best
        self.cache.put(art, best=(request.config is None))
        return OptimizeResult(
            kernel=kdef.name, artifact=art, config=cfg, from_cache=False,
            strategy=strategy.name, backend=backend.name,
            stats=outcome.stats, tune=tune, game=outcome.game,
            seconds=time.time() - t_start, scenario=bucket,
            target=target.name,
            degraded=bool(getattr(backend, "circuit_open", False)))

    def _cell_key(self, req: OptimizeRequest) -> str:
        """The request's campaign-cell id (``kernel@bucket@target``) —
        the key failure ledgers track retries under."""
        from repro.sched.resilience import cell_key
        target = (get_target(req.target) if req.target is not None
                  else self.target)
        return cell_key(req.kernel_name, req.scenario, target)

    def _optimize_isolated(self, req: OptimizeRequest, ledger,
                           max_retries: Optional[int],
                           retry_backoff: float
                           ) -> Union[OptimizeResult, "OptimizeFailure"]:
        """One supervised cell: run ``optimize``, capture any failure
        (verify refusal, backend exhaustion, hard fault, ...) instead of
        letting it kill the fleet; consult/update the ledger so resumable
        passes retry exactly the still-failing cells with backoff."""
        bucket = req.scenario.bucket if req.scenario is not None else None
        target = (get_target(req.target) if req.target is not None
                  else self.target)
        cell = self._cell_key(req)
        prior, backoff = 0, 0.0
        if ledger is not None:
            prior = ledger.attempts(cell)
            if not ledger.should_attempt(cell, max_retries):
                entry = ledger.failed_cells().get(cell, {})
                return OptimizeFailure(
                    kernel=req.kernel_name,
                    error=entry.get("error", "retry budget exhausted"),
                    error_type=entry.get("error_type", "Skipped"),
                    attempts=prior, scenario=bucket, target=target.name,
                    request=req, skipped=True)
            if prior and retry_backoff > 0:
                backoff = retry_backoff * (2.0 ** (prior - 1))
                time.sleep(backoff)
        t0 = time.time()
        try:
            res = self.optimize(req)
        except Exception as e:
            if ledger is not None:
                ledger.record_failure(cell, e, backoff=backoff)
            return OptimizeFailure(
                kernel=req.kernel_name, error=str(e),
                error_type=type(e).__name__, attempts=prior + 1,
                scenario=bucket, target=target.name, request=req,
                seconds=time.time() - t0)
        if ledger is not None:
            ledger.record_success(cell)
        return res

    def optimize_many(self,
                      requests: Iterable[Union[OptimizeRequest, str, KernelDef]],
                      max_workers: Optional[int] = None,
                      on_error: str = "raise",
                      ledger=None,
                      max_retries: Optional[int] = None,
                      retry_backoff: float = 0.0
                      ) -> List[Union[OptimizeResult, "OptimizeFailure"]]:
        """Optimize a fleet of kernels through the shared session state.

        Serial by default (memo statistics stay exact); ``max_workers > 1``
        fans kernels out over a thread pool — measured values are
        deterministic either way (the memo is bit-exact), only the
        hit/miss attribution can shift under concurrency.

        ``on_error="raise"`` (default) keeps the legacy contract: the
        first failing cell's exception propagates — but the threaded path
        now lets every sibling finish first instead of discarding their
        work mid-flight.  ``on_error="collect"`` supervises the fleet:
        each cell's failure is captured as an :class:`OptimizeFailure` in
        the returned list (same order as the requests) and the campaign
        keeps going.  Attaching a
        :class:`repro.sched.resilience.FailureLedger` (implies collect)
        makes the campaign *resumable*: failures persist with attempt
        counts, a later identical ``optimize_many`` retries only the
        still-failed cells (after ``retry_backoff * 2**(attempts-1)``
        seconds), and cells past ``max_retries`` failures come back as
        ``skipped`` failures without re-running.
        """
        if on_error not in ("raise", "collect"):
            raise ValueError(
                f"on_error must be 'raise' or 'collect', got {on_error!r}")
        collect = on_error == "collect" or ledger is not None
        reqs = [r if isinstance(r, OptimizeRequest) else OptimizeRequest(kernel=r)
                for r in requests]

        def run_one(r: OptimizeRequest):
            if collect:
                return self._optimize_isolated(r, ledger, max_retries,
                                               retry_backoff)
            return self.optimize(r)

        if max_workers is not None and max_workers > 1 and len(reqs) > 1:
            # build each target's stall table once, not racing in the pool
            for tgt in {get_target(r.target) if r.target is not None
                        else self.target for r in reqs}:
                self.stall_table(tgt)
            with ThreadPoolExecutor(max_workers=max_workers) as pool:
                futures = [pool.submit(run_one, r) for r in reqs]
                outcomes, first_err = [], None
                for f in futures:     # gather ALL siblings before raising
                    try:
                        outcomes.append(f.result())
                    except Exception as e:
                        outcomes.append(None)
                        if first_err is None:
                            first_err = e
                if first_err is not None:
                    raise first_err
                return outcomes
        return [run_one(r) for r in reqs]

    # -- §4.2 Listing 5: deployment lookup ------------------------------------

    def deploy(self, kernel: Union[str, KernelDef],
               config: Optional[Dict] = None,
               scenario: Optional[Union[Scenario, str]] = None,
               target: Optional[Union[str, MachineTarget]] = None
               ) -> Artifact:
        """Deploy-time lookup: resolve the kernel's chosen config through
        the cache index and return the artifact — **no** autotune, no
        machine execution (the paper's search/deploy split, minus the
        legacy bug of re-running the grid search per lookup).

        With a ``scenario``, the request shape dispatches to the *nearest*
        tuned bucket (still a pure index read); without one, the default
        bucket resolves exactly as before the scenario axis existed."""
        name = kernel if isinstance(kernel, str) else kernel.name
        if config is not None:
            art = self.cache.lookup(name, config, scenario=scenario,
                                    target=target)
        elif scenario is not None:
            art = self.cache.dispatch(name, scenario, target=target)
        else:
            art = self.cache.lookup_best(name, target=target)
        if art is None:
            raise FileNotFoundError(
                f"no cached schedule for {name}"
                + (f" (scenario {bucket_of(scenario)})"
                   if scenario is not None else "")
                + "; run optimize() offline first (the paper's "
                  "search/deploy split)")
        return art
