"""Baseline schedule generator: the ``ptxas -O3`` stand-in (DESIGN.md §2.2).

The assembly game must start from "a -O3 optimized SASS schedule" (paper
§1/§3).  This module provides it: a classical critical-path list scheduler
with full knowledge of the machine's fixed latencies (the vendor compiler
knows its hardware — unlike the RL optimizer, which must infer them).  Like
real ptxas, it does NOT model the dynamic second-order effects the RL agent
can exploit: DMA queue depth, VMEM port contention, and operand-reuse buffer
invalidation (§5.7.1) are absent from its cost model.

After ordering it assigns SASS-style control codes:
  * write barriers on variable-latency loads (CPYIN/LDV) and read barriers
    on stores (CPYOUT/STV), with consumer wait masks;
  * ``.reuse`` hints on back-to-back MXM bursts sharing an operand;
  * stall counts sufficient for every fixed-latency use-def pair.

The result is always valid on the machine (verified against the dataflow
reference by tests) and is the T_0 of the reward function.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.isa import (Control, Instruction, OpClass,
                            is_fixed_latency)
from repro.core.machine import true_fixed_latency  # vendor knowledge
from repro.core.parser import memory_effects
from repro.sched.lowering import LoweredKernel


def _vendor_latency(ins: Instruction) -> float:
    base = ins.base
    if base in ("CPYIN", "CPYOUT"):
        nbytes = 16
        for part in ins.opcode.split(".")[1:]:
            if part.isdigit():
                nbytes = int(part)
        return 48.0 + nbytes / 32.0
    if base == "LDV":
        return 12.0
    if base == "STV":
        return 4.0
    lat = true_fixed_latency(ins.opcode)
    return float(lat) if lat is not None else 1.0


def build_dependencies(block: Sequence[Instruction]) -> List[List[int]]:
    """Successor lists for one basic block: register RAW/WAR/WAW, memory
    aliasing, and same-group order pinning."""
    n = len(block)
    succs: List[List[int]] = [[] for _ in range(n)]
    last_writer: Dict[str, int] = {}
    readers: Dict[str, List[int]] = {}
    cell_writer: Dict[tuple, int] = {}
    cell_readers: Dict[tuple, List[int]] = {}
    last_in_group: Dict[int, int] = {}

    def edge(a: int, b: int):
        if a != b:
            succs[a].append(b)

    for i, ins in enumerate(block):
        for r in sorted(ins.uses or ()):
            if r in last_writer:
                edge(last_writer[r], i)          # RAW
            readers.setdefault(r, []).append(i)
        for r in sorted(ins.defs or ()):
            if r in last_writer:
                edge(last_writer[r], i)          # WAW
            for j in readers.get(r, ()):  # WAR
                edge(j, i)
            readers[r] = []
            last_writer[r] = i
        for cell, is_write in memory_effects(ins):
            if is_write:
                if cell in cell_writer:
                    edge(cell_writer[cell], i)
                for j in cell_readers.get(cell, ()):
                    edge(j, i)
                cell_readers[cell] = []
                cell_writer[cell] = i
            else:
                if cell in cell_writer:
                    edge(cell_writer[cell], i)
                cell_readers.setdefault(cell, []).append(i)
        if ins.group is not None:
            if ins.group in last_in_group:
                edge(last_in_group[ins.group], i)
            last_in_group[ins.group] = i
    return succs


DEFAULT_WINDOW = 16


def _list_schedule(block: List[Instruction],
                   window: Optional[int] = DEFAULT_WINDOW
                   ) -> List[Instruction]:
    """Critical-path list scheduling with a bounded code-motion window.

    Real compilers schedule *before/during* register allocation, so they
    bound how far instructions may move to control register pressure (ptxas
    included).  ``window`` models that: candidates are drawn from the ready
    set restricted to the ``window`` lowest original indices among
    unscheduled instructions.  CuAsmRL operates *after* allocation (the
    register assignment is fixed; WAR/WAW dependencies keep it correct), so
    the RL agent legitimately enjoys code-motion freedom the vendor
    scheduler did not — which is precisely the slack the paper harvests.
    ``window=None`` gives the unbounded global scheduler (reported in the
    benchmarks as the classical upper baseline).
    """
    n = len(block)
    succs = build_dependencies(block)
    npreds = [0] * n
    for i in range(n):
        for j in succs[i]:
            npreds[j] += 1
    # critical-path priority (vendor latencies)
    prio = [0.0] * n
    for i in range(n - 1, -1, -1):
        lat = _vendor_latency(block[i])
        prio[i] = lat + max((prio[j] for j in succs[i]), default=0.0)
    ready = set(i for i in range(n) if npreds[i] == 0)
    scheduled = [False] * n
    horizon = 0
    order: List[int] = []
    while ready:
        if window is not None:
            while horizon < n and scheduled[horizon]:
                horizon += 1
            candidates = [i for i in ready if i < horizon + window]
            if not candidates:
                candidates = list(ready)
        else:
            candidates = list(ready)
        i = max(candidates, key=lambda x: (prio[x], -x))
        ready.discard(i)
        scheduled[i] = True
        order.append(i)
        for j in succs[i]:
            npreds[j] -= 1
            if npreds[j] == 0:
                ready.add(j)
    assert len(order) == n, "cyclic dependencies in block"
    return [block[i] for i in order]


def _assign_reuse(program: List[Instruction]) -> None:
    """ptxas-style operand-cache hints: within a back-to-back MXM pair
    sharing a source register, flag the shared operand of the second."""
    prev: Optional[Instruction] = None
    for ins in program:
        for k, op in enumerate(ins.operands):
            if op.endswith(".reuse"):
                ins.operands[k] = op[: -len(".reuse")]
        if ins.base == "MXM" and prev is not None and prev.base == "MXM":
            shared = (ins.uses or frozenset()) & (prev.uses or frozenset())
            for k, op in enumerate(ins.operands[1:], start=1):
                if op.split(".")[0] in shared:
                    ins.operands[k] = op + ".reuse"
                    break
        prev = ins if ins.base == "MXM" else None


def _assign_barriers(program: List[Instruction]) -> None:
    """Round-robin semaphores 0..5; every dataflow consumer of a
    variable-latency instruction waits on its barrier (paper §2.3)."""
    sem_rr = 0
    setters_reg: Dict[str, Tuple[int, int]] = {}    # reg -> (pos, sem)
    setters_cell: Dict[tuple, Tuple[int, int]] = {}
    addr_read_bar: Dict[str, Tuple[int, int]] = {}  # reg read by DMA -> sem

    for i, ins in enumerate(program):
        wait = set(ins.ctrl.wait_mask)
        for r in sorted(ins.uses or ()):
            if r in setters_reg:
                wait.add(setters_reg[r][1])
        for cell, is_write in memory_effects(ins):
            if not is_write and cell in setters_cell:
                wait.add(setters_cell[cell][1])
            if is_write and cell in setters_cell:
                wait.add(setters_cell[cell][1])  # WAW on a DMA'd cell
        for r in sorted(ins.defs or ()):
            if r in addr_read_bar:   # WAR: redefining a DMA's source reg
                wait.add(addr_read_bar[r][1])
                del addr_read_bar[r]

        base = ins.base
        if base in ("CPYIN", "LDV"):
            sem = sem_rr
            sem_rr = (sem_rr + 1) % 6
            ins.ctrl = Control(frozenset(wait), None, sem, False,
                               ins.ctrl.stall)
            for cell, is_write in memory_effects(ins):
                if is_write:
                    setters_cell[cell] = (i, sem)
            for r in sorted(ins.defs or ()):
                setters_reg[r] = (i, sem)
            if base == "CPYIN":
                rsem = sem_rr
                sem_rr = (sem_rr + 1) % 6
                ins.ctrl = Control(frozenset(wait), rsem, sem, False,
                                   ins.ctrl.stall)
                for r in sorted(ins.uses or ()):
                    addr_read_bar[r] = (i, rsem)
        elif base in ("CPYOUT", "STV"):
            sem = sem_rr
            sem_rr = (sem_rr + 1) % 6
            ins.ctrl = Control(frozenset(wait), sem, None, False,
                               ins.ctrl.stall)
            for cell, is_write in memory_effects(ins):
                if not is_write:
                    # WAR protection for the VMEM tile being drained
                    setters_cell.setdefault(cell, (i, sem))
        else:
            ins.ctrl = Control(frozenset(wait), ins.ctrl.read_bar,
                               ins.ctrl.write_bar, ins.ctrl.yield_flag,
                               ins.ctrl.stall)
        # register overwrite by a fixed op ends the setter's relevance
        if base not in ("CPYIN", "LDV"):
            for r in sorted(ins.defs or ()):
                setters_reg.pop(r, None)


def _assign_stalls(program: List[Instruction]) -> None:
    """Forward fix-up: every fixed-latency use-def pair gets enough
    accumulated stall (the property the paper's Algorithm 1 preserves)."""
    for ins in program:
        ins.ctrl.stall = 1
    # MXM issue interval is a structural stall the vendor compiler encodes
    for i, ins in enumerate(program):
        if ins.base == "MXM":
            ins.ctrl.stall = max(ins.ctrl.stall, 2)

    def_pos: Dict[str, int] = {}
    for i, ins in enumerate(program):
        if ins.klass is OpClass.SYNC:
            def_pos.clear()
            continue
        for r in sorted(ins.uses or ()):
            j = def_pos.get(r)
            if j is None:
                continue
            producer = program[j]
            if not is_fixed_latency(producer.opcode):
                continue
            need = true_fixed_latency(producer.opcode) or 4
            accum = sum(max(1, program[k].ctrl.stall) for k in range(j, i))
            if accum < need:
                program[i - 1].ctrl.stall += need - accum
        for r in sorted(ins.defs or ()):
            def_pos[r] = i


def schedule(lowered: LoweredKernel,
             window: Optional[int] = DEFAULT_WINDOW) -> List[Instruction]:
    """Produce the -O3 baseline: list-schedule each basic block (bounded
    code-motion window = the ptxas stand-in; ``window=None`` = unbounded
    global scheduler), then assign reuse hints, barriers and stall counts."""
    program: List[Instruction] = []
    block: List[Instruction] = []
    for ins in lowered.program:
        if ins.klass is OpClass.SYNC:
            program.extend(_list_schedule(block, window))
            block = []
            program.append(ins.copy())
        else:
            block.append(ins.copy())
    program.extend(_list_schedule(block, window))

    _assign_reuse(program)
    _assign_barriers(program)
    _assign_stalls(program)
    return program


def naive_schedule(lowered: LoweredKernel) -> List[Instruction]:
    """Dataflow order with conservative control codes — the 'no scheduler'
    lower bound used by the benchmarks."""
    program = [ins.copy() for ins in lowered.program]
    _assign_reuse(program)
    _assign_barriers(program)
    _assign_stalls(program)
    return program
