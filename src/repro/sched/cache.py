"""Offline-search / deploy-time-lookup artifact cache (paper §4.2).

"The best optimized cubin found throughout the assembly game is written to
the file system, prefixed by GPU type, workload type etc., as the key to
lookup.  At deployment ... it invokes a lookup process instead of training."

Artifacts are TSASS text (round-trippable through the parser) plus a JSON
sidecar with measured cycles, the winning autotune config and provenance.

Format history:

* **v1** — flat files, no version field, no index.
* **v2** — versioned sidecars + a per-kernel ``index.json`` recording every
  cached config under its spec-hash key plus the *chosen* (autotune-best)
  config, so deploy-time lookup is a single index read.
* **v3** — the index grows a ``"scenarios"`` map: one chosen entry per
  scenario bucket (:mod:`repro.sched.scenario`), keyed
  ``(kernel, target, scenario_bucket)``.  Sidecars carry the bucket.  The
  legacy ``"best"`` field doubles as the **default-scenario** entry, which
  is exactly how v2 indexes (and index-less v1 directories) load through:
  their single chosen config becomes the ``"default"`` bucket, and
  scenario-less lookups keep resolving it byte-identically.  Unknown
  versions and corrupt files still raise :class:`CacheVersionError`
  **loudly** instead of silently missing.

:class:`ScheduleCache` wraps the files with an in-memory LRU so repeated
``deploy()`` / serving lookups are O(1) dict hits, and adds
:meth:`ScheduleCache.dispatch` — the serve-time shim that resolves a
request shape to the nearest tuned bucket as a pure index lookup.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Union

from repro.core.isa import Instruction, program_text
from repro.core.parser import parse_program
from repro.sched.scenario import (DEFAULT_BUCKET, MachineTarget, Scenario,
                                  bucket_of, nearest_bucket)

DEFAULT_CACHE_DIR = os.environ.get("REPRO_SCHED_CACHE", ".repro_cache")
# the legacy bare-string target name; new code addresses targets through
# scenario.MachineTarget / get_target (README migration note)
TARGET = "tpu-tsass-v1"
CACHE_VERSION = 3
_KNOWN_VERSIONS = (1, 2, 3)

ScenarioKey = Union[Scenario, str, None]


class CacheVersionError(RuntimeError):
    """A cache file exists but cannot be trusted (unknown version /
    malformed payload).  Deliberately loud: a silent miss would retrain and
    overwrite an artifact that may still be served elsewhere."""


def _target_name(target: Union[str, MachineTarget, None]) -> str:
    if target is None:
        return TARGET
    return target.name if isinstance(target, MachineTarget) else str(target)


@dataclasses.dataclass
class Artifact:
    kernel: str
    target: str
    config: Dict
    program: List[Instruction]
    baseline_cycles: float
    optimized_cycles: float
    meta: Dict
    scenario: Optional[str] = None          # bucket key; None = default

    @property
    def speedup(self) -> float:
        return self.baseline_cycles / max(self.optimized_cycles, 1.0)

    @property
    def bucket(self) -> str:
        return self.scenario or DEFAULT_BUCKET


def cache_key(kernel: str, target: Union[str, MachineTarget], config: Dict,
              scenario: ScenarioKey = None) -> str:
    """Content key of one (kernel, target, config, scenario-bucket) cell.
    Default-bucket keys are byte-identical to the pre-scenario (v2) keys,
    so existing on-disk artifacts stay addressable."""
    blob = {"k": kernel, "t": _target_name(target), "c": config}
    bucket = bucket_of(scenario)
    if bucket != DEFAULT_BUCKET:
        blob["s"] = bucket
    return hashlib.sha256(
        json.dumps(blob, sort_keys=True).encode()).hexdigest()[:16]


def _paths(cache_dir: str, kernel: str, target: Union[str, MachineTarget],
           config: Dict, scenario: ScenarioKey = None):
    key = cache_key(kernel, target, config, scenario)
    d = os.path.join(cache_dir, _target_name(target), kernel)
    return os.path.join(d, f"{key}.tsass"), os.path.join(d, f"{key}.json")


def _index_path(cache_dir: str, target, kernel: str) -> str:
    return os.path.join(cache_dir, _target_name(target), kernel, "index.json")


def _atomic_write(path: str, payload: str) -> None:
    # atomic writes: temp + rename (same discipline as the checkpointer)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path))
    with os.fdopen(fd, "w") as f:
        f.write(payload)
    os.replace(tmp, path)


def load_index(cache_dir: str, target, kernel: str) -> Optional[Dict]:
    """The kernel's spec-hash index, or ``None`` when never written (pure
    v1 directory).  Unknown index versions fail loudly."""
    path = _index_path(cache_dir, target, kernel)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        try:
            idx = json.load(f)
        except ValueError as e:
            raise CacheVersionError(f"corrupt cache index {path}: {e}") from e
    if idx.get("version") not in _KNOWN_VERSIONS:
        raise CacheVersionError(
            f"cache index {path} has unknown version {idx.get('version')!r}")
    return idx


def index_scenarios(idx: Dict) -> Dict[str, Dict]:
    """bucket -> chosen entry, migrating v2 on the fly: an index written
    before the scenario axis has only ``"best"``, which *is* its default
    bucket (that is the whole v2 -> v3 load-through contract)."""
    scen = dict(idx.get("scenarios", {}))
    if DEFAULT_BUCKET not in scen and "best" in idx:
        scen[DEFAULT_BUCKET] = idx["best"]
    return scen


# serializes the index read-modify-write below: concurrent optimize_many
# threads saving into one kernel's dir must not lose each other's entries
# (cross-process writers still race benignly — artifacts are content-
# addressed, only the index merge needs the lock)
_INDEX_LOCK = threading.Lock()


def _update_index(artifact: Artifact, cache_dir: str, best: bool) -> None:
    path = _index_path(cache_dir, artifact.target, artifact.kernel)
    with _INDEX_LOCK:
        try:
            idx = load_index(cache_dir, artifact.target, artifact.kernel)
        except CacheVersionError:
            idx = None                 # rebuild a corrupt index on write
        if idx is None:
            idx = {"version": CACHE_VERSION, "kernel": artifact.kernel,
                   "target": artifact.target, "entries": {}}
        idx["version"] = CACHE_VERSION
        key = cache_key(artifact.kernel, artifact.target, artifact.config,
                        artifact.scenario)
        idx.setdefault("entries", {})[key] = artifact.config
        bucket = artifact.bucket
        scen = idx.setdefault("scenarios", {})
        if DEFAULT_BUCKET not in scen and "best" in idx:
            scen[DEFAULT_BUCKET] = idx["best"]     # v2 migration on write
        entry = {"key": key, "config": artifact.config,
                 "optimized_cycles": artifact.optimized_cycles}
        if bucket != DEFAULT_BUCKET:
            entry["scenario"] = artifact.meta.get("scenario", {})
        if best or bucket not in scen:
            scen[bucket] = entry
        if bucket == DEFAULT_BUCKET and (best or "best" not in idx):
            # keep the legacy field in lockstep so pre-scenario readers
            # (and the v1-era tooling) still see the chosen config
            idx["best"] = {"key": key, "config": artifact.config,
                           "optimized_cycles": artifact.optimized_cycles}
        os.makedirs(os.path.dirname(path), exist_ok=True)
        _atomic_write(path, json.dumps(idx, indent=2, sort_keys=True))


def save(artifact: Artifact, cache_dir: str = DEFAULT_CACHE_DIR,
         best: bool = True) -> str:
    """Write the artifact (v3 sidecar) and record it in the kernel's index
    under its scenario bucket.  ``best=True`` marks its config as the
    bucket's chosen one — the config ``deploy()`` resolves without
    re-running autotune."""
    tsass_path, json_path = _paths(cache_dir, artifact.kernel,
                                   artifact.target, artifact.config,
                                   artifact.scenario)
    os.makedirs(os.path.dirname(tsass_path), exist_ok=True)
    sidecar = {
        "version": CACHE_VERSION,
        "kernel": artifact.kernel, "target": artifact.target,
        "config": artifact.config,
        "baseline_cycles": artifact.baseline_cycles,
        "optimized_cycles": artifact.optimized_cycles,
        "meta": artifact.meta}
    if artifact.scenario:
        sidecar["scenario"] = artifact.scenario
    for path, payload in (
        (tsass_path, program_text(artifact.program) + "\n"),
        (json_path, json.dumps(sidecar, indent=2)),
    ):
        _atomic_write(path, payload)
    _update_index(artifact, cache_dir, best)
    return tsass_path


def load(kernel: str, target, config: Dict,
         cache_dir: str = DEFAULT_CACHE_DIR,
         scenario: ScenarioKey = None) -> Optional[Artifact]:
    """Load one artifact by (kernel, target, config, scenario).  Missing
    files are a miss (``None``); present-but-untrusted files raise."""
    tsass_path, json_path = _paths(cache_dir, kernel, target, config,
                                   scenario)
    if not (os.path.exists(tsass_path) and os.path.exists(json_path)):
        return None
    return _load_files(tsass_path, json_path)


def _load_files(tsass_path: str, json_path: str) -> Artifact:
    with open(json_path) as f:
        try:
            meta = json.load(f)
        except ValueError as e:
            raise CacheVersionError(
                f"corrupt cache sidecar {json_path}: {e}") from e
    version = meta.get("version", 1)   # v1 sidecars predate the field
    if version not in _KNOWN_VERSIONS:
        raise CacheVersionError(
            f"cache artifact {json_path} has unknown version {version!r}; "
            f"refusing to guess (supported: {_KNOWN_VERSIONS})")
    with open(tsass_path) as f:
        program = parse_program(f.read())
    return Artifact(kernel=meta["kernel"], target=meta["target"],
                    config=meta["config"], program=program,
                    baseline_cycles=meta["baseline_cycles"],
                    optimized_cycles=meta["optimized_cycles"],
                    meta=meta.get("meta", {}),
                    scenario=meta.get("scenario"))


class ScheduleCache:
    """Scenario-indexed artifact store with an in-memory LRU (format v3).

    ``lookup_best`` resolves a kernel's chosen config for one scenario
    bucket through its index — one file read the first time, a dict hit
    afterwards — which is what makes ``deploy()`` and serving free of
    ``autotune``/``Machine`` work.  ``dispatch`` adds the serve-time
    nearest-bucket resolution over the tuned buckets.  Returned artifacts
    carry a fresh ``program`` list, so callers may mutate their copy
    without poisoning the cache.
    """

    def __init__(self, cache_dir: str = DEFAULT_CACHE_DIR,
                 target: Union[str, MachineTarget] = TARGET,
                 lru_size: int = 64):
        self.cache_dir = cache_dir
        self.target = _target_name(target)
        self.lru_size = int(lru_size)
        self._lru: "OrderedDict[str, Artifact]" = OrderedDict()
        # (kernel, target, bucket) -> resolved chosen config
        self._best_cfg: Dict[tuple, Dict] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.disk_loads = 0
        self.fallbacks = 0        # serve-time baseline fallbacks (lowering)
        self.quarantined = 0      # corrupt files renamed *.quarantine

    # -- internals ----------------------------------------------------------

    def _lru_get(self, key: str) -> Optional[Artifact]:
        with self._lock:
            art = self._lru.get(key)
            if art is not None:
                self._lru.move_to_end(key)
                self.hits += 1
            return art

    def _lru_put(self, key: str, art: Artifact) -> None:
        with self._lock:
            self._lru[key] = art
            self._lru.move_to_end(key)
            while len(self._lru) > self.lru_size:
                self._lru.popitem(last=False)

    @staticmethod
    def _fresh(art: Artifact) -> Artifact:
        return dataclasses.replace(art, program=list(art.program),
                                   meta=dict(art.meta))

    def _target(self, target) -> str:
        return self.target if target is None else _target_name(target)

    # -- lookups ------------------------------------------------------------

    def lookup(self, kernel: str, config: Dict,
               scenario: ScenarioKey = None,
               target: Union[str, MachineTarget, None] = None
               ) -> Optional[Artifact]:
        """Artifact for an explicit (kernel, config, scenario) cell,
        LRU-first."""
        tgt = self._target(target)
        key = cache_key(kernel, tgt, config, scenario)
        art = self._lru_get(key)
        if art is not None:
            return self._fresh(art)
        art = load(kernel, tgt, config, self.cache_dir, scenario)
        if art is None:
            with self._lock:
                self.misses += 1
            return None
        self.disk_loads += 1
        self._lru_put(key, art)
        return self._fresh(art)

    def best_config(self, kernel: str, scenario: ScenarioKey = None,
                    target: Union[str, MachineTarget, None] = None
                    ) -> Optional[Dict]:
        """The chosen config of one (kernel, scenario-bucket) cell,
        memoized after the first index read (refreshed by
        ``put(best=True)``; external index rewrites need a fresh
        ScheduleCache to be seen)."""
        tgt = self._target(target)
        bucket = bucket_of(scenario)
        memo_key = (kernel, tgt, bucket)
        cfg = self._best_cfg.get(memo_key)
        if cfg is not None:
            return cfg
        idx = load_index(self.cache_dir, tgt, kernel)
        if idx is not None:
            entry = index_scenarios(idx).get(bucket)
            if entry is not None:
                cfg = entry["config"]
                self._best_cfg[memo_key] = cfg
                return cfg
        return None

    def scenario_buckets(self, kernel: str,
                         target: Union[str, MachineTarget, None] = None
                         ) -> List[str]:
        """The tuned buckets of a kernel (index read; v2 indexes and
        single-artifact v1 directories surface as the default bucket)."""
        tgt = self._target(target)
        idx = load_index(self.cache_dir, tgt, kernel)
        if idx is not None:
            return sorted(index_scenarios(idx))
        if self._v1_single_stem(kernel, tgt) is not None:
            return [DEFAULT_BUCKET]
        return []

    def lookup_best(self, kernel: str, scenario: ScenarioKey = None,
                    target: Union[str, MachineTarget, None] = None
                    ) -> Optional[Artifact]:
        """The chosen artifact of one (kernel, scenario-bucket) cell via
        the index — zero autotune, zero machine execution.  Exact bucket
        only (``dispatch`` does nearest-bucket).  Falls back to the
        directory listing for pure-v1 dirs when exactly one artifact
        exists (unambiguous); the resolved config is memoized either way,
        so repeated lookups are LRU hits."""
        tgt = self._target(target)
        cfg = self.best_config(kernel, scenario, tgt)
        if cfg is not None:
            return self.lookup(kernel, cfg, scenario, tgt)
        bucket = bucket_of(scenario)
        if bucket == DEFAULT_BUCKET:
            stem = self._v1_single_stem(kernel, tgt)
            if stem is not None:
                d = os.path.join(self.cache_dir, tgt, kernel)
                art = self._load_stem(d, stem)
                self._best_cfg[(kernel, tgt, DEFAULT_BUCKET)] = art.config
                self._lru_put(stem, art)
                return self._fresh(art)
        with self._lock:
            self.misses += 1
        return None

    def dispatch(self, kernel: str, scenario: ScenarioKey = None,
                 target: Union[str, MachineTarget, None] = None
                 ) -> Optional[Artifact]:
        """Serve-time dispatch: resolve the request's scenario to the
        *nearest* tuned bucket and return that bucket's chosen artifact —
        a pure index lookup (zero autotune / machine execution), falling
        back through the default bucket so pre-scenario caches keep
        serving.  ``None`` only when the kernel was never optimized."""
        tgt = self._target(target)
        bucket = nearest_bucket(self.scenario_buckets(kernel, tgt), scenario)
        if bucket is None:
            with self._lock:
                self.misses += 1
            return None
        return self.lookup_best(kernel, bucket, tgt)

    def _v1_single_stem(self, kernel: str, tgt: str) -> Optional[str]:
        d = os.path.join(self.cache_dir, tgt, kernel)
        if os.path.isdir(d):
            sidecars = sorted(f for f in os.listdir(d)
                              if f.endswith(".json") and f != "index.json")
            if len(sidecars) == 1:
                return sidecars[0][:-5]   # the stem IS the spec-hash key
        return None

    def _load_stem(self, d: str, stem: str) -> Artifact:
        self.disk_loads += 1
        return _load_files(os.path.join(d, f"{stem}.tsass"),
                           os.path.join(d, f"{stem}.json"))

    # -- writes -------------------------------------------------------------

    def put(self, artifact: Artifact, best: bool = True) -> str:
        path = save(artifact, self.cache_dir, best=best)
        key = cache_key(artifact.kernel, artifact.target, artifact.config,
                        artifact.scenario)
        self._lru_put(key, self._fresh(artifact))
        if best:
            self._best_cfg[(artifact.kernel, artifact.target,
                            artifact.bucket)] = artifact.config
        return path

    # -- quarantine ---------------------------------------------------------

    def quarantine_kernel(self, kernel: str,
                          target: Union[str, MachineTarget, None] = None
                          ) -> List[str]:
        """Rename this kernel's unreadable cache files to ``*.quarantine``
        so one corrupt artifact stops poisoning every load of the
        directory.  Direct :meth:`lookup`/:func:`load` calls still raise
        :class:`CacheVersionError` loudly on corrupt files — quarantine is
        an *explicit* recovery step, invoked by the serve shim's
        ``on_missing="baseline"`` policy (``sched.lowering``) after such a
        raise.  A quarantined sidecar takes its ``.tsass`` twin with it
        (and vice versa): a surviving half-artifact would be
        indistinguishable from a clean miss.  Returns the renamed paths.
        """
        tgt = self._target(target)
        d = os.path.join(self.cache_dir, tgt, kernel)
        renamed: List[str] = []

        def _quarantine(*paths: str) -> None:
            for p in paths:
                if os.path.exists(p):
                    os.replace(p, f"{p}.quarantine")
                    renamed.append(p)

        if os.path.isdir(d):
            try:
                load_index(self.cache_dir, tgt, kernel)
            except CacheVersionError:
                _quarantine(os.path.join(d, "index.json"))
            for f in sorted(os.listdir(d)):
                if not f.endswith(".json") or f == "index.json":
                    continue
                stem = f[:-5]
                json_path = os.path.join(d, f)
                tsass_path = os.path.join(d, f"{stem}.tsass")
                try:
                    _load_files(tsass_path, json_path)
                except (CacheVersionError, ValueError, KeyError, OSError):
                    _quarantine(json_path, tsass_path)
        with self._lock:
            self.quarantined += len(renamed)
            # drop memoized state that may point at quarantined files
            for k in [k for k in self._best_cfg if k[0] == kernel
                      and k[1] == tgt]:
                del self._best_cfg[k]
            self._lru.clear()
        return renamed

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "disk_loads": self.disk_loads, "lru_entries": len(self._lru),
                "fallbacks": self.fallbacks,
                "quarantined": self.quarantined}
