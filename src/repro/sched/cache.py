"""Offline-search / deploy-time-lookup artifact cache (paper §4.2).

"The best optimized cubin found throughout the assembly game is written to
the file system, prefixed by GPU type, workload type etc., as the key to
lookup.  At deployment ... it invokes a lookup process instead of training."

Artifacts are TSASS text (round-trippable through the parser) plus a JSON
sidecar with measured cycles, the winning autotune config and provenance.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Dict, List, Optional

from repro.core.isa import Instruction, program_text
from repro.core.parser import parse_program

DEFAULT_CACHE_DIR = os.environ.get("REPRO_SCHED_CACHE", ".repro_cache")


@dataclasses.dataclass
class Artifact:
    kernel: str
    target: str
    config: Dict
    program: List[Instruction]
    baseline_cycles: float
    optimized_cycles: float
    meta: Dict

    @property
    def speedup(self) -> float:
        return self.baseline_cycles / max(self.optimized_cycles, 1.0)


def cache_key(kernel: str, target: str, config: Dict) -> str:
    blob = json.dumps({"k": kernel, "t": target, "c": config}, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _paths(cache_dir: str, kernel: str, target: str, config: Dict):
    key = cache_key(kernel, target, config)
    d = os.path.join(cache_dir, target, kernel)
    return os.path.join(d, f"{key}.tsass"), os.path.join(d, f"{key}.json")


def save(artifact: Artifact, cache_dir: str = DEFAULT_CACHE_DIR) -> str:
    tsass_path, json_path = _paths(cache_dir, artifact.kernel,
                                   artifact.target, artifact.config)
    os.makedirs(os.path.dirname(tsass_path), exist_ok=True)
    # atomic writes: temp + rename (same discipline as the checkpointer)
    for path, payload in (
        (tsass_path, program_text(artifact.program) + "\n"),
        (json_path, json.dumps({
            "kernel": artifact.kernel, "target": artifact.target,
            "config": artifact.config,
            "baseline_cycles": artifact.baseline_cycles,
            "optimized_cycles": artifact.optimized_cycles,
            "meta": artifact.meta}, indent=2)),
    ):
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path))
        with os.fdopen(fd, "w") as f:
            f.write(payload)
        os.replace(tmp, path)
    return tsass_path


def load(kernel: str, target: str, config: Dict,
         cache_dir: str = DEFAULT_CACHE_DIR) -> Optional[Artifact]:
    tsass_path, json_path = _paths(cache_dir, kernel, target, config)
    if not (os.path.exists(tsass_path) and os.path.exists(json_path)):
        return None
    with open(json_path) as f:
        meta = json.load(f)
    with open(tsass_path) as f:
        program = parse_program(f.read())
    return Artifact(kernel=meta["kernel"], target=meta["target"],
                    config=meta["config"], program=program,
                    baseline_cycles=meta["baseline_cycles"],
                    optimized_cycles=meta["optimized_cycles"],
                    meta=meta.get("meta", {}))
