"""Offline-search / deploy-time-lookup artifact cache (paper §4.2).

"The best optimized cubin found throughout the assembly game is written to
the file system, prefixed by GPU type, workload type etc., as the key to
lookup.  At deployment ... it invokes a lookup process instead of training."

Artifacts are TSASS text (round-trippable through the parser) plus a JSON
sidecar with measured cycles, the winning autotune config and provenance.

Format v2 adds two things on top of the original flat files (v1):

* sidecars carry ``"version": 2`` — v1 sidecars (no version field) still
  load; an unknown version or an unreadable file raises
  :class:`CacheVersionError` / the underlying parse error **loudly**
  instead of silently missing;
* a per-kernel ``index.json`` records every cached config under its
  spec-hash key plus the *chosen* (autotune-best) config, so deploy-time
  lookup is a single index read — no re-autotune (the legacy
  ``CuAsmRL.deploy`` re-ran the whole grid just to recover the key).

:class:`ScheduleCache` wraps the files with an in-memory LRU so repeated
``deploy()`` / serving lookups are O(1) dict hits.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import threading
from collections import OrderedDict
from typing import Dict, List, Optional

from repro.core.isa import Instruction, program_text
from repro.core.parser import parse_program

DEFAULT_CACHE_DIR = os.environ.get("REPRO_SCHED_CACHE", ".repro_cache")
TARGET = "tpu-tsass-v1"
CACHE_VERSION = 2
_KNOWN_VERSIONS = (1, 2)


class CacheVersionError(RuntimeError):
    """A cache file exists but cannot be trusted (unknown version /
    malformed payload).  Deliberately loud: a silent miss would retrain and
    overwrite an artifact that may still be served elsewhere."""


@dataclasses.dataclass
class Artifact:
    kernel: str
    target: str
    config: Dict
    program: List[Instruction]
    baseline_cycles: float
    optimized_cycles: float
    meta: Dict

    @property
    def speedup(self) -> float:
        return self.baseline_cycles / max(self.optimized_cycles, 1.0)


def cache_key(kernel: str, target: str, config: Dict) -> str:
    blob = json.dumps({"k": kernel, "t": target, "c": config}, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _paths(cache_dir: str, kernel: str, target: str, config: Dict):
    key = cache_key(kernel, target, config)
    d = os.path.join(cache_dir, target, kernel)
    return os.path.join(d, f"{key}.tsass"), os.path.join(d, f"{key}.json")


def _index_path(cache_dir: str, target: str, kernel: str) -> str:
    return os.path.join(cache_dir, target, kernel, "index.json")


def _atomic_write(path: str, payload: str) -> None:
    # atomic writes: temp + rename (same discipline as the checkpointer)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path))
    with os.fdopen(fd, "w") as f:
        f.write(payload)
    os.replace(tmp, path)


def load_index(cache_dir: str, target: str, kernel: str) -> Optional[Dict]:
    """The kernel's spec-hash index, or ``None`` when never written (pure
    v1 directory).  Unknown index versions fail loudly."""
    path = _index_path(cache_dir, target, kernel)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        try:
            idx = json.load(f)
        except ValueError as e:
            raise CacheVersionError(f"corrupt cache index {path}: {e}") from e
    if idx.get("version") not in _KNOWN_VERSIONS:
        raise CacheVersionError(
            f"cache index {path} has unknown version {idx.get('version')!r}")
    return idx


# serializes the index read-modify-write below: concurrent optimize_many
# threads saving into one kernel's dir must not lose each other's entries
# (cross-process writers still race benignly — artifacts are content-
# addressed, only the index merge needs the lock)
_INDEX_LOCK = threading.Lock()


def _update_index(artifact: Artifact, cache_dir: str, best: bool) -> None:
    path = _index_path(cache_dir, artifact.target, artifact.kernel)
    with _INDEX_LOCK:
        try:
            idx = load_index(cache_dir, artifact.target, artifact.kernel)
        except CacheVersionError:
            idx = None                 # rebuild a corrupt index on write
        if idx is None:
            idx = {"version": CACHE_VERSION, "kernel": artifact.kernel,
                   "target": artifact.target, "entries": {}}
        key = cache_key(artifact.kernel, artifact.target, artifact.config)
        idx.setdefault("entries", {})[key] = artifact.config
        if best or "best" not in idx:
            idx["best"] = {"key": key, "config": artifact.config,
                           "optimized_cycles": artifact.optimized_cycles}
        os.makedirs(os.path.dirname(path), exist_ok=True)
        _atomic_write(path, json.dumps(idx, indent=2, sort_keys=True))


def save(artifact: Artifact, cache_dir: str = DEFAULT_CACHE_DIR,
         best: bool = True) -> str:
    """Write the artifact (v2 sidecar) and record it in the kernel's index.
    ``best=True`` marks its config as the kernel's chosen one — the config
    ``deploy()`` resolves without re-running autotune."""
    tsass_path, json_path = _paths(cache_dir, artifact.kernel,
                                   artifact.target, artifact.config)
    os.makedirs(os.path.dirname(tsass_path), exist_ok=True)
    for path, payload in (
        (tsass_path, program_text(artifact.program) + "\n"),
        (json_path, json.dumps({
            "version": CACHE_VERSION,
            "kernel": artifact.kernel, "target": artifact.target,
            "config": artifact.config,
            "baseline_cycles": artifact.baseline_cycles,
            "optimized_cycles": artifact.optimized_cycles,
            "meta": artifact.meta}, indent=2)),
    ):
        _atomic_write(path, payload)
    _update_index(artifact, cache_dir, best)
    return tsass_path


def load(kernel: str, target: str, config: Dict,
         cache_dir: str = DEFAULT_CACHE_DIR) -> Optional[Artifact]:
    """Load one artifact by (kernel, target, config).  Missing files are a
    miss (``None``); present-but-untrusted files raise."""
    tsass_path, json_path = _paths(cache_dir, kernel, target, config)
    if not (os.path.exists(tsass_path) and os.path.exists(json_path)):
        return None
    return _load_files(tsass_path, json_path)


def _load_files(tsass_path: str, json_path: str) -> Artifact:
    with open(json_path) as f:
        try:
            meta = json.load(f)
        except ValueError as e:
            raise CacheVersionError(
                f"corrupt cache sidecar {json_path}: {e}") from e
    version = meta.get("version", 1)   # v1 sidecars predate the field
    if version not in _KNOWN_VERSIONS:
        raise CacheVersionError(
            f"cache artifact {json_path} has unknown version {version!r}; "
            f"refusing to guess (supported: {_KNOWN_VERSIONS})")
    with open(tsass_path) as f:
        program = parse_program(f.read())
    return Artifact(kernel=meta["kernel"], target=meta["target"],
                    config=meta["config"], program=program,
                    baseline_cycles=meta["baseline_cycles"],
                    optimized_cycles=meta["optimized_cycles"],
                    meta=meta.get("meta", {}))


class ScheduleCache:
    """Spec-hash-indexed artifact store with an in-memory LRU (format v2).

    ``lookup_best`` resolves a kernel's chosen config through its index —
    one file read the first time, a dict hit afterwards — which is what
    makes ``deploy()`` and serving free of ``autotune``/``Machine`` work.
    Returned artifacts carry a fresh ``program`` list, so callers may
    mutate their copy without poisoning the cache.
    """

    def __init__(self, cache_dir: str = DEFAULT_CACHE_DIR,
                 target: str = TARGET, lru_size: int = 64):
        self.cache_dir = cache_dir
        self.target = target
        self.lru_size = int(lru_size)
        self._lru: "OrderedDict[str, Artifact]" = OrderedDict()
        self._best_cfg: Dict[str, Dict] = {}   # kernel -> resolved config
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.disk_loads = 0

    # -- internals ----------------------------------------------------------

    def _lru_get(self, key: str) -> Optional[Artifact]:
        with self._lock:
            art = self._lru.get(key)
            if art is not None:
                self._lru.move_to_end(key)
                self.hits += 1
            return art

    def _lru_put(self, key: str, art: Artifact) -> None:
        with self._lock:
            self._lru[key] = art
            self._lru.move_to_end(key)
            while len(self._lru) > self.lru_size:
                self._lru.popitem(last=False)

    @staticmethod
    def _fresh(art: Artifact) -> Artifact:
        return dataclasses.replace(art, program=list(art.program),
                                   meta=dict(art.meta))

    # -- lookups ------------------------------------------------------------

    def lookup(self, kernel: str, config: Dict) -> Optional[Artifact]:
        """Artifact for an explicit (kernel, config) pair, LRU-first."""
        key = cache_key(kernel, self.target, config)
        art = self._lru_get(key)
        if art is not None:
            return self._fresh(art)
        art = load(kernel, self.target, config, self.cache_dir)
        if art is None:
            with self._lock:
                self.misses += 1
            return None
        self.disk_loads += 1
        self._lru_put(key, art)
        return self._fresh(art)

    def best_config(self, kernel: str) -> Optional[Dict]:
        """The kernel's chosen config, memoized after the first index read
        (refreshed by ``put(best=True)``; external index rewrites need a
        fresh ScheduleCache to be seen)."""
        cfg = self._best_cfg.get(kernel)
        if cfg is not None:
            return cfg
        idx = load_index(self.cache_dir, self.target, kernel)
        if idx is not None and "best" in idx:
            cfg = idx["best"]["config"]
            self._best_cfg[kernel] = cfg
            return cfg
        return None

    def lookup_best(self, kernel: str) -> Optional[Artifact]:
        """The kernel's chosen artifact via the index — zero autotune, zero
        machine execution.  Falls back to the directory listing for pure-v1
        dirs when exactly one artifact exists (unambiguous); the resolved
        config is memoized either way, so repeated lookups are LRU hits."""
        cfg = self.best_config(kernel)
        if cfg is not None:
            return self.lookup(kernel, cfg)
        d = os.path.join(self.cache_dir, self.target, kernel)
        if os.path.isdir(d):
            sidecars = sorted(f for f in os.listdir(d)
                              if f.endswith(".json") and f != "index.json")
            if len(sidecars) == 1:
                stem = sidecars[0][:-5]   # the stem IS the spec-hash key
                art = self._load_stem(d, stem)
                self._best_cfg[kernel] = art.config
                self._lru_put(stem, art)
                return self._fresh(art)
        with self._lock:
            self.misses += 1
        return None

    def _load_stem(self, d: str, stem: str) -> Artifact:
        self.disk_loads += 1
        return _load_files(os.path.join(d, f"{stem}.tsass"),
                           os.path.join(d, f"{stem}.json"))

    # -- writes -------------------------------------------------------------

    def put(self, artifact: Artifact, best: bool = True) -> str:
        path = save(artifact, self.cache_dir, best=best)
        key = cache_key(artifact.kernel, self.target, artifact.config)
        self._lru_put(key, self._fresh(artifact))
        if best:
            self._best_cfg[artifact.kernel] = artifact.config
        return path

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "disk_loads": self.disk_loads, "lru_entries": len(self._lru)}
