"""Public API of the schedule optimizer — the ``@cuasmrl.jit`` analogue
(paper §4.1 Listing 4, §4.2 Listing 5).

    kdef = repro.kernels.KERNELS["matmul_leakyrelu"]
    opt  = CuAsmRL(kdef)
    art  = opt.optimize()          # hierarchical search + assembly game
    art  = opt.deploy()            # deploy-time lookup, no training

Pipeline per kernel: autotune configs (§3.1) -> lower best config to TSASS ->
baseline -O3 schedule -> PPO assembly game (§3.3-3.7) -> probabilistic
testing (§4.1) -> cache artifact (§4.2).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.core.game import GameResult, train_on_program
from repro.core.machine import Machine
from repro.core.microbench import build_stall_table
from repro.core.ppo import PPOConfig
from repro.sched import autotune as autotune_mod
from repro.sched import baseline, cache, lowering, verify
from repro.sched.spec import KernelSpec

TARGET = "tpu-tsass-v1"


@dataclasses.dataclass
class KernelDef:
    """One optimizable kernel: its Pallas/ref callables plus the schedule
    spec constructor and the autotuner's configuration space."""
    name: str
    make_spec: Callable[[Dict], KernelSpec]
    configs: List[Dict]
    pallas_fn: Optional[Callable] = None
    ref_fn: Optional[Callable] = None


class CuAsmRL:
    def __init__(self, kdef: KernelDef,
                 ppo: Optional[PPOConfig] = None,
                 cache_dir: str = cache.DEFAULT_CACHE_DIR,
                 target: str = TARGET,
                 machine_factory: Callable[[], Machine] = Machine,
                 stall_db: Optional[Dict[str, int]] = None,
                 verify_seeds: int = 4):
        self.kdef = kdef
        self.ppo = ppo or PPOConfig()
        self.cache_dir = cache_dir
        self.target = target
        self.machine_factory = machine_factory
        # Table 1: built once per target by dependency microbenchmarking
        self.stall_db = stall_db if stall_db is not None else \
            build_stall_table(machine=machine_factory())
        self.verify_seeds = verify_seeds
        self.last_game: Optional[GameResult] = None

    # ---- §4.2 Listing 5: invoke optimization --------------------------------

    def optimize(self, force: bool = False, verbose: bool = False
                 ) -> cache.Artifact:
        tune = autotune_mod.autotune(self.kdef.make_spec, self.kdef.configs,
                                     self.machine_factory())
        cfg = tune.best.config
        cached = None if force else cache.load(self.kdef.name, self.target,
                                               cfg, self.cache_dir)
        if cached is not None:
            return cached

        spec = self.kdef.make_spec(cfg)
        lowered = lowering.lower(spec)
        o3 = baseline.schedule(lowered)
        game = train_on_program(o3, stall_db=self.stall_db, cfg=self.ppo,
                                machine_factory=self.machine_factory,
                                verbose=verbose)
        self.last_game = game

        check = verify.probabilistic_test(o3, game.best_program,
                                          n_seeds=self.verify_seeds,
                                          machine=self.machine_factory())
        if not check.ok:
            raise RuntimeError(
                f"probabilistic testing FAILED for {self.kdef.name}: "
                f"seeds {check.failures} — masking bug, refusing to cache")

        art = cache.Artifact(
            kernel=self.kdef.name, target=self.target, config=cfg,
            program=game.best_program,
            baseline_cycles=game.baseline_cycles,
            optimized_cycles=game.best_cycles,
            meta={
                "autotune": [dataclasses.asdict(e) for e in tune.entries],
                "improvement": game.improvement,
                "ppo_updates": len(game.stats),
                "verify_seeds": check.n_seeds,
            })
        cache.save(art, self.cache_dir)
        return art

    # ---- §4.2 Listing 5: deployment lookup ------------------------------------

    def deploy(self, load_dir: Optional[str] = None) -> cache.Artifact:
        tune = autotune_mod.autotune(self.kdef.make_spec, self.kdef.configs,
                                     self.machine_factory())
        art = cache.load(self.kdef.name, self.target, tune.best.config,
                         load_dir or self.cache_dir)
        if art is None:
            raise FileNotFoundError(
                f"no cached schedule for {self.kdef.name}; run optimize() "
                f"offline first (the paper's search/deploy split)")
        return art
