"""Legacy public API of the schedule optimizer — the ``@cuasmrl.jit``
analogue (paper §4.1 Listing 4, §4.2 Listing 5).

.. deprecated::
    ``CuAsmRL`` **is** an :class:`OptimizationSession` now — a
    ``DeprecationWarning``-emitting alias that pins one kernel and keeps
    the legacy ``optimize(force=...)`` / ``deploy(load_dir=...)``
    call shapes working.  New code should write

        session = OptimizationSession()
        res = session.optimize(OptimizeRequest(kernel="matmul_leakyrelu"))
        art = session.deploy("matmul_leakyrelu")

    Every session capability (``optimize_many``, scenario/target axes,
    pluggable backends) is available on the alias directly.
"""

from __future__ import annotations

import warnings
from typing import Callable, Dict, Optional, Union

from repro.core.game import GameResult
from repro.core.machine import Machine
from repro.core.ppo import PPOConfig
from repro.sched import cache
from repro.sched.backends import FastTimingBackend
from repro.sched.cache import TARGET, ScheduleCache
from repro.sched.session import (KernelDef, OptimizationSession,
                                 OptimizeRequest)

__all__ = ["CuAsmRL", "KernelDef", "TARGET"]


class CuAsmRL(OptimizationSession):
    """Deprecated one-kernel alias of :class:`OptimizationSession`."""

    def __init__(self, kdef: KernelDef,
                 ppo: Optional[PPOConfig] = None,
                 cache_dir: str = cache.DEFAULT_CACHE_DIR,
                 target: str = TARGET,
                 machine_factory: Callable[[], Machine] = Machine,
                 stall_db: Optional[Dict[str, int]] = None,
                 verify_seeds: int = 4):
        warnings.warn(
            "CuAsmRL is deprecated; use OptimizationSession.optimize("
            "OptimizeRequest(kernel=...)) — see repro.sched.session",
            DeprecationWarning, stacklevel=2)
        super().__init__(
            backend=FastTimingBackend(machine_factory=machine_factory),
            cache_dir=cache_dir, target=target, stall_db=stall_db,
            verify_seeds=verify_seeds)
        self.kdef = kdef
        self.ppo = ppo or PPOConfig()
        self.cache_dir = cache_dir
        self.machine_factory = machine_factory
        self.last_game: Optional[GameResult] = None

    @property
    def stall_db(self) -> Dict[str, int]:
        # Table 1: built once per target by dependency microbenchmarking
        return self.stall_table()

    # ---- §4.2 Listing 5: invoke optimization --------------------------------

    def optimize(self, request=None, *, force: bool = False,
                 verbose: bool = False):
        """Legacy ``optimize(force=..., verbose=...)`` on the pinned
        kernel, returning the bare :class:`~repro.sched.cache.Artifact`.
        A session-style request argument goes straight to
        :meth:`OptimizationSession.optimize` and returns its
        ``OptimizeResult``."""
        if request is not None:
            return super().optimize(request)
        res = super().optimize(OptimizeRequest(
            kernel=self.kdef, ppo=self.ppo, force=force, verbose=verbose))
        if res.game is not None:
            self.last_game = res.game
        return res.artifact

    # ---- §4.2 Listing 5: deployment lookup ------------------------------------

    def deploy(self, load_dir: Optional[str] = None, **kwargs):
        """Legacy ``deploy(load_dir=...)`` on the pinned kernel — a pure
        cache-index lookup (v1 single-artifact directories resolve
        through :class:`ScheduleCache` itself).  Passing a kernel
        name/def (session-style) forwards to
        :meth:`OptimizationSession.deploy`."""
        if isinstance(load_dir, (KernelDef,)) or kwargs or (
                isinstance(load_dir, str) and not _looks_like_path(load_dir)):
            return super().deploy(load_dir, **kwargs)
        sc = (self.cache if load_dir is None
              else ScheduleCache(load_dir, self.target))
        art = sc.lookup_best(self.kdef.name)
        if art is None:
            raise FileNotFoundError(
                f"no cached schedule for {self.kdef.name}; run optimize() "
                f"offline first (the paper's search/deploy split)")
        return art


def _looks_like_path(s: str) -> bool:
    """Disambiguate legacy ``deploy(load_dir)`` from session-style
    ``deploy(kernel_name)``: cache dirs carry path separators or exist on
    disk; registry names never do."""
    import os
    return os.sep in s or "/" in s or os.path.isdir(s)
