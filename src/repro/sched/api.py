"""Legacy public API of the schedule optimizer — the ``@cuasmrl.jit``
analogue (paper §4.1 Listing 4, §4.2 Listing 5).

.. deprecated::
    ``CuAsmRL`` is now a thin shim over the session API
    (:mod:`repro.sched.session`); new code should write

        session = OptimizationSession()
        res = session.optimize(OptimizeRequest(kernel="matmul_leakyrelu"))
        art = session.deploy("matmul_leakyrelu")

    The shim keeps every existing caller working unchanged — including the
    deploy-time fix: ``deploy()`` resolves the chosen config through the
    cache index instead of re-running autotune (it only falls back to the
    legacy grid-search lookup for pre-index v1 cache directories).
"""

from __future__ import annotations

import warnings
from typing import Callable, Dict, Optional

from repro.core.game import GameResult
from repro.core.machine import Machine
from repro.core.ppo import PPOConfig
from repro.sched import autotune as autotune_mod
from repro.sched import cache
from repro.sched.backends import FastTimingBackend
from repro.sched.cache import TARGET, ScheduleCache
from repro.sched.session import (KernelDef, OptimizationSession,
                                 OptimizeRequest)

__all__ = ["CuAsmRL", "KernelDef", "TARGET"]


class CuAsmRL:
    """One-kernel wrapper over :class:`OptimizationSession` (deprecated)."""

    def __init__(self, kdef: KernelDef,
                 ppo: Optional[PPOConfig] = None,
                 cache_dir: str = cache.DEFAULT_CACHE_DIR,
                 target: str = TARGET,
                 machine_factory: Callable[[], Machine] = Machine,
                 stall_db: Optional[Dict[str, int]] = None,
                 verify_seeds: int = 4):
        warnings.warn(
            "CuAsmRL is deprecated; use OptimizationSession.optimize("
            "OptimizeRequest(kernel=...)) — see repro.sched.session",
            DeprecationWarning, stacklevel=2)
        self.kdef = kdef
        self.ppo = ppo or PPOConfig()
        self.cache_dir = cache_dir
        self.target = target
        self.machine_factory = machine_factory
        self.verify_seeds = verify_seeds
        self.session = OptimizationSession(
            backend=FastTimingBackend(machine_factory=machine_factory),
            cache_dir=cache_dir, target=target, stall_db=stall_db,
            verify_seeds=verify_seeds)
        self.last_game: Optional[GameResult] = None

    @property
    def stall_db(self) -> Dict[str, int]:
        # Table 1: built once per target by dependency microbenchmarking
        return self.session.stall_table()

    # ---- §4.2 Listing 5: invoke optimization --------------------------------

    def optimize(self, force: bool = False, verbose: bool = False
                 ) -> cache.Artifact:
        res = self.session.optimize(OptimizeRequest(
            kernel=self.kdef, ppo=self.ppo, force=force, verbose=verbose))
        if res.game is not None:
            self.last_game = res.game
        return res.artifact

    # ---- §4.2 Listing 5: deployment lookup ------------------------------------

    def deploy(self, load_dir: Optional[str] = None) -> cache.Artifact:
        sc = (self.session.cache if load_dir is None
              else ScheduleCache(load_dir, self.target))
        art = sc.lookup_best(self.kdef.name)
        if art is None:
            # pre-index (v1) cache directory: recover the chosen config the
            # way the legacy class did — by re-running the autotune grid
            tune = autotune_mod.autotune(self.kdef.make_spec,
                                         self.kdef.configs,
                                         self.machine_factory())
            art = cache.load(self.kdef.name, self.target, tune.best.config,
                             load_dir or self.cache_dir)
        if art is None:
            raise FileNotFoundError(
                f"no cached schedule for {self.kdef.name}; run optimize() "
                f"offline first (the paper's search/deploy split)")
        return art
