"""Kernel schedule specs: the contract between Pallas kernels and the
TSASS lowering/optimization pipeline (the cubin-interception point of the
paper's Fig. 2, adapted to Pallas — DESIGN.md §2.4).

A :class:`KernelSpec` describes the *steady-state inner loop* of a tiled
kernel: which HBM tiles are DMA'd in per grid step, the per-step tile
computation (a traceable jnp function — its jaxpr drives instruction
selection), and which tiles are DMA'd out.  Block sizes come from the
autotuner (§3.1 hierarchical search), so one kernel yields one spec per
candidate configuration.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TileIO:
    """One tile moved between HBM and VMEM each grid step.

    ``invariant`` tiles keep the same HBM address every step (weights,
    norm scales): their address registers are defined in the prologue
    *before* the loop label — which is exactly what makes the paper's
    denylist non-empty (§3.2: defs across labels are unresolvable).
    """
    name: str
    shape: Tuple[int, ...]
    dtype: str = "bf16"
    invariant: bool = False

    @property
    def itemsize(self) -> int:
        return {"bf16": 2, "f32": 4, "f16": 2, "i8": 1, "i32": 4}[self.dtype]

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * self.itemsize


@dataclasses.dataclass
class KernelSpec:
    name: str
    tile_fn: Callable                      # (*input tiles) -> tuple(outputs)
    inputs: List[TileIO]
    outputs: List[TileIO]
    steps: int = 3                         # inner-loop iterations to materialize
    accumulate: bool = False               # outputs stored only on last step
    epilogue_fn: Optional[Callable] = None  # applied to accumulators at the end
    config: Dict = dataclasses.field(default_factory=dict)
    flops_per_step: int = 0

    def describe(self) -> str:
        ins = ", ".join(f"{t.name}{list(t.shape)}" for t in self.inputs)
        outs = ", ".join(f"{t.name}{list(t.shape)}" for t in self.outputs)
        return (f"{self.name}[{self.config}] steps={self.steps} "
                f"in=({ins}) out=({outs})")
