"""Fault-tolerant measurement: retries, robust statistics, circuit
breaking, and the campaign failure ledger.

The paper's measurement channel is real hardware (§3.6) and its search
loop leans on repeated measurement and probabilistic testing precisely
because that channel flakes, hangs, crashes and returns outliers (§4).
This module is the simulated-stack counterpart: a decorator backend that
makes any :class:`repro.sched.backends.MeasureBackend` survive the fault
modes :mod:`repro.core.faults` injects.

* :class:`RetryPolicy` — the knobs: bounded retries with exponential
  backoff + deterministic jitter, a per-measure wall-clock deadline,
  median-of-k sampling with MAD outlier rejection (k adapts upward while
  the spread stays wide), and the circuit-breaker threshold.
* :class:`ResilientBackend` — wraps an inner backend.  One-shot timings
  (``time`` / ``autotune_time_fn``) get the full retry + robust-statistics
  treatment; machines handed to the assembly game
  (:meth:`ResilientBackend.new_machine`) are wrapped in
  :class:`ResilientMachine` so the game's direct ``machine.run`` /
  ``machine.time`` measurements retry too.  A *deterministic* inner
  backend (stock noise-free machine) passes straight through — the
  memoized fast path stays bit-exact with zero overhead.
* **Circuit breaker** — ``breaker_threshold`` *consecutive* hard
  failures (:class:`~repro.core.faults.HardFault` or retry exhaustion)
  trip the breaker: from then on every measurement for that target is
  served by the deterministic scoreboard model (the
  :class:`~repro.sched.backends.FastTimingBackend` semantics) instead of
  the faulty channel, and ``summary()`` reports the degradation.  Any
  success before the threshold resets the count, so one
  always-crashing cell in an otherwise healthy campaign fails alone
  without dragging its target into degraded mode.
* :class:`FailureLedger` — the persistent per-campaign record
  (``campaign_state.json``) of failed cells: error, attempt count, last
  backoff.  ``launch.optimize`` uses it for resumable supervised
  campaigns — a re-run retries exactly the failed cells, with backoff,
  up to ``--max-retries`` attempts.

Registered as ``BACKENDS["resilient"]`` so ``make_backend("resilient")``
and the launchers' ``--backend resilient`` compose it over the default
fast-timing backend.
"""

from __future__ import annotations

import json
import os
import random
import statistics
import threading
import time
import warnings
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.faults import HardFault, MeasureError, MeasureTimeout
from repro.core.isa import Instruction
from repro.core.machine import Machine, RunResult
from repro.sched.backends import (BACKENDS, FastTimingBackend, MeasureBackend,
                                  SharedMeasureMemo)

# MAD -> sigma for normally distributed samples; the usual robust-stats
# consistency constant
_MAD_SIGMA = 1.4826


class MeasureExhausted(MeasureError):
    """The retry budget ran out without one successful measurement —
    the channel is persistently failing, not merely flaky.  Counts as a
    hard failure toward the circuit breaker."""


@dataclass(frozen=True)
class RetryPolicy:
    """Knobs of the resilient measurement loop.

    ``max_retries`` bounds *extra* attempts per measurement (total =
    1 + max_retries).  ``backoff_s`` is the first retry's sleep, doubling
    (``backoff_mult``) each retry with up to ``jitter`` fractional
    deterministic jitter on top — 0 keeps tests instant.  ``timeout_s``
    is a per-measure wall-clock deadline: a call that returns *after* it
    (a hang / latency spike) is discarded and retried as a
    :class:`~repro.core.faults.MeasureTimeout`.  ``samples`` is the
    median-of-k width for one-shot timings; MAD-rejected outliers are
    re-drawn and ``samples`` escalates (doubles, up to ``max_samples``)
    while the relative spread exceeds ``spread_tolerance``.
    ``breaker_threshold`` consecutive hard failures trip the circuit
    breaker (see module docstring).
    """

    max_retries: int = 4
    backoff_s: float = 0.0
    backoff_mult: float = 2.0
    jitter: float = 0.25
    timeout_s: Optional[float] = None
    samples: int = 1
    max_samples: int = 8
    mad_threshold: float = 3.5
    spread_tolerance: float = 0.05
    breaker_threshold: int = 3


class BackendHealth:
    """Shared mutable health state of one resilient backend (all machines
    it hands out report here).  Thread-compatible under the GIL: counter
    bumps are single int ops and the breaker latches one way."""

    def __init__(self):
        self.circuit_open = False
        self.consecutive_hard = 0
        self.counters = {
            "measures": 0, "retries": 0, "transients": 0, "timeouts": 0,
            "hard_faults": 0, "exhausted": 0, "outliers_rejected": 0,
            "sample_escalations": 0, "breaker_trips": 0, "degraded": 0,
        }

    def record_success(self) -> None:
        self.counters["measures"] += 1
        self.consecutive_hard = 0

    def record_hard(self, policy: RetryPolicy, kind: str) -> None:
        self.counters[kind] += 1
        self.consecutive_hard += 1
        if (not self.circuit_open
                and self.consecutive_hard >= policy.breaker_threshold):
            self.circuit_open = True
            self.counters["breaker_trips"] += 1


def call_with_retries(fn: Callable[[], "object"], policy: RetryPolicy,
                      health: BackendHealth,
                      rng: random.Random) -> "object":
    """Run one measurement through the retry loop: transient raises and
    post-hoc deadline violations are retried with exponential backoff +
    jitter; :class:`HardFault` propagates immediately (retrying a
    schedule that crashes the machine is futile); exhaustion raises
    :class:`MeasureExhausted`.  Both hard outcomes feed the breaker."""
    delay = policy.backoff_s
    last: Optional[MeasureError] = None
    for attempt in range(policy.max_retries + 1):
        if attempt:
            health.counters["retries"] += 1
            if delay > 0:
                time.sleep(delay * (1.0 + policy.jitter * rng.random()))
                delay *= policy.backoff_mult
        t0 = time.monotonic()
        try:
            value = fn()
        except HardFault:
            health.record_hard(policy, "hard_faults")
            raise
        except MeasureError as e:
            key = "timeouts" if isinstance(e, MeasureTimeout) else "transients"
            health.counters[key] += 1
            last = e
            continue
        if policy.timeout_s is not None \
                and time.monotonic() - t0 > policy.timeout_s:
            health.counters["timeouts"] += 1
            last = MeasureTimeout(
                f"measurement exceeded the {policy.timeout_s:.3f}s deadline")
            continue
        health.record_success()
        return value
    health.record_hard(policy, "exhausted")
    raise MeasureExhausted(
        f"measurement failed after {policy.max_retries + 1} attempts "
        f"(last: {last})") from last


class ResilientMachine(Machine):
    """The machine the assembly game / verifier sees when the inner
    channel can fault: every ``time``/``run``/``issue_times`` goes through
    the retry loop, and once the target's breaker is open, measurements
    are served by a private deterministic scoreboard machine instead
    (dataflow hashes from ``run`` stay real — the fallback is a full
    stock :class:`Machine`, not a timing surrogate)."""

    def __init__(self, inner: Machine, policy: RetryPolicy,
                 health: BackendHealth, rng: random.Random,
                 fallback: Optional[Machine] = None):
        super().__init__(noise=getattr(inner, "noise", 0.0), seed=0)
        self.inner = inner
        self.policy = policy
        self.health = health
        self._retry_rng = rng
        self.fallback = fallback if fallback is not None else Machine()

    def _measure(self, fn: Callable[[], "object"],
                 degraded_fn: Callable[[], "object"]) -> "object":
        if self.health.circuit_open:
            self.health.counters["degraded"] += 1
            return degraded_fn()
        try:
            return call_with_retries(fn, self.policy, self.health,
                                     self._retry_rng)
        except (HardFault, MeasureExhausted):
            if self.health.circuit_open:      # this failure tripped it
                self.health.counters["degraded"] += 1
                return degraded_fn()
            raise

    def time(self, program: Sequence[Instruction],
             input_seed: int = 0) -> float:
        return self._measure(lambda: self.inner.time(program, input_seed),
                             lambda: self.fallback.time(program, input_seed))

    def run(self, program: Sequence[Instruction], input_seed: int = 0,
            _serialize: bool = False) -> RunResult:
        return self._measure(
            lambda: self.inner.run(program, input_seed=input_seed,
                                   _serialize=_serialize),
            lambda: self.fallback.run(program, input_seed=input_seed,
                                      _serialize=_serialize))

    def issue_times(self, program: Sequence[Instruction]) -> List[float]:
        return self._measure(lambda: self.inner.issue_times(program),
                             lambda: self.fallback.issue_times(program))


class ResilientBackend:
    """Decorator :class:`MeasureBackend`: fault tolerance over any inner
    backend (see module docstring).  Composes through ``for_target`` —
    each target sibling wraps the inner backend's sibling with its *own*
    health/breaker (one wedged target must not degrade another), while
    ``summary()``/``stats()`` aggregate over the whole family."""

    fast_measure = True
    measure_workers: Optional[int] = None

    def __init__(self, inner: Optional[MeasureBackend] = None,
                 policy: Optional[RetryPolicy] = None,
                 fallback_factory: Callable[[], Machine] = Machine,
                 _family: Optional[List[BackendHealth]] = None):
        self.inner = inner if inner is not None else FastTimingBackend()
        self.policy = policy if policy is not None else RetryPolicy()
        self.name = f"resilient[{self.inner.name}]"
        self.fast_measure = self.inner.fast_measure
        self.measure_workers = self.inner.measure_workers
        self._fallback_factory = fallback_factory
        self.health = BackendHealth()
        self._family = _family if _family is not None else []
        self._family.append(self.health)
        self._rng = random.Random(0)
        self._machine: Optional[Machine] = None   # persistent faulty channel
        # the degraded path: deterministic scoreboard timing (shares the
        # inner memo when it has one, so degraded cells still memoize)
        memo = getattr(self.inner, "memo", None)
        self._fallback = FastTimingBackend(
            fallback_factory,
            memo=memo if isinstance(memo, SharedMeasureMemo) else None)

    # -- passthrough state ---------------------------------------------------

    @property
    def memo(self):
        return getattr(self.inner, "memo", None)

    @property
    def circuit_open(self) -> bool:
        return self.health.circuit_open

    @property
    def _deterministic(self) -> bool:
        """When the inner channel is already a pure function of the
        schedule, there is nothing to be resilient *against* — pass
        machines and memo views straight through so the fast path stays
        bit-exact and overhead-free."""
        return bool(getattr(self.inner, "deterministic", False))

    # -- MeasureBackend surface ----------------------------------------------

    def new_machine(self) -> Machine:
        if self._deterministic:
            return self.inner.new_machine()
        return ResilientMachine(self.inner.new_machine(), self.policy,
                                self.health, self._rng,
                                fallback=self._fallback_factory())

    def memo_view(self, program, owner: str = ""):
        if self.health.circuit_open:
            return self._fallback.memo_view(program, owner)
        return self.inner.memo_view(program, owner)

    def _measure_once(self, program, owner: str) -> float:
        if self._deterministic:
            fn = lambda: self.inner.time(program, owner)
        else:
            # ONE persistent machine for every one-shot timing: a fresh
            # machine per attempt would replay the same fault/noise stream
            # from its seed, making retries deterministic re-failures
            if self._machine is None:
                self._machine = self.inner.new_machine()
            fn = lambda: self._machine.time(program)
        return call_with_retries(fn, self.policy, self.health, self._rng)

    def _robust_time(self, program, owner: str = "") -> float:
        """Median-of-k with MAD rejection and adaptive k (policy knobs):
        draw ``samples`` retried measurements, reject the ones further
        than ``mad_threshold`` robust sigmas from the median, and double
        the sample count (up to ``max_samples``) while rejections happen
        or the kept spread stays above ``spread_tolerance``."""
        policy = self.policy
        k = max(1, policy.samples)
        vals: List[float] = []
        while True:
            while len(vals) < k:
                vals.append(self._measure_once(program, owner))
            if len(vals) == 1:
                return vals[0]
            med = statistics.median(vals)
            mad = statistics.median(abs(v - med) for v in vals)
            sigma = _MAD_SIGMA * mad
            kept = [v for v in vals
                    if sigma == 0 or abs(v - med) <= policy.mad_threshold * sigma]
            rejected = len(vals) - len(kept)
            self.health.counters["outliers_rejected"] += rejected
            spread = (statistics.median(abs(v - med) for v in kept) / med
                      if kept and med else 0.0)
            if (rejected or spread > policy.spread_tolerance) \
                    and k < policy.max_samples:
                self.health.counters["sample_escalations"] += 1
                k = min(policy.max_samples, k * 2)
                vals = kept
                continue
            return statistics.median(kept or vals)

    def time(self, program, owner: str = "") -> float:
        if self.health.circuit_open:
            self.health.counters["degraded"] += 1
            return self._fallback.time(program, owner)
        try:
            return self._robust_time(program, owner)
        except (HardFault, MeasureExhausted):
            if self.health.circuit_open:      # this failure tripped it
                self.health.counters["degraded"] += 1
                return self._fallback.time(program, owner)
            raise

    def autotune_time_fn(self, owner: str = "") -> Callable:
        if self._deterministic:
            return self.inner.autotune_time_fn(owner)
        return lambda program: self.time(program, owner)

    def for_target(self, machine_factory: Callable[[], Machine]
                   ) -> "ResilientBackend":
        return ResilientBackend(self.inner.for_target(machine_factory),
                                policy=self.policy,
                                fallback_factory=self._fallback_factory,
                                _family=self._family)

    # -- health reporting ----------------------------------------------------

    def stats(self) -> Dict[str, int]:
        """Aggregated health counters over this backend and every target
        sibling it spawned via ``for_target``."""
        agg = {k: 0 for k in self.health.counters}
        open_breakers = 0
        for h in self._family:
            for k, v in h.counters.items():
                agg[k] += v
            open_breakers += int(h.circuit_open)
        agg["open_breakers"] = open_breakers
        agg["targets"] = len(self._family)
        return agg

    def summary(self) -> str:
        s = self.stats()
        line = (f"{s['measures']} measures, {s['retries']} retries "
                f"({s['transients']} transient, {s['timeouts']} timeout), "
                f"{s['hard_faults']} hard faults, "
                f"{s['outliers_rejected']} outliers rejected")
        if s["open_breakers"]:
            line += (f"; {s['open_breakers']}/{s['targets']} breakers OPEN "
                     f"({s['degraded']} degraded measures)")
        return line


# ---------------------------------------------------------------------------
# the campaign failure ledger
# ---------------------------------------------------------------------------

LEDGER_FORMAT = "repro-campaign-state"
LEDGER_VERSION = 1


def cell_key(kernel: str, scenario=None, target=None) -> str:
    """Stable id of one campaign cell: ``kernel@bucket@target``."""
    from repro.sched.cache import _target_name
    from repro.sched.scenario import bucket_of
    return f"{kernel}@{bucket_of(scenario)}@{_target_name(target)}"


class FailureLedger:
    """Persistent record of a campaign's failed cells
    (``campaign_state.json`` in the campaign's cache dir).

    Each entry carries the captured error, the attempt count across
    passes, and the last backoff applied — which is what makes campaigns
    *resumable*: a later pass consults :meth:`should_attempt` to retry
    exactly the failed cells (healthy ones resolve from the schedule
    cache), and :meth:`record_success` clears a cell once it finally
    lands.  Writes are atomic (tmp + rename) after every update, so a
    killed campaign never loses its ledger.  A corrupt ledger file is
    quarantined (``*.quarantine``) with a warning rather than killing
    the campaign it exists to protect — strict callers pass
    ``strict=True`` to keep the raise."""

    def __init__(self, path: str, strict: bool = False):
        self.path = path
        self._lock = threading.Lock()
        self.cells: Dict[str, Dict] = {}
        if os.path.exists(path):
            try:
                with open(path) as f:
                    payload = json.load(f)
                if payload.get("format") != LEDGER_FORMAT or \
                        payload.get("version") != LEDGER_VERSION:
                    raise ValueError(
                        f"not a {LEDGER_FORMAT} v{LEDGER_VERSION} file")
                self.cells = dict(payload.get("cells", {}))
            except (ValueError, OSError) as e:
                if strict:
                    raise RuntimeError(
                        f"corrupt campaign ledger {path}: {e}") from e
                quarantine = f"{path}.quarantine"
                os.replace(path, quarantine)
                warnings.warn(
                    f"corrupt campaign ledger {path} ({e}); quarantined to "
                    f"{quarantine}, starting an empty ledger")

    def save(self) -> None:
        payload = {"format": LEDGER_FORMAT, "version": LEDGER_VERSION,
                   "cells": self.cells}
        tmp = f"{self.path}.tmp.{os.getpid()}"
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        os.replace(tmp, self.path)

    def attempts(self, cell: str) -> int:
        return int(self.cells.get(cell, {}).get("attempts", 0))

    def should_attempt(self, cell: str,
                       max_retries: Optional[int] = None) -> bool:
        """True while the cell's failure count is within the retry budget
        (``attempts <= max_retries`` — i.e. 1 + max_retries total tries;
        ``None`` = unbounded)."""
        if max_retries is None:
            return True
        return self.attempts(cell) <= max_retries

    def record_failure(self, cell: str, error: BaseException,
                       backoff: float = 0.0) -> Dict:
        with self._lock:
            entry = self.cells.setdefault(cell, {"attempts": 0})
            entry["attempts"] += 1
            entry["error"] = f"{type(error).__name__}: {error}"
            entry["error_type"] = type(error).__name__
            entry["last_backoff"] = backoff
            entry["wall_time"] = time.time()
            self.save()
            return dict(entry)

    def record_success(self, cell: str) -> None:
        with self._lock:
            if cell in self.cells:
                del self.cells[cell]
                self.save()

    def failed_cells(self) -> Dict[str, Dict]:
        return {k: dict(v) for k, v in sorted(self.cells.items())}

    def __len__(self) -> int:
        return len(self.cells)


BACKENDS["resilient"] = ResilientBackend
