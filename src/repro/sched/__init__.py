"""Compiler-integration layer: Pallas kernel -> TSASS -> assembly game
-> cached optimized schedule (the paper's Triton integration, §4)."""

from repro.sched.api import CuAsmRL, KernelDef, TARGET
from repro.sched.autotune import TuneResult, autotune
from repro.sched.baseline import naive_schedule, schedule
from repro.sched.cache import Artifact, load, save
from repro.sched.lowering import LoweredKernel, lower
from repro.sched.spec import KernelSpec, TileIO
from repro.sched.verify import probabilistic_test

__all__ = [
    "CuAsmRL", "KernelDef", "TARGET", "TuneResult", "autotune",
    "naive_schedule", "schedule", "Artifact", "load", "save",
    "LoweredKernel", "lower", "KernelSpec", "TileIO", "probabilistic_test",
]
