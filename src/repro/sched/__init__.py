"""Compiler-integration layer: Pallas kernel -> TSASS -> assembly game
-> cached optimized schedule (the paper's Triton integration, §4).

The public surface is the session API (:mod:`repro.sched.session`):
``OptimizationSession`` over pluggable measurement backends
(:mod:`repro.sched.backends`) and search strategies, with fleet-scale
``optimize_many`` and index-driven ``deploy``.  ``CuAsmRL`` survives as a
deprecated one-kernel shim (:mod:`repro.sched.api`).
"""

from repro.sched.api import CuAsmRL
from repro.sched.autotune import TuneResult, autotune
from repro.sched.backends import (BACKENDS, FastTimingBackend, MeasureBackend,
                                  OracleBackend, PooledBackend,
                                  SharedMeasureMemo, make_backend)
from repro.sched.baseline import naive_schedule, schedule
from repro.sched.cache import (TARGET, Artifact, CacheVersionError,
                               ScheduleCache, load, save)
from repro.sched.lowering import LoweredKernel, lower, resolve_schedule
from repro.sched.resilience import (FailureLedger, ResilientBackend,
                                    RetryPolicy)
from repro.sched.scenario import (DEFAULT_BUCKET, DEFAULT_TARGET, TARGETS,
                                  MachineTarget, Scenario, get_target,
                                  nearest_bucket, register_target,
                                  require_target, unregister_target)
from repro.sched.session import (STRATEGIES, GreedySwapStrategy, KernelDef,
                                 OptimizationSession, OptimizeFailure,
                                 OptimizeRequest, OptimizeResult, PPOStrategy,
                                 RandomSearchStrategy, SearchOutcome,
                                 SearchStrategy, make_budgeted_strategy,
                                 make_strategy)
from repro.sched.spec import KernelSpec, TileIO
from repro.sched.verify import probabilistic_test

__all__ = [
    # session API
    "OptimizationSession", "OptimizeRequest", "OptimizeResult",
    "OptimizeFailure", "SearchStrategy", "SearchOutcome", "PPOStrategy",
    "GreedySwapStrategy", "RandomSearchStrategy", "STRATEGIES",
    "make_strategy", "make_budgeted_strategy",
    # backends + resilience
    "MeasureBackend", "OracleBackend", "FastTimingBackend", "PooledBackend",
    "SharedMeasureMemo", "BACKENDS", "make_backend",
    "ResilientBackend", "RetryPolicy", "FailureLedger",
    # cache
    "Artifact", "ScheduleCache", "CacheVersionError", "load", "save",
    # scenario / target axes
    "Scenario", "MachineTarget", "TARGETS", "DEFAULT_BUCKET",
    "DEFAULT_TARGET", "get_target", "require_target", "register_target",
    "unregister_target", "nearest_bucket", "resolve_schedule",
    # legacy + building blocks
    "CuAsmRL", "KernelDef", "TARGET", "TuneResult", "autotune",
    "naive_schedule", "schedule", "LoweredKernel", "lower", "KernelSpec",
    "TileIO", "probabilistic_test",
]
