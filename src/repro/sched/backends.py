"""Measurement backends for the optimization session (API redesign).

A :class:`MeasureBackend` answers one question — "how many cycles does this
schedule take?" — and carries the knobs the assembly game and the autotuner
need to answer it consistently: a machine factory, whether the timing-only
fast path applies, how many worker threads may prime measurement misses,
and (for the fast backends) the **cross-kernel measurement memo**.

The memo (:class:`SharedMeasureMemo`) is the fleet-scale piece: it maps
``(program fingerprint, position->identity permutation)`` to cycles, where
the fingerprint is interned from the per-instruction *timing records*
(:func:`repro.core.timing.time_record`) — the complete timing semantics of
an instruction identity.  Two kernels whose lowered programs share the same
record sequence (the same kernel appearing under several registry names /
workloads in a fleet, re-optimization of an already-seen schedule) therefore
share every measurement, and ``cross_kernel_hits`` counts reads served by an
entry another kernel wrote.  Timing is bit-exact and deterministic
(``tests/test_timing_fast.py``), so sharing never changes measured values —
only how often they are recomputed.

Backends:

* :class:`OracleBackend` — every measurement through the full dataflow
  oracle ``Machine.run`` (the pre-fast-path behaviour; reference + noisy /
  subclassed machines).
* :class:`FastTimingBackend` — the timing-only path
  (:class:`repro.core.timing.ScheduleTimer` inside the game, memoized
  one-shot timing elsewhere) behind the shared memo.
* :class:`PooledBackend` — FastTiming plus a thread pool over which the
  batched rollout primes distinct measurement misses concurrently.
"""

from __future__ import annotations

import os
import pickle
import threading
from typing import (Callable, Dict, Iterator, NamedTuple, Optional, Protocol,
                    Sequence, runtime_checkable)

import numpy as np

from repro.core.isa import Instruction
from repro.core.machine import Machine
from repro.core.timing import time_program, time_record

# disk format for persisted memos (SharedMeasureMemo.save/load).  Bump the
# version on layout changes; unknown versions and corrupt files fail
# loudly (MemoVersionError) — a half-read memo warm-start would silently
# waste a re-optimization campaign, exactly the failure mode schedule
# cache v2 rules out.
MEMO_FORMAT = "repro-measure-memo"
MEMO_VERSION = 1
_KNOWN_MEMO_VERSIONS = (1,)


class MemoVersionError(RuntimeError):
    """A persisted measurement memo is corrupt or from an unknown format
    version.  Deliberately loud (like ``sched.cache.CacheVersionError``):
    callers wanting best-effort warm-starts catch exactly this."""


# ---------------------------------------------------------------------------
# cross-kernel measurement memo
# ---------------------------------------------------------------------------

class MemoEntry(NamedTuple):
    """One exported measurement: which program (by interned fingerprint and
    its timing-record sequence), which schedule (position -> identity
    permutation), and the measured cycles."""
    fingerprint: int
    records: tuple
    permutation: Optional[np.ndarray]   # None for non-permutation keys
    cycles: float
    writer: str

class _MemoView:
    """Dict-like view of a :class:`SharedMeasureMemo` for one program.

    Keys are the game's permutation bytes (``id_at.tobytes()``); the view
    namespaces them under the program's interned fingerprint, so distinct
    programs can never collide while identical ones (same timing records)
    share entries.  Implements exactly the mapping surface
    :class:`repro.core.env.AssemblyGame` uses for its ``measure_cache``.
    """

    __slots__ = ("_memo", "_fp", "owner")

    def __init__(self, memo: "SharedMeasureMemo", fp: int, owner: str):
        self._memo = memo
        self._fp = fp
        self.owner = owner

    def get(self, key, default=None):
        entry = self._memo._data.get((self._fp, key))
        if entry is None:
            return default
        cycles, writer = entry
        self._memo.hits += 1
        if writer != self.owner:
            self._memo.cross_kernel_hits += 1
        return cycles

    def __contains__(self, key) -> bool:
        return (self._fp, key) in self._memo._data

    def __setitem__(self, key, cycles: float) -> None:
        self._memo._insert((self._fp, key), (cycles, self.owner))


class SharedMeasureMemo:
    """Schedule -> cycles memo shared across kernels, envs and phases.

    Thread-compatible under the GIL: entry reads/writes are single dict
    operations and identical values make write races benign; the counters
    are best-effort under concurrent fleets (exact in the default serial
    ``optimize_many``).

    ``max_entries`` bounds resident memory over long measurement campaigns
    (keys are full permutation byte-strings): when exceeded, the oldest
    eighth of the entries is dropped — eviction only costs re-timing, never
    correctness.
    """

    def __init__(self, max_entries: int = 250_000):
        self._data: Dict[tuple, tuple] = {}
        self._fp_ids: Dict[tuple, int] = {}   # record-tuple -> interned id
        self._lock = threading.Lock()
        self.max_entries = int(max_entries)
        self.hits = 0
        self.misses = 0
        self.cross_kernel_hits = 0
        self.evictions = 0

    def _insert(self, key: tuple, entry: tuple) -> None:
        data = self._data
        if key in data:               # first writer wins; values bit-exact
            return
        data[key] = entry
        self.misses += 1
        if len(data) > self.max_entries:
            # dicts preserve insertion order: drop the oldest ~1/8 batch
            drop = [k for i, k in enumerate(data)
                    if i < max(1, self.max_entries // 8)]
            for k in drop:
                del data[k]
            self.evictions += len(drop)

    def fingerprint(self, program: Sequence[Instruction]) -> int:
        """Interned id of the program's timing-record sequence.  Structural:
        two instruction lists with equal records get the same id."""
        recs = tuple(time_record(ins) for ins in program)
        with self._lock:
            fp = self._fp_ids.get(recs)
            if fp is None:
                fp = len(self._fp_ids)
                self._fp_ids[recs] = fp
            return fp

    def view(self, program: Sequence[Instruction], owner: str = "") -> _MemoView:
        return _MemoView(self, self.fingerprint(program), owner)

    def stats(self) -> Dict[str, int]:
        return {
            "entries": len(self._data),
            "programs": len(self._fp_ids),
            "hits": self.hits,
            "misses": self.misses,
            "cross_kernel_hits": self.cross_kernel_hits,
            "evictions": self.evictions,
        }

    def summary(self) -> str:
        """One-line human-readable stats (shared by the CLI, examples and
        benchmarks so the format lives in exactly one place)."""
        s = self.stats()
        total = max(s["hits"] + s["misses"], 1)
        return (f"{s['entries']} entries, {s['hits']}/{total} hits "
                f"({s['hits'] / total:.1%}), {s['cross_kernel_hits']} "
                f"cross-kernel")

    def __len__(self) -> int:
        return len(self._data)

    def export_entries(self) -> Iterator[MemoEntry]:
        """Iterate every *resident* measurement as a :class:`MemoEntry` —
        the public export hook the cost-model dataset builder consumes, so
        nothing outside this module reaches into ``_data`` / ``_fp_ids``.

        Permutation keys (the game's ``id_at.tobytes()`` and the one-shot
        ``np.arange`` keys) decode back to int64 arrays; any other key
        shape exports with ``permutation=None``.  Eviction caveat: the memo
        bounds resident memory by dropping its oldest entries, so evicted
        measurements are simply **absent** from exports — an export is a
        snapshot of what is currently resident, not a full measurement log.
        """
        with self._lock:
            recs_of = {fp: recs for recs, fp in self._fp_ids.items()}
        for (fp, key), (cycles, writer) in list(self._data.items()):
            recs = recs_of.get(fp)
            if recs is None:
                continue
            perm = None
            if isinstance(key, bytes) and len(key) % 8 == 0:
                perm = np.frombuffer(key, dtype=np.int64).copy()
            yield MemoEntry(fp, recs, perm, cycles, writer)

    # -- persistence (fleet warm-starts across campaigns) -------------------

    def save(self, path: str, merge: bool = True) -> int:
        """Persist every entry to ``path`` (atomic: tmp file + rename).

        The on-disk layout stores the *timing-record sequences* themselves
        — not the process-local interned fingerprint ids, which a fresh
        process would assign differently.

        ``merge=True`` (the default) first folds an existing file at
        ``path`` into the written payload, so concurrent campaign writers
        sharing one ``--memo-dir`` converge on the union of their
        measurements instead of last-writer-wins (values are bit-exact, so
        whose copy of a shared entry survives is immaterial).  The window
        between the read and the atomic rename can still drop entries a
        racing writer lands *inside* it — eviction-grade loss that only
        costs re-timing, never correctness.  Returns the number of entries
        written; raises :class:`MemoVersionError` when the existing file
        is corrupt (overwriting it silently could destroy a healthy
        sibling campaign's work — pass ``merge=False`` to clobber)."""
        by_recs: Dict[tuple, Dict] = {}
        recs_of = {fp: recs for recs, fp in self._fp_ids.items()}
        for (fp, key), (cycles, writer) in self._data.items():
            if fp in recs_of:
                by_recs.setdefault(recs_of[fp], {})[key] = (cycles, writer)
        if merge and os.path.exists(path):
            for prog in _read_memo_payload(path)["programs"]:
                dst = by_recs.setdefault(tuple(prog["records"]), {})
                for key, cycles, writer in prog["entries"]:
                    dst.setdefault(key, (cycles, writer))   # ours win
        payload = {
            "format": MEMO_FORMAT,
            "version": MEMO_VERSION,
            "programs": [
                {"records": recs,
                 "entries": [(k, c, w) for k, (c, w) in entries.items()]}
                for recs, entries in by_recs.items()
            ],
        }
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            pickle.dump(payload, f, protocol=pickle.HIGHEST_PROTOCOL)
        os.replace(tmp, path)
        return sum(len(e) for e in by_recs.values())

    def load(self, path: str) -> int:
        """Merge the memo persisted at ``path`` into this one (existing
        entries win — values are bit-exact anyway, and first-writer-wins is
        the in-memory rule too).  Returns the number of entries merged.
        Raises :class:`MemoVersionError` on corrupt or unknown-version
        files."""
        payload = _read_memo_payload(path)
        merged = 0
        for prog in payload["programs"]:
            recs = tuple(prog["records"])
            with self._lock:
                fp = self._fp_ids.get(recs)
                if fp is None:
                    fp = len(self._fp_ids)
                    self._fp_ids[recs] = fp
            for key, cycles, writer in prog["entries"]:
                k = (fp, key)
                if k not in self._data:
                    self._data[k] = (cycles, writer)
                    merged += 1
        return merged


def warm_start_memo(memo: SharedMeasureMemo, path: str,
                    strict: bool = False) -> int:
    """Best-effort campaign warm-start: merge the memo persisted at
    ``path`` into ``memo``, treating corruption as a recoverable event.

    A corrupt or unknown-version file is renamed to ``path + ".quarantine"``
    with a warning and the campaign starts from an empty memo — losing a
    warm-start only costs re-timing, while dying on it costs the whole
    campaign (the failure mode this module's loud :meth:`SharedMeasureMemo.load`
    is *for* when callers want strictness; ``strict=True`` keeps that
    raise).  Missing files are simply an empty warm-start.  Returns the
    number of entries merged."""
    import warnings
    if not os.path.exists(path):
        return 0
    try:
        return memo.load(path)
    except MemoVersionError as e:
        if strict:
            raise
        quarantine = f"{path}.quarantine"
        os.replace(path, quarantine)
        warnings.warn(
            f"corrupt measurement memo {path} ({e}); quarantined to "
            f"{quarantine}, starting from an empty memo")
        return 0


def _read_memo_payload(path: str) -> dict:
    """Read + validate one persisted memo payload (shared by load and the
    merge-on-save path; every failure mode is a loud MemoVersionError)."""
    try:
        with open(path, "rb") as f:
            payload = pickle.load(f)
    except (OSError, pickle.UnpicklingError, EOFError, AttributeError,
            ImportError, IndexError) as e:
        raise MemoVersionError(
            f"corrupt measurement memo {path}: {e}") from e
    if not isinstance(payload, dict) \
            or payload.get("format") != MEMO_FORMAT:
        raise MemoVersionError(
            f"{path} is not a {MEMO_FORMAT} file")
    if payload.get("version") not in _KNOWN_MEMO_VERSIONS:
        raise MemoVersionError(
            f"measurement memo {path} has version "
            f"{payload.get('version')!r}; this build reads "
            f"{_KNOWN_MEMO_VERSIONS}")
    return payload


# ---------------------------------------------------------------------------
# backend protocol + implementations
# ---------------------------------------------------------------------------

@runtime_checkable
class MeasureBackend(Protocol):
    """What a search strategy / the session needs from a measurement path."""

    name: str
    fast_measure: bool                    # AssemblyGame(use_fast_measure=...)
    measure_workers: Optional[int]        # train_on_program worker pool size

    def new_machine(self) -> Machine:
        """A fresh machine (one per env, the legacy ``machine_factory``)."""
        ...

    def memo_view(self, program: Sequence[Instruction],
                  owner: str = "") -> Optional[_MemoView]:
        """Shared-memo view for ``program`` (``None`` = no sharing)."""
        ...

    def time(self, program: Sequence[Instruction], owner: str = "") -> float:
        """One-shot cycle count of ``program`` (autotune / baselines)."""
        ...

    def autotune_time_fn(self, owner: str = "") -> Callable:
        """A program->cycles callable for one autotune grid sweep."""
        ...

    def for_target(self, machine_factory: Callable[[], Machine]
                   ) -> "MeasureBackend":
        """A sibling backend measuring through ``machine_factory`` —
        how a session re-points one backend at another
        :class:`repro.sched.scenario.MachineTarget` while *sharing* the
        measurement memo (safe: the fingerprint keys the timing records,
        and a target whose machine times differently yields different
        records / falls off the deterministic fast path entirely)."""
        ...


class OracleBackend:
    """Every measurement through the dataflow oracle ``Machine.run`` — the
    reference backend, and the only correct one for noisy machines or
    ``Machine`` subclasses that override ``run``."""

    name = "oracle"
    fast_measure = False
    measure_workers: Optional[int] = None

    def __init__(self, machine_factory: Callable[[], Machine] = Machine):
        self._factory = machine_factory

    def new_machine(self) -> Machine:
        return self._factory()

    def memo_view(self, program, owner: str = "") -> None:
        return None

    def time(self, program, owner: str = "") -> float:
        return self.new_machine().run(program).cycles

    def autotune_time_fn(self, owner: str = "") -> "Callable":
        # one machine across the whole grid, so a noisy machine draws
        # independent noise per config (the legacy autotune contract)
        machine = self.new_machine()
        return lambda program: machine.run(program).cycles

    def for_target(self, machine_factory: Callable[[], Machine]
                   ) -> "OracleBackend":
        return OracleBackend(machine_factory)


class FastTimingBackend:
    """Timing-only measurement behind the shared cross-kernel memo.

    Bit-exact against the oracle for the stock noise-free :class:`Machine`
    (the precondition the game itself checks); for anything else the
    backend degrades to unmemoized ``machine.time`` and the game falls back
    to its oracle path, preserving legacy behaviour exactly.
    """

    name = "fast"
    fast_measure = True
    measure_workers: Optional[int] = None

    def __init__(self, machine_factory: Callable[[], Machine] = Machine,
                 memo: Optional[SharedMeasureMemo] = None):
        self._factory = machine_factory
        self.memo = memo if memo is not None else SharedMeasureMemo()
        self._deterministic: Optional[bool] = None

    def new_machine(self) -> Machine:
        return self._factory()

    @property
    def deterministic(self) -> bool:
        """Memoization is sound iff timing is a pure function of the
        schedule — same check the game uses before enabling its fast path."""
        if self._deterministic is None:
            m = self._factory()
            self._deterministic = (m.noise == 0
                                   and type(m).run is Machine.run)
        return self._deterministic

    def memo_view(self, program, owner: str = "") -> Optional[_MemoView]:
        if not self.deterministic:
            return None
        return self.memo.view(program, owner)

    def time(self, program, owner: str = "") -> float:
        if not self.deterministic:
            return self.new_machine().time(program)
        view = self.memo.view(program, owner)
        key = np.arange(len(program), dtype=np.int64).tobytes()
        cycles = view.get(key)
        if cycles is None:
            cycles = time_program(program)
            view[key] = cycles
        return cycles

    def autotune_time_fn(self, owner: str = "") -> "Callable":
        if self.deterministic:
            return lambda program: self.time(program, owner)
        # noisy / subclassed machine: one machine across the grid so each
        # config draws fresh noise from the same stream, exactly like the
        # legacy ``autotune(..., machine=factory())`` path
        machine = self.new_machine()
        return machine.time

    def for_target(self, machine_factory: Callable[[], Machine]
                   ) -> "FastTimingBackend":
        return FastTimingBackend(machine_factory, memo=self.memo)


class PooledBackend(FastTimingBackend):
    """FastTiming plus a measurement worker pool: the batched rollout fans
    one step's distinct memo misses out over ``workers`` threads (pays off
    for timing paths that release the GIL; see ``train_on_program``)."""

    name = "pooled"

    def __init__(self, machine_factory: Callable[[], Machine] = Machine,
                 memo: Optional[SharedMeasureMemo] = None, workers: int = 4):
        super().__init__(machine_factory, memo)
        self.measure_workers = int(workers)

    def for_target(self, machine_factory: Callable[[], Machine]
                   ) -> "PooledBackend":
        return PooledBackend(machine_factory, memo=self.memo,
                             workers=self.measure_workers)


BACKENDS = {
    "oracle": OracleBackend,
    "fast": FastTimingBackend,
    "pooled": PooledBackend,
}


def make_backend(name: str, **kwargs) -> MeasureBackend:
    """CLI-facing constructor: ``make_backend("pooled", workers=8)``."""
    try:
        cls = BACKENDS[name]
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; one of {sorted(BACKENDS)}")
    return cls(**kwargs)
