"""Lowering: KernelSpec -> TSASS dataflow listing.

This is the "compile + disassemble the cubin" stage of the paper's Fig. 2,
adapted to the Pallas pipeline: the kernel's per-step tile computation is
traced to a jaxpr, instructions are selected against the TSASS ISA
(dot_general -> MXU passes, elementwise/reduce -> VPU lanes, transcendental
-> slow VPU lanes), tile movement becomes grouped DMA (CPYIN/CPYOUT, the
LDGSTS/STG analogues) plus VMEM<->VREG staging (LDV/STV), and address
arithmetic becomes scalar-core instructions feeding the DMA — the
fixed-latency -> memory-instruction dependencies the paper's analysis pass
and Algorithm 1 revolve around.

The output is a *dataflow-ordered* listing with empty control codes; the
baseline list scheduler (:mod:`repro.sched.baseline`, our ptxas -O3 stand-in)
orders it and assigns barriers/stall counts.

Deliberate structural features carried over from real SASS kernels:
  * grouped consecutive DMA per tile (``grp=``) whose relative order is
    pinned (paper §3.5 "additional dependencies");
  * loop-invariant tiles loaded via prologue-defined address registers,
    producing denylist entries (§3.2);
  * predicated-off ``@!PT LDV`` boundary-check slots (§5.7.2, Fig. 13);
  * MXM bursts whose second operand earns a ``.reuse`` flag (§5.7.1, Fig. 9).
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.isa import Control, Instruction
from repro.core.parser import analyze_operands
from repro.sched.spec import KernelSpec, TileIO

DMA_CHUNK = 4096       # bytes per CPYIN/CPYOUT instruction
LDV_CHUNK = 8192       # bytes per LDV/STV staging instruction
VPU_ELEMS = 2048       # elements per VPU instruction
MXU_DIM = 128          # systolic array edge

_ELTWISE_OP = {
    "add": "VADD", "sub": "VSUB", "mul": "VMUL", "div": "VRECIP",
    "max": "VMAX", "min": "VMAX", "exp": "VEXP", "exp2": "VEXP",
    "log": "VEXP", "rsqrt": "VRSQ", "sqrt": "VRSQ", "logistic": "VEXP",
    "tanh": "VEXP", "neg": "VSUB", "integer_pow": "VMUL", "pow": "VMUL",
    "abs": "VMAX", "sign": "VMAX", "select_n": "VADD", "concatenate": "VADD",
    "lt": "VMAX", "gt": "VMAX", "ge": "VMAX", "le": "VMAX", "eq": "VMAX",
    "ne": "VMAX", "and": "VADD", "or": "VADD", "xor": "VADD",
    "clamp": "VMAX", "erf": "VEXP",
}
_VIEW_PRIMS = {
    "broadcast_in_dim", "reshape", "transpose", "squeeze", "expand_dims",
    "convert_element_type", "copy", "stop_gradient", "slice", "rev",
    "dynamic_slice", "bitcast_convert_type", "iota",
}
_REDUCE_OP = {"reduce_sum": "VADD", "reduce_max": "VMAX", "reduce_min": "VMAX",
              "reduce_prod": "VMUL", "cumsum": "VADD", "cumlogsumexp": "VEXP"}
_CALL_PRIMS = {"pjit", "closed_call", "custom_jvp_call", "custom_vjp_call",
               "remat", "checkpoint", "custom_vjp_call_jaxpr"}


class _RegAlloc:
    """Simple rotating allocator: data registers R32..R199 (wrap-around
    introduces occasional false dependencies — as in real, register-pressured
    SASS), address pairs R4..R30, accumulators R200..R250."""

    def __init__(self):
        self._data = 32
        self._addr = 4
        self._acc = 200

    def data(self) -> str:
        r = self._data
        self._data += 1
        if self._data > 198:
            self._data = 32
        return f"R{r}"

    def addr_pair(self) -> str:
        r = self._addr
        self._addr += 2
        if self._addr > 30:
            self._addr = 4
        return f"R{r}"

    def acc(self) -> str:
        r = self._acc
        self._acc += 1
        if self._acc > 250:
            self._acc = 200
        return f"R{r}"


@dataclasses.dataclass
class LoweredKernel:
    spec: KernelSpec
    program: List[Instruction]           # dataflow order, empty control codes

    @property
    def name(self) -> str:
        return self.spec.name


class _Lowerer:
    def __init__(self, spec: KernelSpec):
        self.spec = spec
        self.ra = _RegAlloc()
        self.prog: List[Instruction] = []
        self.group_id = 0
        self.lit_regs: Dict[str, str] = {}
        self.vmem_off = 0

    # -- emission helpers ----------------------------------------------------

    def emit(self, opcode, operands, pred=None, tile=None, group=None,
             comment="") -> Instruction:
        ins = Instruction(opcode, list(operands), Control(), pred, tile,
                          group, comment)
        analyze_operands(ins)
        self.prog.append(ins)
        return ins

    def _vmem_slot(self, nbytes: int) -> int:
        off = self.vmem_off
        self.vmem_off += nbytes
        return off

    # -- DMA ------------------------------------------------------------------

    def dma_in(self, tile: TileIO, step: int, addr_reg: str) -> tuple:
        """Grouped CPYIN of one tile; returns the VMEM tile token.

        VMEM destinations address through the uniform base ``UR2`` +
        immediate (uniform registers are prologue constants, excluded from
        the stall-dependency scan like SASS descriptor URs)."""
        space = f"in_{tile.name}" if not tile.invariant else f"w_{tile.name}"
        token = (space, step if not tile.invariant else 0)
        base = self._vmem_slot(tile.nbytes)
        self.group_id += 1
        g = self.group_id
        nchunks = max(1, math.ceil(tile.nbytes / DMA_CHUNK))
        for cidx in range(nchunks):
            nbytes = min(DMA_CHUNK, tile.nbytes - cidx * DMA_CHUNK)
            self.emit(f"CPYIN.{nbytes}",
                      [f"[UR2+{hex(base + cidx * DMA_CHUNK)}]",
                       f"desc[UR16][{addr_reg}.64]"],
                      tile=token, group=g)
        return token

    def dma_out(self, tile: TileIO, step: int, token: tuple,
                src_reg: str, addr_reg: str) -> None:
        # stage VREG -> VMEM, then grouped CPYOUT
        nstv = max(1, math.ceil(tile.nbytes / LDV_CHUNK))
        base = self._vmem_slot(tile.nbytes)
        for cidx in range(nstv):
            self.emit("STV", [f"[UR2+{hex(base + cidx * LDV_CHUNK)}]", src_reg],
                      tile=token)
        self.group_id += 1
        g = self.group_id
        nchunks = max(1, math.ceil(tile.nbytes / DMA_CHUNK))
        for cidx in range(nchunks):
            nbytes = min(DMA_CHUNK, tile.nbytes - cidx * DMA_CHUNK)
            self.emit(f"CPYOUT.{nbytes}",
                      [f"desc[UR16][{addr_reg}.64+{hex(cidx * DMA_CHUNK)}]",
                       src_reg],
                      tile=token, group=g)

    def stage_in(self, tile: TileIO, token: tuple) -> List[str]:
        """LDV the tile into vector registers; returns the rep registers.
        Also emits the predicated-off boundary-check slots observed in real
        SASS (Fig. 13)."""
        self.emit("LDV", ["RZ", "[RZ]"], pred="@!PT")
        nldv = max(1, math.ceil(tile.nbytes / LDV_CHUNK))
        regs = []
        for cidx in range(min(nldv, 4)):
            r = self.ra.data()
            self.emit("LDV", [r, f"[UR2+{hex(self._ldv_src(token, cidx))}]"],
                      tile=token)
            regs.append(r)
        return regs

    def _ldv_src(self, token, cidx) -> int:
        # address text only needs to be stable per (tile, chunk)
        return (abs(hash(token)) % 0x4000) + cidx * LDV_CHUNK

    # -- compute: jaxpr walk -----------------------------------------------------

    def _literal_reg(self, val) -> str:
        key = repr(val)
        if key not in self.lit_regs:
            r = self.ra.data()
            self.emit("SMOV", [r, key if len(key) < 12 else hex(abs(hash(key)) % 2**24)])
            self.lit_regs[key] = r
        return self.lit_regs[key]

    def trace_compute(self, fn, in_avals: Sequence[jax.ShapeDtypeStruct],
                      in_reps: Sequence[List[str]]) -> List[str]:
        jaxpr = jax.make_jaxpr(fn)(*in_avals)
        env: Dict = {}
        for var, reps in zip(jaxpr.jaxpr.invars, in_reps):
            env[var] = list(reps)
        self._walk(jaxpr.jaxpr, env)
        outs = []
        for var in jaxpr.jaxpr.outvars:
            outs.append(self._read(env, var)[0])
        return outs

    def _read(self, env, var) -> List[str]:
        if isinstance(var, jax.extend.core.Literal):
            return [self._literal_reg(var.val)]
        return env[var]

    def _walk(self, jaxpr, env) -> None:
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            if prim in _CALL_PRIMS:
                inner = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                inner_jaxpr = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                sub_env = {}
                for iv, ov in zip(inner_jaxpr.invars, eqn.invars):
                    sub_env[iv] = self._read(env, ov)
                self._walk(inner_jaxpr, sub_env)
                for ov, iv in zip(eqn.outvars, inner_jaxpr.outvars):
                    env[ov] = self._read(sub_env, iv)
                continue
            if prim == "dot_general":
                env[eqn.outvars[0]] = self._emit_dot(eqn, env)
                continue
            if prim in _VIEW_PRIMS:
                if eqn.invars and not isinstance(eqn.invars[0],
                                                 jax.extend.core.Literal) \
                        and eqn.invars[0] in env:
                    env[eqn.outvars[0]] = env[eqn.invars[0]]
                else:
                    env[eqn.outvars[0]] = [self._literal_reg(prim)]
                continue
            if prim in _REDUCE_OP:
                env[eqn.outvars[0]] = self._emit_reduce(eqn, env,
                                                        _REDUCE_OP[prim])
                continue
            # elementwise / fallback
            opcode = _ELTWISE_OP.get(prim, "VADD")
            env[eqn.outvars[0]] = self._emit_eltwise(eqn, env, opcode)

    def _emit_dot(self, eqn, env) -> List[str]:
        a_aval, b_aval = eqn.invars[0].aval, eqn.invars[1].aval
        ((lc, rc), (lb, rb)) = eqn.params["dimension_numbers"]
        a_shape = [d for i, d in enumerate(a_aval.shape)
                   if i not in set(lc) | set(lb)]
        b_shape = [d for i, d in enumerate(b_aval.shape)
                   if i not in set(rc) | set(rb)]
        k = int(np.prod([a_aval.shape[i] for i in lc])) or 1
        m = int(np.prod(a_shape)) or 1
        n = int(np.prod(b_shape)) or 1
        batch = int(np.prod([a_aval.shape[i] for i in lb])) or 1
        nm = max(1, math.ceil(m / MXU_DIM))
        nn = max(1, math.ceil(n / MXU_DIM))
        nk = max(1, math.ceil(k / MXU_DIM))
        a_reps = self._read(env, eqn.invars[0])
        b_reps = self._read(env, eqn.invars[1])
        accs = [self.ra.acc() for _ in range(min(nm * nn, 8))]
        idx = 0
        for b_i in range(batch):
            for im in range(nm):
                for ik in range(nk):
                    a_r = a_reps[(im * nk + ik) % len(a_reps)]
                    for i_n in range(nn):
                        acc = accs[(im * nn + i_n) % len(accs)]
                        b_r = b_reps[(ik * nn + i_n) % len(b_reps)]
                        # ptxas-style .reuse on the stationary operand of a
                        # burst (same `a` tile across the n sweep)
                        a_op = f"{a_r}.reuse" if i_n > 0 else a_r
                        self.emit("MXM", [acc, a_op, b_r])
                        idx += 1
        return [accs[0]]

    def _emit_eltwise(self, eqn, env, opcode) -> List[str]:
        out_elems = int(np.prod(eqn.outvars[0].aval.shape)) or 1
        n = max(1, math.ceil(out_elems / VPU_ELEMS))
        srcs = []
        for iv in eqn.invars[:3]:
            srcs.append(self._read(env, iv)[0])
        dsts = []
        for i in range(min(n, 16)):
            d = self.ra.data()
            ops = [d] + [srcs[j % len(srcs)] for j in range(min(len(srcs), 2))]
            self.emit(opcode, ops)
            dsts.append(d)
        return [dsts[0]]

    def _emit_reduce(self, eqn, env, opcode) -> List[str]:
        in_elems = int(np.prod(eqn.invars[0].aval.shape)) or 1
        n = max(1, math.ceil(in_elems / VPU_ELEMS))
        src = self._read(env, eqn.invars[0])[0]
        acc = self.ra.data()
        self.emit(opcode, [acc, src, src])
        for _ in range(min(n - 1, 15)):
            self.emit(opcode, [acc, acc, src])
        return [acc]


def lower(spec: KernelSpec) -> LoweredKernel:
    """Materialize the steady-state TSASS listing for one kernel config."""
    lo = _Lowerer(spec)

    # ---- prologue (basic block 0) -------------------------------------------
    lo.emit("SMOV", ["UR16", "0x0"])        # DMA descriptor
    lo.emit("SMOV", ["UR2", "0x0"])         # VMEM base (uniform)
    addr_regs: Dict[str, str] = {}
    for t in spec.inputs + spec.outputs:
        r = lo.ra.addr_pair()
        lo.emit("SMULW", [f"{r}.64", "R0", hex(t.nbytes)])
        addr_regs[t.name] = r

    # invariant tiles (weights/scales): loaded once, addresses never
    # redefined inside the loop body -> their loop uses hit the denylist
    invariant_tokens: Dict[str, tuple] = {}
    for t in spec.inputs:
        if t.invariant:
            invariant_tokens[t.name] = lo.dma_in(t, 0, addr_regs[t.name])

    lo.emit("LABEL", ["L0"])

    # ---- unrolled steady-state loop (one big basic block) --------------------
    avals = [jax.ShapeDtypeStruct(t.shape, jnp.float32) for t in spec.inputs]
    out_reps_last: List[str] = []
    for step in range(spec.steps):
        reps: List[List[str]] = []
        for t in spec.inputs:
            if t.invariant:
                token = invariant_tokens[t.name]
            else:
                # step 0 addresses straight from the prologue-computed
                # parameters (its DMA lands on the denylist: defs cross the
                # label, §3.2); later steps bump in-block — the fixed-latency
                # producer feeding the DMA that Algorithm 1 guards
                if step > 0:
                    r = addr_regs[t.name]
                    hi = f"R{int(r[1:]) + 1}"
                    lo.emit("SADD", [r, r, hex(t.nbytes)])
                    lo.emit("SADDX", [hi, hi, "RZ"])  # carry into the pair's
                    # odd half (the paper's IADD3.X pattern, §3.2)
                token = lo.dma_in(t, step, addr_regs[t.name])
            reps.append(lo.stage_in(t, token))
        out_reps_last = lo.trace_compute(spec.tile_fn, avals, reps)

        store_now = (not spec.accumulate) or step == spec.steps - 1
        if store_now:
            outs = out_reps_last
            if spec.accumulate and spec.epilogue_fn is not None:
                acc_sds = jax.eval_shape(spec.tile_fn, *avals)
                if not isinstance(acc_sds, (tuple, list)):
                    acc_sds = (acc_sds,)
                ep_avals = [jax.ShapeDtypeStruct(s.shape, jnp.float32)
                            for s in acc_sds]
                outs = lo.trace_compute(spec.epilogue_fn, ep_avals,
                                        [[r] for r in out_reps_last])
            for oi, t in enumerate(spec.outputs):
                if step > 0:
                    r = addr_regs[t.name]
                    hi = f"R{int(r[1:]) + 1}"
                    lo.emit("SADD", [r, r, hex(t.nbytes)])
                    lo.emit("SADDX", [hi, hi, "RZ"])
                token = (f"out_{t.name}", step)
                lo.dma_out(t, step, token, outs[min(oi, len(outs) - 1)],
                           addr_regs[t.name])

    lo.emit("EXIT", [])
    return LoweredKernel(spec=spec, program=lo.prog)


# ---------------------------------------------------------------------------
# serve-time dispatch shim
# ---------------------------------------------------------------------------

def resolve_schedule(cache, kernel: str, scenario=None, target=None,
                     on_missing="baseline"):
    """Deploy-time counterpart of :func:`lower`: instead of *building* a
    schedule, resolve the one already tuned for this workload point.

    The request's scenario (shape/dtype/occupancy of the traffic actually
    hitting the engine) dispatches to the **nearest tuned bucket** of the
    kernel's cache index — a pure index lookup, zero autotune and zero
    machine execution, falling back through the default bucket so
    pre-scenario caches keep serving.

    ``on_missing`` is the degradation policy:

    * ``"baseline"`` (default) — a kernel with no usable cached schedule
      degrades gracefully: ``None`` is returned (the engine serves the
      -O3 baseline this module's listing feeds to
      :mod:`repro.sched.baseline`) and the cache's ``fallbacks`` counter
      ticks.  A *corrupt* cached schedule
      (:class:`~repro.sched.cache.CacheVersionError`) is quarantined
      (``*.quarantine``, via :meth:`ScheduleCache.quarantine_kernel`)
      with a warning, the lookup retried once over the cleaned
      directory, and only then falls back to the baseline.
    * ``"raise"`` — strict mode for production rollouts that must not
      silently serve unoptimized kernels: a missing schedule raises
      :class:`FileNotFoundError` and a corrupt one propagates its
      :class:`CacheVersionError` untouched (no quarantine).

    ``cache`` is a :class:`repro.sched.cache.ScheduleCache`; ``scenario``
    a :class:`repro.sched.scenario.Scenario`, a bucket string, or ``None``
    for the legacy single-point lookup.
    """
    if on_missing not in ("baseline", "raise"):
        raise ValueError(
            f"on_missing must be 'baseline' or 'raise', got {on_missing!r}")

    def _lookup():
        if scenario is None:
            return cache.lookup_best(kernel, target=target)
        return cache.dispatch(kernel, scenario, target=target)

    if on_missing == "raise":
        art = _lookup()
        if art is None:
            raise FileNotFoundError(
                f"no cached schedule for {kernel} and on_missing='raise'; "
                f"run optimize() offline first or serve with "
                f"on_missing='baseline'")
        return art

    from repro.sched.cache import CacheVersionError
    try:
        art = _lookup()
    except CacheVersionError as e:
        quarantine = getattr(cache, "quarantine_kernel", None)
        renamed = quarantine(kernel, target) if quarantine else []
        warnings.warn(
            f"corrupt cached schedule for {kernel} ({e}); quarantined "
            f"{len(renamed)} file(s), serving the -O3 baseline unless a "
            f"clean entry remains")
        try:
            art = _lookup()          # retry once over the cleaned directory
        except CacheVersionError:
            art = None
    if art is None and hasattr(cache, "fallbacks"):
        cache.fallbacks += 1
    return art


def schedule_plan(kernel_names, cache_dir=None, target=None, cache=None,
                  scenario=None, on_missing="baseline"):
    """Deploy-time schedule lookup for a serve engine's kernel fleet —
    the fleet-shaped wrapper over :func:`resolve_schedule` (and what
    ``repro.serve.engine.schedule_plan`` re-exports).

    ``kernel_names`` takes bare registry names (legacy: keys are the
    names, resolved at ``scenario`` — the engine's current traffic point,
    or the default bucket when ``None``) and/or the ``(kernel, scenario)``
    pairs :func:`repro.launch.specs.kernel_fleet` yields (keys are
    ``(name, bucket)``, one resolution per workload the model serves).

    Every resolution is a nearest-tuned-bucket pure index lookup — **no**
    autotune and no machine execution at serve time (the paper's §4.2
    search/deploy split).  ``None`` marks a kernel that serves the -O3
    baseline.  ``on_missing`` is :func:`resolve_schedule`'s degradation
    policy: ``"baseline"`` (default) degrades missing/corrupt entries to
    the baseline with a warning + quarantine; ``"raise"`` keeps the loud
    behaviour a production rollout may prefer (missing entries raise
    :class:`FileNotFoundError`, corrupt caches their
    :class:`CacheVersionError`).
    """
    from repro.sched.cache import DEFAULT_CACHE_DIR, TARGET, ScheduleCache
    if cache is None:
        cache = ScheduleCache(cache_dir or DEFAULT_CACHE_DIR,
                              target or TARGET)
    plan = {}
    for item in kernel_names:
        if isinstance(item, str):
            plan[item] = resolve_schedule(cache, item, scenario,
                                          on_missing=on_missing)
        else:
            name, scen = item
            key = (name, scen.bucket if scen is not None else "default")
            plan[key] = resolve_schedule(cache, name, scen,
                                         on_missing=on_missing)
    return plan
