"""Hierarchical search, stage 1 (paper §3.1): autotune kernel configurations
before the RL agent optimizes the schedule of the best one.

"The autotuner employs a grid search-like strategy, which enumerates
user-provided kernel configurations, compiles with the kernel
configurations, measures the execution throughput on the target GPU, and
greedily selects as well as caches the optimal set of kernel
configurations."  Our target is the TSASS machine; the figure of merit is
useful work per cycle (configs move different tile volumes per step, so raw
cycles are not comparable).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.core.machine import Machine
from repro.sched import baseline, lowering
from repro.sched.scenario import Scenario, build_spec
from repro.sched.spec import KernelSpec


@dataclasses.dataclass
class TuneEntry:
    config: Dict
    cycles: float
    work_per_cycle: float
    num_instructions: int


@dataclasses.dataclass
class TuneResult:
    best: TuneEntry
    entries: List[TuneEntry]


def _work_per_step(spec: KernelSpec) -> float:
    if spec.flops_per_step:
        return float(spec.flops_per_step)
    return float(sum(t.nbytes for t in spec.inputs + spec.outputs))


def autotune(make_spec: Callable[[Dict], KernelSpec], configs: List[Dict],
             machine: Optional[Machine] = None,
             time_fn: Optional[Callable] = None,
             scenario: Optional[Scenario] = None) -> TuneResult:
    """``time_fn`` (program -> cycles) overrides the measurement path — the
    session injects its backend here so grid timings land in the shared
    memo; default is the machine's timing-only executor.  ``scenario``
    flows into spec construction (scenario-aware builders materialize the
    scenario's tile stream), so the grid is scored per workload point —
    the same config grid can pick different winners per bucket."""
    if time_fn is None:
        machine = machine or Machine()
        time_fn = machine.time
    entries: List[TuneEntry] = []
    for cfg in configs:
        spec = build_spec(make_spec, cfg, scenario)
        program = baseline.schedule(lowering.lower(spec))
        # grid points only need cycle counts: timing-only path (bit-exact
        # against machine.run(program).cycles), no dataflow simulation
        cycles = time_fn(program)
        work = _work_per_step(spec) * spec.steps
        entries.append(TuneEntry(cfg, cycles, work / max(cycles, 1.0),
                                 len(program)))
    best = max(entries, key=lambda e: e.work_per_cycle)
    return TuneResult(best=best, entries=entries)
