"""Hierarchical search, stage 1 (paper §3.1): autotune kernel configurations
before the RL agent optimizes the schedule of the best one.

"The autotuner employs a grid search-like strategy, which enumerates
user-provided kernel configurations, compiles with the kernel
configurations, measures the execution throughput on the target GPU, and
greedily selects as well as caches the optimal set of kernel
configurations."  Our target is the TSASS machine; the figure of merit is
useful work per cycle (configs move different tile volumes per step, so raw
cycles are not comparable).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.core.machine import Machine
from repro.sched import baseline, lowering
from repro.sched.spec import KernelSpec


@dataclasses.dataclass
class TuneEntry:
    config: Dict
    cycles: float
    work_per_cycle: float
    num_instructions: int


@dataclasses.dataclass
class TuneResult:
    best: TuneEntry
    entries: List[TuneEntry]


def _work_per_step(spec: KernelSpec) -> float:
    if spec.flops_per_step:
        return float(spec.flops_per_step)
    return float(sum(t.nbytes for t in spec.inputs + spec.outputs))


def autotune(make_spec: Callable[[Dict], KernelSpec], configs: List[Dict],
             machine: Optional[Machine] = None) -> TuneResult:
    machine = machine or Machine()
    entries: List[TuneEntry] = []
    for cfg in configs:
        spec = make_spec(cfg)
        program = baseline.schedule(lowering.lower(spec))
        # grid points only need cycle counts: timing-only path (bit-exact
        # against machine.run(program).cycles), no dataflow simulation
        cycles = machine.time(program)
        work = _work_per_step(spec) * spec.steps
        entries.append(TuneEntry(cfg, cycles, work / max(cycles, 1.0),
                                 len(program)))
    best = max(entries, key=lambda e: e.work_per_cycle)
    return TuneResult(best=best, entries=entries)
