"""repro: CuAsmRL (CGO'25) on TPU — RL-optimized instruction schedules as a
compiler service inside a multi-pod JAX training/serving framework."""

from repro import compat as _compat

_compat.install()

__version__ = "1.0.0"
