"""Train-step construction: loss, grads, microbatching, optimizer fusion.

``make_train_step`` returns a pure ``(state, batch) -> (state, metrics)``
suitable for jit/pjit; the dry-run lowers exactly this function for every
architecture's ``train_4k`` cell.
"""

from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist import compress
from repro.dist import sharding as shd
from repro.dist.pipeline import get_schedule
from repro.models import encdec, lm
from repro.optim.adamw import AdamState, Optimizer, apply_updates
from repro.utils.tree import global_norm


class TrainState(NamedTuple):
    params: Dict
    opt_state: object
    step: jnp.ndarray
    # error-feedback residuals for the compressed pod-axis gradient
    # reduction (None outside the multi-pod shard_map step).  Leaves carry
    # a leading pod-block dim: global (pod, *param_shape), sharded P("pod")
    # — the residual is *local* to a pod rank by construction.
    ef: object = None


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL in f32.  logits (B, S, V); labels (B, S) int32."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean()


def loss_fn(params: Dict, batch: Dict, cfg: ModelConfig,
            mesh=None) -> Tuple[jax.Array, Dict]:
    if cfg.family == "encdec":
        logits = encdec.forward(params, batch["frames"], batch["tokens"],
                                cfg, mesh=mesh)
    else:
        logits = lm.forward(params, batch["tokens"], cfg, mesh=mesh)
    loss = cross_entropy(logits, batch["labels"])
    return loss, {"loss": loss}


def make_train_step(cfg: ModelConfig, opt: Optimizer, mesh=None,
                    num_microbatches: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    ``num_microbatches > 1`` accumulates gradients over sequential
    microbatches (lax.scan) — the standard memory/batch-size lever.

    With ``mesh`` given, the step is fully sharded by the dist layer:
    params (and thus grads / optimizer moments) follow the logical-axis
    rules (FSDP over ``data`` × TP over ``model``), the batch follows
    ``batch_spec``, and XLA's SPMD partitioner inserts the collectives."""

    param_sh = batch_of = None
    if mesh is not None:
        model = encdec if cfg.family == "encdec" else lm
        param_sh = shd.param_shardings(model.model_spec(cfg), mesh)

        def batch_of(batch):
            return jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(
                    x, jax.sharding.NamedSharding(
                        mesh, shd.batch_spec(mesh, x.shape[0], ndim=x.ndim))),
                batch)

    grad_fn = jax.value_and_grad(
        functools.partial(loss_fn, cfg=cfg, mesh=mesh), has_aux=True)

    def compute_grads(params, batch):
        if num_microbatches == 1:
            (loss, aux), grads = grad_fn(params, batch)
            return loss, grads

        def split(x):
            return x.reshape((num_microbatches,
                              x.shape[0] // num_microbatches) + x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            loss_acc, grads_acc = carry
            (loss, _), grads = grad_fn(params, mb)
            grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
            return (loss_acc + loss, grads_acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads_sum), _ = jax.lax.scan(body, (jnp.zeros(()), zeros),
                                                micro)
        scale = 1.0 / num_microbatches
        return loss_sum * scale, jax.tree.map(lambda g: g * scale, grads_sum)

    def train_step(state: TrainState, batch: Dict):
        if param_sh is not None:
            state = state._replace(
                params=jax.lax.with_sharding_constraint(state.params,
                                                        param_sh))
            batch = batch_of(batch)
        loss, grads = compute_grads(state.params, batch)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        metrics = {
            "loss": loss,
            "grad_norm": global_norm(grads),
            "step": state.step + 1,
        }
        return TrainState(params, opt_state, state.step + 1), metrics

    return train_step


# ---------------------------------------------------------------------------
# shard_map distributed step: gpipe over `pipe`, compressed psum over `pod`
# ---------------------------------------------------------------------------

class PipelineStepError(ValueError):
    """A config/mesh combination the shard_map pipeline step cannot stage
    (raised eagerly by :func:`make_sharded_train_step`'s validation).
    Callers offering a GSPMD fallback catch exactly this — not bare
    ValueError — so genuine construction bugs still surface."""


def wants_ef(cfg: ModelConfig, mesh) -> bool:
    """True when the sharded step on ``mesh`` will carry error-feedback
    state (compressed pod-axis reduction active)."""
    return (cfg.compress_pod_grads and shd.pipe_size(mesh) > 1
            and shd.axis_sizes(mesh).get("pod", 1) > 1)


def init_ef_state(params, mesh, spec_tree=None):
    """Zero error-feedback residuals for :func:`make_sharded_train_step`:
    one f32 block per ``pod`` rank, stacked on a leading dim.  Each leaf is
    created directly under its shard_map sharding (P("pod") / stage leaves
    P("pod", "pipe")) — materializing (pod, *param_shape) zeros replicated
    on the default device would double the fp32 parameter footprint per
    pod before the step ever runs.

    ``spec_tree`` (the model's ParamSpec tree) is required when ``mesh``
    carries a ``model`` axis > 1: the residuals then mirror the
    tensor-parallel weight shards, which takes the logical axes."""
    pod = shd.axis_sizes(mesh).get("pod", 1)
    ef_specs = shd.sharded_ef_specs(
        spec_tree if spec_tree is not None else params, mesh=mesh)

    def make(p, spec):
        sharding = jax.sharding.NamedSharding(mesh, spec)
        return jax.jit(
            lambda: jnp.zeros((pod,) + p.shape, jnp.float32),
            out_shardings=sharding)()

    return jax.tree.map(make, params, ef_specs)


def make_sharded_train_step(cfg: ModelConfig, opt: Optimizer, mesh, *,
                            num_microbatches: Optional[int] = None,
                            compress_pod: Optional[bool] = None,
                            schedule=None,
                            overlap_pod_reduce: Optional[bool] = None):
    """Explicit-collective train step built on ``jax.shard_map``.

    Per device, the step: embeds the local batch shard, stages the decoder
    blocks through a :class:`repro.dist.pipeline.PipelineSchedule`
    (``schedule`` / ``cfg.pipeline_schedule``: ``"gpipe"`` or ``"1f1b"``)
    microbatched over the ``pipe`` axis (each rank owns ``n_layers / pipe``
    contiguous layers — stage weights never replicate), differentiates the
    pipeline in place (the ring ppermute transposes to the backward ring),
    then reduces gradients: glue params (embed / final norm / head) psum
    over ``pipe``, everything pmean over ``data``, and over the slow
    ``pod`` axis either :func:`repro.dist.compress.compressed_psum` (bf16
    wire format + error feedback, ``compress_pod``) or a plain fp32 pmean.
    With ``overlap_pod_reduce`` (default ``cfg.overlap_pod_reduce``) the
    compressed reduction is issued per gradient group — stage grads first,
    as they finalize during the backward drain — and joined only at the
    optimizer update, so the scheduler can overlap the slow pod wire time
    with the remaining backward work and the next step's fill phase.

    A ``model`` mesh axis > 1 composes tensor parallelism into the stage
    bodies: attention/MLP weights shard per head/column over ``model``
    (:func:`repro.dist.sharding.sharded_param_specs`), the blocks psum
    their partial projections in-stage (``repro.nn`` ``tp_axis`` paths),
    and glue stays replicated.  Supported for the dense family with
    ``d_ff`` / ``n_heads`` / ``n_kv_heads`` divisible by the axis size.

    Remaining constraints (checked eagerly): ``pipe >= 2`` on the mesh;
    family in dense/moe/ssm with a uniform layer stack divisible by
    ``pipe``; ``opt`` from :mod:`repro.optim.adamw` (AdamState-shaped
    state).

    Returns ``train_step(state, batch) -> (state, metrics)`` with the same
    contract as :func:`make_train_step`; ``state.ef`` must be
    :func:`init_ef_state` when the compressed path is active, else None.
    """
    sizes = shd.axis_sizes(mesh)
    n_stages = sizes.get("pipe", 1)
    tp = sizes.get("model", 1)
    if n_stages < 2:
        raise PipelineStepError("make_sharded_train_step needs a mesh 'pipe' axis "
                         f"of size >= 2, got {sizes}")
    if cfg.family not in ("dense", "moe", "ssm"):
        raise PipelineStepError(f"pipeline step: unsupported family {cfg.family}")
    if cfg.family == "moe" and cfg.first_dense_layers:
        raise PipelineStepError("pipeline step: moe configs with leading dense "
                         "layers are not stage-uniform")
    if cfg.n_layers % n_stages:
        raise PipelineStepError(f"n_layers={cfg.n_layers} not divisible by "
                         f"pipe={n_stages}")
    if tp > 1:
        if cfg.family != "dense":
            raise PipelineStepError(
                "tensor-parallel stage composition (model axis > 1) "
                f"supports the dense family only, got {cfg.family}")
        if cfg.mla:
            raise PipelineStepError("pipeline step: MLA attention has no "
                                    "explicit-TP path")
        if cfg.qk_norm:
            raise PipelineStepError(
                "pipeline step: qk_norm scales live inside the TP region "
                "and would need a model-axis grad reduction")
        for val, nm in ((cfg.d_ff, "d_ff"), (cfg.n_heads, "n_heads"),
                       (cfg.n_kv_heads, "n_kv_heads")):
            if val % tp:
                raise PipelineStepError(
                    f"{nm}={val} not divisible by model={tp} (head-/column-"
                    "granular TP sharding)")
    try:
        sched = get_schedule(schedule if schedule is not None
                             else cfg.pipeline_schedule)
    except ValueError as e:
        raise PipelineStepError(str(e)) from None
    tp_axis = "model" if tp > 1 else None
    n_micro = num_microbatches or cfg.pipeline_microbatches
    has_pod = sizes.get("pod", 1) > 1
    if compress_pod is None:
        compress_pod = cfg.compress_pod_grads
    compress_pod = bool(compress_pod and has_pod)
    if overlap_pod_reduce is None:
        overlap_pod_reduce = cfg.overlap_pod_reduce
    dp_total = sizes.get("pod", 1) * sizes.get("data", 1)
    stage_keys = tuple(k for k in shd.STAGE_KEYS)
    layers_per_stage = cfg.n_layers // n_stages
    windows_full = (jnp.asarray(lm.window_schedule(cfg))
                    if cfg.family in ("dense", "moe") else None)

    def local_loss(params, batch):
        tokens = batch["tokens"]
        x = lm.embed_forward(params, tokens, cfg)
        mb = tokens.shape[0] // n_micro
        micro = x.reshape((n_micro, mb) + x.shape[1:])
        if windows_full is not None:
            stage = jax.lax.axis_index("pipe")
            wloc = jax.lax.dynamic_slice_in_dim(
                windows_full, stage * layers_per_stage, layers_per_stage)
        else:
            wloc = None

        def stage_fn(w, h):
            return lm.stage_forward(cfg, w, h, windows=wloc,
                                    tp_axis=tp_axis)

        y = sched.run_local(stage_fn, params["layers"], micro,
                            n_stages=n_stages, axis="pipe",
                            replicate_out=False)
        y = y.reshape((tokens.shape[0],) + y.shape[2:])
        logits = lm.head_forward(params, y, cfg)
        nll = cross_entropy(logits, batch["labels"])
        # only the last pipe rank holds real pipeline outputs; masking the
        # loss there makes the summed-over-ranks scalar equal ONE copy of
        # the shard loss, so backward collectives don't over-count it.
        # Under TP every model rank replicates the final stream, so the
        # loss is additionally owned by model rank 0 alone — same trick,
        # second axis.
        owns = jax.lax.axis_index("pipe") == n_stages - 1
        if tp > 1:
            owns = owns & (jax.lax.axis_index("model") == 0)
        return jnp.where(owns, nll, 0.0)

    # --- in/out specs + per-leaf reduction plan ----------------------------
    p_specs = shd.sharded_param_specs(lm.model_spec(cfg), stage_keys, mesh)
    opt_specs = AdamState(step=P(), mu=p_specs, nu=p_specs)
    ef_specs = (shd.sharded_ef_specs(lm.model_spec(cfg), stage_keys, mesh)
                if compress_pod else None)

    # per-leaf reduction plans, read straight off the specs.  Gradients are
    # *partial* over every pipeline/TP axis the leaf is NOT sharded on
    # (the masked loss is owned by one (pipe, model) rank; each rank's
    # backward carries only its own compute's contribution), so assembly
    # psums over {pipe, model} minus the leaf's sharded axes — for the
    # model=1 mesh this degenerates to the classic glue-psum-over-pipe.
    # The global grad norm is the mirror image: leaves sharded over
    # pipe/model psum their squared sums over exactly those axes.
    def _spec_axes(sp) -> tuple:
        ents = []
        for e in tuple(sp):
            ents.extend(e if isinstance(e, (tuple, list)) else (e,))
        return tuple(a for a in ("pipe", "model") if a in ents)

    def is_spec(x):
        return isinstance(x, P)

    partial_axes = ("pipe", "model") if tp > 1 else ("pipe",)
    flat_specs = [_spec_axes(sp)
                  for sp in jax.tree.leaves(p_specs, is_leaf=is_spec)]
    flat_norm_axes = flat_specs
    flat_psum_axes = [tuple(a for a in partial_axes if a not in sharded)
                      for sharded in flat_specs]

    def assemble_grads(grads):
        flat, tdef = jax.tree.flatten(grads)
        flat = [jax.lax.psum(g, ax) if ax else g
                for g, ax in zip(flat, flat_psum_axes)]
        return jax.tree.unflatten(tdef, flat)

    def global_sq(grads):
        groups: Dict[tuple, list] = {}
        for g, ax in zip(jax.tree.leaves(grads), flat_norm_axes):
            groups.setdefault(ax, []).append(
                jnp.sum(jnp.square(g.astype(jnp.float32))))
        total = jnp.zeros(())
        for ax, parts in groups.items():
            part = jnp.sum(jnp.stack(parts))
            total = total + (jax.lax.psum(part, ax) if ax else part)
        return total

    def device_step(state: TrainState, batch: Dict):
        params = state.params
        loss_part, grads = jax.value_and_grad(local_loss)(params, batch)
        grads = assemble_grads(grads)
        loss = jax.lax.psum(loss_part, partial_axes)
        if "data" in sizes:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, "data"), grads)
            loss = jax.lax.pmean(loss, "data")
        ef = state.ef
        if has_pod:
            loss = jax.lax.pmean(loss, "pod")
            if compress_pod:
                err = jax.tree.map(lambda e: e[0], ef)
                if overlap_pod_reduce:
                    # issue per-group reductions, stage grads first: their
                    # buckets finalize during the backward drain and can
                    # fly while glue backward / metrics still compute —
                    # joined only at the optimizer update below
                    order = ([k for k in grads if k in stage_keys]
                             + [k for k in grads if k not in stage_keys])
                    grads, new_err = compress.compressed_psum_grouped(
                        grads, err, "pod", order)
                else:
                    grads, new_err = compress.compressed_psum(grads, err,
                                                              "pod")
                ef = jax.tree.map(lambda e: e[None], new_err)
            else:
                grads = jax.tree.map(lambda g: jax.lax.pmean(g, "pod"),
                                     grads)
        # true global grad norm from the per-leaf reduction plan
        gnorm = jnp.sqrt(global_sq(grads))
        if opt.max_grad_norm is not None:
            # clip against the GLOBAL norm here; after this scaling every
            # per-rank norm opt.update can see is <= max_grad_norm, so its
            # own (local) clip is a no-op — clipping happens exactly once
            scale = jnp.minimum(1.0, opt.max_grad_norm / (gnorm + 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        updates, opt_state = opt.update(grads, state.opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": state.step + 1}
        return TrainState(params, opt_state, state.step + 1, ef), metrics
    state_specs = TrainState(params=p_specs, opt_state=opt_specs,
                             step=P(), ef=ef_specs)
    metric_specs = {"loss": P(), "grad_norm": P(), "step": P()}
    bspec = P(shd.dp_axes(mesh))

    def train_step(state: TrainState, batch: Dict):
        batch_size = batch["tokens"].shape[0]
        if batch_size % dp_total:
            raise ValueError(f"global batch {batch_size} not divisible by "
                             f"pod*data={dp_total}")
        if (batch_size // dp_total) % n_micro:
            raise ValueError(f"local batch {batch_size // dp_total} not "
                             f"divisible by {n_micro} microbatches")
        if compress_pod and state.ef is None:
            raise ValueError("compressed pod reduction needs state.ef — "
                             "initialize it with init_ef_state(params, mesh)")
        batch_specs = jax.tree.map(lambda _: bspec, batch)
        fn = jax.shard_map(device_step, mesh=mesh,
                           in_specs=(state_specs, batch_specs),
                           out_specs=(state_specs, metric_specs),
                           check_vma=False)
        return fn(state, batch)

    return train_step


def make_eval_step(cfg: ModelConfig, mesh=None):
    def eval_step(params: Dict, batch: Dict):
        loss, _ = loss_fn(params, batch, cfg, mesh)
        return {"loss": loss}
    return eval_step
