"""Train-step construction: loss, grads, microbatching, optimizer fusion.

``make_train_step`` returns a pure ``(state, batch) -> (state, metrics)``
suitable for jit/pjit; the dry-run lowers exactly this function for every
architecture's ``train_4k`` cell.
"""

from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import sharding as shd
from repro.models import encdec, lm
from repro.optim.adamw import Optimizer, apply_updates
from repro.utils.tree import global_norm


class TrainState(NamedTuple):
    params: Dict
    opt_state: object
    step: jnp.ndarray


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL in f32.  logits (B, S, V); labels (B, S) int32."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean()


def loss_fn(params: Dict, batch: Dict, cfg: ModelConfig,
            mesh=None) -> Tuple[jax.Array, Dict]:
    if cfg.family == "encdec":
        logits = encdec.forward(params, batch["frames"], batch["tokens"],
                                cfg, mesh=mesh)
    else:
        logits = lm.forward(params, batch["tokens"], cfg, mesh=mesh)
    loss = cross_entropy(logits, batch["labels"])
    return loss, {"loss": loss}


def make_train_step(cfg: ModelConfig, opt: Optimizer, mesh=None,
                    num_microbatches: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    ``num_microbatches > 1`` accumulates gradients over sequential
    microbatches (lax.scan) — the standard memory/batch-size lever.

    With ``mesh`` given, the step is fully sharded by the dist layer:
    params (and thus grads / optimizer moments) follow the logical-axis
    rules (FSDP over ``data`` × TP over ``model``), the batch follows
    ``batch_spec``, and XLA's SPMD partitioner inserts the collectives."""

    param_sh = batch_of = None
    if mesh is not None:
        model = encdec if cfg.family == "encdec" else lm
        param_sh = shd.param_shardings(model.model_spec(cfg), mesh)

        def batch_of(batch):
            return jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(
                    x, jax.sharding.NamedSharding(
                        mesh, shd.batch_spec(mesh, x.shape[0], ndim=x.ndim))),
                batch)

    grad_fn = jax.value_and_grad(
        functools.partial(loss_fn, cfg=cfg, mesh=mesh), has_aux=True)

    def compute_grads(params, batch):
        if num_microbatches == 1:
            (loss, aux), grads = grad_fn(params, batch)
            return loss, grads

        def split(x):
            return x.reshape((num_microbatches,
                              x.shape[0] // num_microbatches) + x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            loss_acc, grads_acc = carry
            (loss, _), grads = grad_fn(params, mb)
            grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
            return (loss_acc + loss, grads_acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads_sum), _ = jax.lax.scan(body, (jnp.zeros(()), zeros),
                                                micro)
        scale = 1.0 / num_microbatches
        return loss_sum * scale, jax.tree.map(lambda g: g * scale, grads_sum)

    def train_step(state: TrainState, batch: Dict):
        if param_sh is not None:
            state = state._replace(
                params=jax.lax.with_sharding_constraint(state.params,
                                                        param_sh))
            batch = batch_of(batch)
        loss, grads = compute_grads(state.params, batch)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        metrics = {
            "loss": loss,
            "grad_norm": global_norm(grads),
            "step": state.step + 1,
        }
        return TrainState(params, opt_state, state.step + 1), metrics

    return train_step


def make_eval_step(cfg: ModelConfig, mesh=None):
    def eval_step(params: Dict, batch: Dict):
        loss, _ = loss_fn(params, batch, cfg, mesh)
        return {"loss": loss}
    return eval_step
