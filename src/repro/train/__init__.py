from repro.train.loop import InjectedFailure, TrainConfig, Trainer
from repro.train.step import TrainState, cross_entropy, make_train_step

__all__ = ["InjectedFailure", "TrainConfig", "Trainer", "TrainState",
           "cross_entropy", "make_train_step"]
