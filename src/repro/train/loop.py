"""Training loop: data -> jit'd step -> metrics/checkpoints, with the
fault-tolerance contract the brief requires:

  * checkpoint every ``ckpt_every`` steps (async, atomic commit);
  * restart-from-LATEST on construction — a killed job resumes bitwise
    (deterministic data keyed by step + exact state restore);
  * failure injection (``fail_at_step``) for the FT tests;
  * straggler watermarks: per-step wall time ring buffer + a hook that
    fires when a step exceeds ``straggler_factor``× the running median —
    on synchronous SPMD the mitigation is checkpoint + elastic remesh,
    and the elastic path is restore(shardings=new_mesh) (tested).
"""

from __future__ import annotations

import dataclasses
import math
import statistics
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.ckpt import checkpoint
from repro.configs.base import ModelConfig
from repro.data.pipeline import make_data
from repro.dist import sharding as shd
from repro.models import encdec, lm
from repro.optim import adamw as adamw_fn, linear_warmup_cosine
from repro.train.step import (TrainState, init_ef_state,
                              make_sharded_train_step, make_train_step,
                              wants_ef)


class InjectedFailure(RuntimeError):
    pass


def pipeline_microbatch_clamp(n_micro: int, global_batch: int, mesh):
    """``(clamped, per_shard_batch)``: the pipeline microbatch count the
    Trainer will actually stream — the requested count gcd-clamped to
    divide the per-shard batch.  One definition, shared by the Trainer
    (which applies it) and ``launch.train`` (which warns about it)."""
    local_b = max(1, global_batch // max(1, shd.dp_size(mesh)))
    return math.gcd(n_micro, local_b) or 1, local_b


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    lr: float = 3e-4
    warmup: int = 10
    weight_decay: float = 0.1
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    num_microbatches: int = 1
    seed: int = 0
    fail_at_step: Optional[int] = None        # failure injection (tests)
    straggler_factor: float = 3.0
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, mesh=None,
                 straggler_hook: Optional[Callable[[int, float], None]] = None):
        self.cfg, self.tcfg, self.mesh = cfg, tcfg, mesh
        self.data = make_data(cfg, tcfg.seq_len, tcfg.global_batch, tcfg.seed)
        sched = linear_warmup_cosine(tcfg.lr, tcfg.warmup, tcfg.steps)
        self.opt = adamw_fn(sched, weight_decay=tcfg.weight_decay,
                               max_grad_norm=1.0)
        self.straggler_hook = straggler_hook
        self.step_times: List[float] = []
        self.metrics_log: List[Dict] = []
        self._ckpt = checkpoint.AsyncCheckpointer()

        model = encdec if cfg.family == "encdec" else lm
        key = jax.random.PRNGKey(tcfg.seed)
        params = model.init_model(cfg, key)
        state = TrainState(params=params, opt_state=self.opt.init(params),
                           step=jax.numpy.zeros((), jax.numpy.int32))

        # a mesh with a pipe axis >= 2 selects the shard_map pipeline step:
        # gpipe microbatches over `pipe`, compressed psum over `pod` (the
        # config opts in via pipeline_stages / compress_pod_grads — see
        # repro.launch.train, which sizes the mesh from them)
        self.use_pipeline = mesh is not None and shd.pipe_size(mesh) > 1
        if self.use_pipeline and wants_ef(cfg, mesh):
            # error-feedback residuals ride in the train state so they are
            # checkpointed (a restart must not reset accumulated residuals);
            # the spec tree lets them mirror TP weight shards on
            # `model > 1` meshes
            state = state._replace(
                ef=init_ef_state(params, mesh,
                                 spec_tree=model.model_spec(cfg)))

        self.start_step = 0
        if tcfg.ckpt_dir and checkpoint.latest_step(tcfg.ckpt_dir) is not None:
            try:
                state, self.start_step = checkpoint.restore(tcfg.ckpt_dir,
                                                            state)
            except (ValueError, TypeError) as e:
                if state.ef is None:
                    # template has no ef leaves but restore still failed —
                    # most likely a checkpoint from a compressed multi-pod
                    # run resumed under a different compress/mesh config
                    raise RuntimeError(
                        "checkpoint restore failed: if the checkpoint was "
                        "written by a compressed multi-pod run (TrainState"
                        ".ef present), restart with the same "
                        "compress_pod_grads / mesh configuration") from e
                # checkpoint predates the compressed-reduction config (no
                # ef leaves): restore everything else and restart the
                # error-feedback residuals from zero
                bare, self.start_step = checkpoint.restore(
                    tcfg.ckpt_dir, state._replace(ef=None))
                state = bare._replace(ef=state.ef)
                print("[train] checkpoint carries no error-feedback "
                      "residuals; reinitialized ef to zero")
            state = jax.tree.map(jax.numpy.asarray, state)
        self.state = state

        if self.use_pipeline:
            if tcfg.num_microbatches > 1:
                # the pipeline step has no gradient-accumulation scan; its
                # microbatches are the gpipe stream (cfg.pipeline_
                # microbatches), not tcfg.num_microbatches — say so rather
                # than silently changing the effective-batch semantics
                print(f"[train] pipeline step ignores num_microbatches="
                      f"{tcfg.num_microbatches} (no gradient accumulation; "
                      f"gpipe streams cfg.pipeline_microbatches instead)")
            # clamp the pipeline microbatch count to divide the per-shard
            # batch (strictness stays in make_sharded_train_step for
            # direct callers; the Trainer knows the global batch and can
            # pick the nearest workable M)
            n_micro, local_b = pipeline_microbatch_clamp(
                cfg.pipeline_microbatches, tcfg.global_batch, mesh)
            if n_micro != cfg.pipeline_microbatches:
                print(f"[train] pipeline microbatches clamped "
                      f"{cfg.pipeline_microbatches} -> {n_micro} "
                      f"(per-shard batch {local_b})")
            step_fn = make_sharded_train_step(cfg, self.opt, mesh,
                                              num_microbatches=n_micro)
        else:
            step_fn = make_train_step(cfg, self.opt, mesh=mesh,
                                      num_microbatches=tcfg.num_microbatches)
        self.train_step = jax.jit(step_fn, donate_argnums=0)

    def run(self) -> List[Dict]:
        t = self.tcfg
        try:
            return self._run()
        finally:
            # join the in-flight async write even on a crash path: the
            # atomicity contract is that a checkpoint whose save() started
            # is either fully committed or absent — never torn.  Without
            # this, a failure a few (fast) steps after a save races the
            # writer thread and restart loses a committed-looking step.
            if t.ckpt_dir:
                self._ckpt.wait()

    def _run(self) -> List[Dict]:
        t = self.tcfg
        for step in range(self.start_step, t.steps):
            if t.fail_at_step is not None and step == t.fail_at_step:
                raise InjectedFailure(f"injected failure at step {step}")
            batch = self.data.batch_at(step)
            t0 = time.time()
            self.state, metrics = self.train_step(self.state, batch)
            metrics = {k: float(np.asarray(v)) for k, v in metrics.items()}
            dt = time.time() - t0
            self.step_times.append(dt)
            if len(self.step_times) >= 5:
                med = statistics.median(self.step_times[-50:])
                if dt > self.tcfg.straggler_factor * med \
                        and self.straggler_hook is not None:
                    self.straggler_hook(step, dt)
            metrics.update(step=step, seconds=dt)
            self.metrics_log.append(metrics)
            if t.ckpt_dir and (step + 1) % t.ckpt_every == 0:
                self._ckpt.save(t.ckpt_dir, step + 1, self.state)
        if t.ckpt_dir:
            self._ckpt.wait()
            checkpoint.save(t.ckpt_dir, t.steps, self.state)
        return self.metrics_log
