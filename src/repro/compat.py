"""Forward-compatibility shims for JAX API drift.

The codebase (and its tests) are written against the current JAX surface:

  * ``jax.shard_map(..., check_vma=...)`` — promoted out of
    ``jax.experimental.shard_map`` (where the flag is ``check_rep``);
  * ``jax.sharding.AbstractMesh(axis_sizes, axis_names)`` — older releases
    take a single tuple of ``(name, size)`` pairs;
  * ``pltpu.CompilerParams`` — renamed from ``TPUCompilerParams``; bridged
    by :func:`tpu_compiler_params` below (re-exported by
    :mod:`repro.kernels.ops`, whose dispatchers are its main consumers —
    the implementation lives here because this module imports no kernel
    modules, so the per-kernel imports of it can never cycle).

``install()`` back-fills the *new* names onto old installs and is a no-op
wherever the installed JAX already provides them.  It only ever adds
missing attributes / widens accepted signatures — existing behaviour is
never altered, so running under a current JAX is unaffected.

Imported (and applied) from ``repro/__init__.py`` so that any
``import repro`` guarantees the modern surface.  Importing jax here does
not initialize the XLA backend, so XLA_FLAGS set before first device use
(the dry-run contract) still take effect.
"""

from __future__ import annotations

import functools
import inspect

import jax


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    @functools.wraps(_shard_map)
    def shard_map(f, mesh=None, in_specs=None, out_specs=None, *,
                  check_vma=None, check_rep=None, **kwargs):
        if check_vma is not None:
            check_rep = check_vma
        if check_rep is not None:
            kwargs["check_rep"] = check_rep
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kwargs)

    jax.shard_map = shard_map


def _install_abstract_mesh() -> None:
    base = jax.sharding.AbstractMesh
    params = list(inspect.signature(base.__init__).parameters)
    # new-style constructor already takes (axis_sizes, axis_names)
    if "axis_names" in params or "axis_sizes" in params:
        return

    class AbstractMesh(base):
        """Accepts both the legacy ``((name, size), ...)`` pair form and
        the current ``(axis_sizes, axis_names)`` two-tuple form."""

        def __init__(self, *args, **kwargs):
            if (len(args) == 2
                    and all(isinstance(n, int) for n in args[0])
                    and all(isinstance(n, str) for n in args[1])):
                args = (tuple(zip(args[1], args[0])),)
            super().__init__(*args, **kwargs)

    AbstractMesh.__name__ = base.__name__
    AbstractMesh.__qualname__ = base.__qualname__
    jax.sharding.AbstractMesh = AbstractMesh


def tpu_compiler_params(**kwargs):
    """Build Pallas TPU compiler params under either API name: current JAX
    exposes ``pltpu.CompilerParams``, older releases the same class as
    ``pltpu.TPUCompilerParams``."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def install() -> None:
    _install_shard_map()
    _install_abstract_mesh()
