"""Render EXPERIMENTS.md tables from results/*.json (re-runnable)."""

import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(name):
    path = os.path.join(ROOT, "results", name)
    with open(path) as f:
        return json.load(f)


def rl_table() -> str:
    s = load("agents_summary.json")
    lines = ["| kernel | -O3 baseline (cycles) | vanilla (paper-faithful) | "
             "+warm-start | +warm+macro-moves | best speedup |",
             "|---|---|---|---|---|---|"]
    geo = {"vanilla": 1.0, "warm_start": 1.0, "warm_macro": 1.0}
    n = 0
    for k, e in s.items():
        cells = []
        best = 1.0
        for mode in ("vanilla", "warm_start", "warm_macro"):
            m = e.get(mode)
            if m is None:
                cells.append("—")
                continue
            cells.append(f"{m['optimized_cycles']:.0f} "
                         f"({m['improvement']:+.2%})")
            geo[mode] *= m["speedup"]
            best = max(best, m["speedup"])
        lines.append(f"| {k} | {e['vanilla']['baseline_cycles']:.0f} | "
                     + " | ".join(cells) + f" | {best:.4f}× |")
        n += 1
    lines.append(f"| **geomean** | | {geo['vanilla'] ** (1/n):.4f}× "
                 f"| {geo['warm_start'] ** (1/n):.4f}× "
                 f"| {geo['warm_macro'] ** (1/n):.4f}× | |")
    lines.append("")
    lines.append(
        "Interpretation (recorded per the hypothesis protocol): the RL agent "
        "reliably harvests the *local* slack the pressure-bounded vendor "
        "scheduler leaves (fused_ff +5.4% — the paper's own best kernel "
        "class; small-but-verified wins elsewhere), and the two beyond-paper "
        "variants confirm the remaining corridor to the unbounded global "
        "scheduler (9–53%) is plateau-separated: it requires coordinated "
        "restructuring of hundreds of instructions, not reachable by "
        "single-instruction moves in 128-step episodes.  This is the same "
        "shape as the paper's spread (2–26%: most kernels small, a few "
        "large), with the added diagnosis of *why* the ceiling sits where "
        "it does.")
    return "\n".join(lines)


def dryrun_table() -> str:
    cells = load("dryrun.json")
    lines = ["| arch | shape | mesh | status | compile (s) | peak mem/dev "
             "(GB)* | HLO FLOPs (global) | collective B (global) |",
             "|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c["status"] == "ok":
            r = c["roofline"]
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | ok "
                f"| {c['compile_s']} | {c['memory']['peak_bytes'] / 1e9:.1f} "
                f"| {r['flops_global']:.2e} | {r['coll_bytes_global']:.2e} |")
        else:
            reason = c.get("reason", c.get("error", ""))[:60]
            lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} "
                         f"| {c['status']} ({reason}) | | | | |")
    lines.append("")
    lines.append("\\* `memory_analysis()` of the CPU-backend partitioned "
                 "module, recorded verbatim.  Caveat (verified empirically): "
                 "the CPU backend does not credit scan/microbatch buffer "
                 "reuse — temp bytes are identical at 1 and 8 microbatches — "
                 "so train-cell peaks overstate the TPU footprint; "
                 "per-device *state* (args column in the JSON: params + "
                 "optimizer + caches) is exact and fits comfortably in "
                 "every cell.")
    return "\n".join(lines)


def roofline_table() -> str:
    cells = [c for c in load("dryrun.json")
             if c["mesh"] == "single"]
    lines = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
             "dominant | MODEL/HLO flops | one-line: what moves the "
             "dominant term |",
             "|---|---|---|---|---|---|---|---|"]
    hints = {
        ("whisper-large-v3", "train_4k"): "flash-fused attention (§Perf A shows the prefill variant)",
        ("whisper-large-v3", "prefill_32k"): "§Perf cell A: deploy the Pallas flash kernel (memory 2.62→0.024 s)",
        ("whisper-large-v3", "decode_32k"): "KV reads dominate: batch the decode wider",
        ("deepseek-v2-lite-16b", "train_4k"): "flash fusion + EP capacity tuning",
        ("deepseek-v2-lite-16b", "prefill_32k"): "flash fusion on the MLA path",
        ("deepseek-v2-lite-16b", "decode_32k"): "§Perf cell B (fixed): remaining term = expert-weight residency",
        ("olmoe-1b-7b", "train_4k"): "flash fusion; a2a already minor",
        ("olmoe-1b-7b", "prefill_32k"): "flash fusion",
        ("olmoe-1b-7b", "decode_32k"): "expert-weight residency: larger decode batch amortizes",
        ("stablelm-3b", "train_4k"): "§Perf cell C: flash fusion flips it compute-bound",
        ("stablelm-3b", "prefill_32k"): "flash fusion",
        ("stablelm-3b", "decode_32k"): "KV + weight reads: wider batch",
        ("qwen1.5-4b", "train_4k"): "near-balanced; remat policy (see C2/C3 tradeoff)",
        ("qwen1.5-4b", "prefill_32k"): "flash fusion",
        ("qwen1.5-4b", "decode_32k"): "KV + weight reads",
        ("stablelm-12b", "train_4k"): "compute-bound at 70% useful: dots-saveable remat (C2) if memory allows",
        ("stablelm-12b", "prefill_32k"): "flash fusion",
        ("stablelm-12b", "decode_32k"): "KV + weight reads",
        ("gemma3-1b", "train_4k"): "compute-bound; window layers already cheap",
        ("gemma3-1b", "prefill_32k"): "flash fusion (local layers are window-bounded)",
        ("gemma3-1b", "decode_32k"): "tiny model: collectives are latency-bound — fuse/coalesce per-layer psums",
        ("gemma3-1b", "long_500k"): "global-layer cache reads; seq-sharded over data+model already",
        ("mamba2-1.3b", "train_4k"): "SSD chunk kernel (Pallas) fuses the state chunk loop",
        ("mamba2-1.3b", "prefill_32k"): "SSD chunk kernel",
        ("mamba2-1.3b", "decode_32k"): "O(1) state: already near floor; batch wider",
        ("mamba2-1.3b", "long_500k"): "state resident: term is µs-scale already",
        ("chameleon-34b", "train_4k"): "compute-bound at 73% useful: largest model, TP collectives next",
        ("chameleon-34b", "prefill_32k"): "flash fusion",
        ("chameleon-34b", "decode_32k"): "weight reads at bs=128: wider batch / int8 weights",
        ("zamba2-2.7b", "train_4k"): "SSD kernel + flash on the shared block",
        ("zamba2-2.7b", "prefill_32k"): "SSD kernel",
        ("zamba2-2.7b", "decode_32k"): "SSM state + shared-block cache reads",
        ("zamba2-2.7b", "long_500k"): "shared-block cache reads (9 blocks × 500k)",
    }
    for c in cells:
        if c["status"] == "skip":
            lines.append(f"| {c['arch']} | {c['shape']} | — | — | — | skip "
                         f"| — | {c['reason'][:70]} |")
            continue
        if c["status"] != "ok":
            continue
        r = c["roofline"]
        hint = hints.get((c["arch"], c["shape"]), "")
        lines.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']:.3g} "
            f"| {r['memory_s']:.3g} | {r['collective_s']:.3g} "
            f"| **{r['dominant']}** | {(r['useful_ratio'] or 0):.2f} "
            f"| {hint} |")
    return "\n".join(lines)


def perf_section() -> str:
    hc = load("hillclimb_AC.json")
    c2 = hc["C2"]["roofline"]
    return f"""
**Cell selection from the baseline table:** A = whisper-large-v3 ×
prefill_32k (worst roofline fraction: memory term 6.5× the compute term);
B = deepseek-v2-lite-16b × decode_32k (most collective-bound cell in the
sweep: collective term 3978× compute); C = stablelm-3b × train_4k (the
arch whose hot ops are exactly the paper's kernel set — most representative
of the technique).

### Cell A — whisper-large-v3 / prefill_32k (dominant: memory)

| iter | hypothesis | change | compute (s) | memory (s) | collective (s) | verdict |
|---|---|---|---|---|---|---|
| A0 | — | baseline | 0.403 | **2.62** | 0.049 | memory-bound 6.5× |
| A1 | the memory term is attention-score materialization (B·H·S·chunk f32 per chunk per layer, fwd); the Pallas flash kernel keeps scores + q-tile accumulators in VMEM | deploy the flash kernel for every online-softmax chunk loop (kernel-aware cost accounting, `jcost(fused_attn=True)`) | **0.403** | 0.024 | 0.049 | **confirmed: memory 2.62 → 0.024 s (108×); cell flips compute-bound** |

Post-A1 the step bound drops 2.62 → 0.403 s (6.5× projected).  The
remaining compute is dominated by the encoder's non-causal 32k² attention
FLOPs (MODEL/HLO = 0.16 — attention math is not in 2·N·D), which is
inherent to the shape, not waste.  Stopping: next-best ideas (bigger
chunks, bf16 accum) napkin at <5% of the dominant term.

### Cell B — deepseek-v2-lite-16b / decode_32k (dominant: collective)

| iter | hypothesis | change | compute (s) | memory (s) | collective (s) | verdict |
|---|---|---|---|---|---|---|
| B0 | — | baseline | 8.4e-05 | 0.0039 | **0.335** | collective-bound 3978× |
| B1 | HLO shows 135 all-gathers of `f32[8,32768,512]` = the MLA latent cache, all-gathered (in f32!) twice per layer because the cache was sharded on its *contraction* dim (R) | shard the MLA cache on **sequence** instead (specs.py `_cache_shardings`; the softmax partial-stats combine is bytes-trivial) | 8.4e-05 | **0.0039** | 0.00034 | **confirmed: collective 0.335 → 0.00034 s (987×); cell flips memory-bound** |
| B2 | remaining memory term ≈ expert-weight residency: the replicated-EP decode touches all 64 experts' weights (1.8 GB/device) every step — at 128 tokens × top-6 nearly every expert is hit, so the reads are irreducible at this batch | napkin analysis (no change): 1.8 GB / 819 GB/s = 2.2 ms ≈ the measured 3.9 ms within 2× | — | — | — | floor reached; batching wider amortizes — stop |

Step bound 0.335 → 0.0039 s (**86×**).  This was a real sharding bug class
(contraction-dim cache sharding) that the roofline loop caught; the fix is
now the default rule and the §Dry-run table contains the re-run cells.

### Cell C — stablelm-3b / train_4k (dominant: memory)

| iter | hypothesis | change | compute (s) | memory (s) | useful | verdict |
|---|---|---|---|---|---|---|
| C0 | — | baseline | 0.528 | **0.774** | 0.63 | memory-bound |
| C1 | same attention-score materialization as cell A, fwd+bwd+remat | flash-kernel deployment accounting | **0.528** | 0.425 | 0.63 | **confirmed: memory 0.774 → 0.425 s; flips compute-bound** |
| C2 | 37% of compute is remat recompute (useful 0.63); saving dot outputs eliminates it | `remat_policy="dots"` | 0.418 | 0.409 | **0.80** | compute confirmed ({c2['compute_s']:.3f} s, useful 0.80) — but **feasibility refuted**: the policy saves the attention-score dots too → 137 GB/device of saved activations.  A refuted hypothesis is data: the production form is a flash custom-VJP (scores recomputed in-kernel) + dots saved elsewhere |
| C3 | microbatching restores feasibility | `train_microbatches=8` | 0.418 | 0.409 | 0.80 | peak unchanged in `memory_analysis()` — found a *tooling* limit: the CPU backend does not credit scan buffer reuse (verified mb1 vs mb8 identical).  Analytically: per-microbatch live activations ≈ 1.3 GB/device with nothing_saveable + mb8 → fits |

Final deployed config for cell C: flash kernels + nothing_saveable remat +
8 microbatches — step bound 0.774 → **0.528 s** (1.47×), i.e. 63% of the
6·N·D ideal (0.333 s at 197 TF/chip); the remaining gap is remat recompute
(deliberately kept: the dots-saveable alternative needs a flash custom-VJP
to be memory-feasible, recorded as the next engineering step).

### Stopping criteria

Each cell stopped after the dominant term's best remaining idea napkin'd
below 5% (A: chunk-size/accum-dtype tweaks; B: weight-residency floor;
C: flash-bwd custom-VJP is the identified next step but is out of scope for
cost accounting — it would not change the *reported* terms further).
"""


def main() -> None:
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    text = text.replace("<!-- RL_RESULTS_TABLE -->", rl_table())
    text = text.replace("<!-- DRYRUN_TABLE -->", dryrun_table())
    text = text.replace("<!-- ROOFLINE_TABLE -->", roofline_table())
    text = text.replace("<!-- PERF_SECTION -->", perf_section())
    with open(path, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md rendered")


if __name__ == "__main__":
    main()
