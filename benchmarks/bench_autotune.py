"""Paper §3.1: the hierarchical search's first stage — autotuner entries
(work/cycle per candidate config) for each kernel, like Triton's autotuner
table that precedes SASS optimization."""

from repro.kernels import KERNELS
from repro.sched import autotune
from benchmarks.common import emit


def run():
    rows = []
    for name, kdef in KERNELS.items():
        res = autotune(kdef.make_spec, kdef.configs)
        for e in res.entries:
            rows.append(("autotune", name, str(e.config).replace(",", ";"),
                         round(e.cycles, 0), round(e.work_per_cycle, 1),
                         "best" if e is res.best else ""))
    emit(rows, header=("bench", "kernel", "config", "cycles",
                       "work_per_cycle", "selected"))
    return rows
