"""Paper Table 1: fixed-latency stall counts by dependency-based
microbenchmarking, plus the §4.3 clock-based-underestimate demonstration."""

from repro.core import build_stall_table, clock_based_estimate
from benchmarks.common import emit


def run():
    table = build_stall_table()
    rows = []
    for op, stall in sorted(table.items()):
        clock = clock_based_estimate(op)
        rows.append(("table1", op, stall, round(clock, 2),
                     "underestimates" if clock < stall else "matches"))
    emit(rows, header=("bench", "instruction", "dependency_based_stall",
                       "clock_based_estimate", "note"))
    return rows
