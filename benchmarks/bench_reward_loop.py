"""Reward-loop throughput on the §5.7 kernels: env-steps/sec through the
fast measurement path (timing-only executor + checkpointed incremental
re-timing + schedule memo) vs. the full dataflow oracle, raw
measure-calls/sec for both executors, and the memo hit rate under a
training-shaped access pattern (episode resets re-measure the start
schedule).  Tracked in CI from the PR that introduced the fast path."""

import time

import numpy as np

from repro.core import Machine, build_stall_table
from repro.core.env import AssemblyGame
from repro.kernels import KERNELS
from repro.sched import lower, schedule
from benchmarks.common import emit


def _env_steps_per_sec(prog, db, fast, budget_steps, seed=0):
    """Training-shaped stepping: observation written into preallocated
    buffers (the vectorized rollout path), random valid actions, resets on
    episode end — everything identical between the two measurement paths."""
    env = AssemblyGame(prog, stall_db=db, episode_length=32,
                       use_fast_measure=fast)
    state_buf = np.zeros((env.n, env.feature_dim), np.float32)
    mask_buf = np.zeros(env.num_actions, np.float32)
    rng = np.random.default_rng(seed)
    env.reset()
    n = 0
    t0 = time.perf_counter()
    while n < budget_steps:
        env.write_obs(state_buf, mask_buf)
        va = np.flatnonzero(mask_buf)
        if va.size == 0:
            env.reset()
            continue
        env.begin_step(int(rng.choice(va)))
        if fast:
            env.prime_measure()
        _, _, done, _ = env.finish_step(want_obs=False)
        n += 1
        if done:
            env.reset()
    dt = time.perf_counter() - t0
    return n / dt, env


def _calls_per_sec(fn, min_seconds=0.4):
    k = 0
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < min_seconds:
        fn()
        k += 1
    return k / (time.perf_counter() - t0)


def run(budget_steps: int = 300):
    db = build_stall_table()
    rows = []
    for name in ("matmul_leakyrelu", "bmm"):       # the two kernels of §5.7
        kdef = KERNELS[name]
        prog = schedule(lower(kdef.make_spec(kdef.configs[0])))
        m = Machine()
        run_cps = _calls_per_sec(lambda: m.run(prog))
        time_cps = _calls_per_sec(lambda: m.time(prog))
        oracle_sps, _ = _env_steps_per_sec(prog, db, False,
                                           max(60, budget_steps // 4))
        fast_sps, env = _env_steps_per_sec(prog, db, True, budget_steps)
        hit_rate = env.memo_hits / max(env.measure_calls, 1)
        speedup = fast_sps / oracle_sps
        rows.append(("reward_loop", name, len(prog),
                     round(run_cps, 1), round(time_cps, 1),
                     round(oracle_sps, 1), round(fast_sps, 1),
                     round(speedup, 2), round(hit_rate, 3)))
        print(f"# {name}: {len(prog)} ins | run {run_cps:.0f}/s vs "
              f"time {time_cps:.0f}/s | env-steps/s {oracle_sps:.0f} -> "
              f"{fast_sps:.0f} ({speedup:.1f}x, memo hit {hit_rate:.1%})")
    emit(rows, header=("bench", "kernel", "n_ins", "run_calls_per_s",
                       "time_calls_per_s", "env_steps_per_s_oracle",
                       "env_steps_per_s_fast", "speedup", "memo_hit_rate"))
    return rows
