"""Resilience-layer benchmark: the same small campaign measured through
fault channels of increasing hostility (transient rates 0%, 5%, 20%),
always behind :class:`repro.sched.resilience.ResilientBackend`.

Reports, per fault rate: campaign success rate, retries spent, transient
faults absorbed, degraded cells, wall time, and the overhead vs the
fault-free run — plus a correctness row asserting every surviving cell's
optimized cycle count is bit-exact against the fault-free campaign (the
whole point of retry + robust timing: faults cost wall time, never
results).  In the CI ``--fast`` smoke set, so BENCH_ci.json tracks the
fault-absorption trajectory."""

import tempfile
import time

from repro.core import FaultSpec, FaultyMachine, build_stall_table
from repro.sched import (FastTimingBackend, OptimizationSession,
                         ResilientBackend, RetryPolicy,
                         make_budgeted_strategy)
from repro.launch.optimize import campaign_requests, parse_scenarios
from benchmarks.common import emit

FLEET = ("rmsnorm", "softmax")
SCENARIOS = "4x512,8x4096"
FAULT_RATES = (0.0, 0.05, 0.20)


def _campaign(rate: float, timesteps: int):
    db = build_stall_table()
    if rate > 0:
        spec = FaultSpec(seed=11, transient_rate=rate)
        inner = FastTimingBackend(lambda: FaultyMachine(spec))
    else:
        inner = FastTimingBackend()
    backend = ResilientBackend(inner, policy=RetryPolicy(max_retries=8))
    session = OptimizationSession(
        backend=backend, stall_db=db,
        cache_dir=tempfile.mkdtemp(prefix="bench_resilience_"),
        strategy=make_budgeted_strategy("random", timesteps=timesteps,
                                        episode_length=8))
    units = [(k, s) for k in FLEET for s in parse_scenarios(SCENARIOS)]
    reqs = campaign_requests(units)
    t0 = time.perf_counter()
    results = session.optimize_many(reqs, on_error="collect")
    wall = time.perf_counter() - t0
    return results, backend.stats(), wall


def run(timesteps: int = 32):
    rows = []
    baseline_cycles = {}
    baseline_wall = None
    for rate in FAULT_RATES:
        results, stats, wall = _campaign(rate, timesteps)
        ok = [r for r in results if r.ok]
        cycles = {(r.kernel, r.scenario): r.artifact.optimized_cycles
                  for r in ok}
        if rate == 0.0:
            baseline_cycles, baseline_wall = cycles, wall
            exact = len(cycles)
        else:
            exact = sum(1 for k, v in cycles.items()
                        if baseline_cycles.get(k) == v)
        rows.append((f"resilience_rate{int(rate * 100)}_success",
                     f"{len(ok)}/{len(results)}",
                     f"{stats['retries']} retries "
                     f"{stats['transients']} transients "
                     f"{stats['degraded']} degraded"))
        rows.append((f"resilience_rate{int(rate * 100)}_bitexact_cells",
                     f"{exact}/{len(baseline_cycles)}",
                     "optimized cycles vs fault-free campaign"))
        rows.append((f"resilience_rate{int(rate * 100)}_wall_s",
                     f"{wall:.2f}",
                     f"{wall / baseline_wall:.2f}x of fault-free"))
        assert len(ok) == len(results), \
            f"cells failed at transient rate {rate} despite retries"
        if rate > 0.0:
            assert exact == len(baseline_cycles), \
                f"fault rate {rate} changed campaign results"
    return emit(rows, header=("name", "value", "derived"))


if __name__ == "__main__":
    run()
