"""Paper Fig. 7: fraction of stall-count dependencies resolved by the
microbenchmarked table (db), inferred by the analysis pass, or denylisted."""

from repro.core import analyze, build_stall_table
from repro.kernels import KERNELS
from repro.sched import lower, schedule
from benchmarks.common import emit


def run():
    db = build_stall_table()
    rows = []
    tot = {"db": 0.0, "infer": 0.0, "denylist": 0.0}
    for name, kdef in KERNELS.items():
        prog = schedule(lower(kdef.make_spec(kdef.configs[0])))
        fr = analyze(prog, db).resolution_fractions()
        rows.append(("fig7", name, round(fr["db"], 3), round(fr["infer"], 3),
                     round(fr["denylist"], 3)))
        for k in tot:
            tot[k] += fr[k]
    n = len(KERNELS)
    rows.append(("fig7", "average", round(tot["db"] / n, 3),
                 round(tot["infer"] / n, 3), round(tot["denylist"] / n, 3)))
    emit(rows, header=("bench", "kernel", "db", "infer", "denylist"))
    return rows
