"""Paper Fig. 6: normalized kernel throughput.  The -O3 list schedule is the
Triton-baseline analogue (normalized to 1.0); 'naive' is the unscheduled
dataflow order; CuAsmRL is the RL-optimized schedule from the artifact
cache (results/agents_summary.json, produced by the offline search)."""

from repro.core import Machine
from repro.kernels import KERNELS
from repro.sched import lower, naive_schedule, schedule
from benchmarks.common import emit, load_agents_summary


def run():
    summary = load_agents_summary()
    m = Machine()
    rows = []
    geo = 1.0
    n = 0
    for name, kdef in KERNELS.items():
        cfg = (summary.get(name, {}).get("config")
               or kdef.configs[0])
        lk = lower(kdef.make_spec(cfg))
        o3 = m.run(schedule(lk)).cycles
        nv = m.run(naive_schedule(lk)).cycles
        if name in summary:
            opt = summary[name]["optimized_cycles"]
        else:
            opt = o3  # agents not trained yet: report baseline
        rows.append(("fig6", name, round(o3 / nv, 3), 1.0,
                     round(o3 / opt, 4), round(o3, 0), round(opt, 0)))
        geo *= o3 / opt
        n += 1
    rows.append(("fig6", "geomean", "", 1.0, round(geo ** (1 / max(n, 1)), 4),
                 "", ""))
    emit(rows, header=("bench", "kernel", "naive_norm", "baseline_norm",
                       "cuasmrl_norm", "baseline_cycles", "cuasmrl_cycles"))
    return rows
