"""Fleet benchmark for the session API: N kernels through
``OptimizationSession.optimize_many`` — isolated per-kernel sessions (the
legacy one-kernel-at-a-time shape, nothing shared) vs one session sharing
the stall table and the cross-kernel measurement memo.  Reports wall time
for both, the shared memo's hit rate and its cross-kernel hit count, and
asserts the measured cycles are identical (sharing is bit-exact).  In the
CI ``--fast`` smoke set, so BENCH_ci.json tracks the fleet trajectory."""

import tempfile
import time

from repro.core import build_stall_table
from repro.core.ppo import PPOConfig
from repro.kernels import (KERNELS, KernelDef, register_kernel,
                           unregister_kernel)
from repro.sched import OptimizationSession, OptimizeRequest
from benchmarks.common import emit

# rmsnorm appears twice under different workload names — the fleet-dedup
# scenario (the same kernel serving several models) the memo exists for
ALIAS = "rmsnorm_fleet_alias"
FLEET = ("rmsnorm", "softmax", ALIAS)


def run(timesteps: int = 256):
    db = build_stall_table()
    base = KERNELS["rmsnorm"]
    register_kernel(KernelDef(ALIAS, base.make_spec, base.configs))
    ppo = PPOConfig(total_timesteps=timesteps, num_envs=4, num_steps=16,
                    episode_length=12, seed=0)
    try:
        reqs = [OptimizeRequest(kernel=n, ppo=ppo, force=True)
                for n in FLEET]

        t0 = time.perf_counter()
        isolated = []
        for req in reqs:
            s = OptimizationSession(
                stall_db=db, cache_dir=tempfile.mkdtemp(prefix="bench_iso_"))
            isolated.append(s.optimize(req))
        t_isolated = time.perf_counter() - t0

        shared = OptimizationSession(
            stall_db=db, cache_dir=tempfile.mkdtemp(prefix="bench_shr_"))
        t0 = time.perf_counter()
        fleet = shared.optimize_many(reqs)
        t_shared = time.perf_counter() - t0

        for a, b in zip(isolated, fleet):   # sharing never changes cycles
            assert a.artifact.optimized_cycles == b.artifact.optimized_cycles, \
                (a.kernel, a.artifact.optimized_cycles,
                 b.artifact.optimized_cycles)

        stats = shared.memo.stats()
        total = max(stats["hits"] + stats["misses"], 1)
        hit_rate = stats["hits"] / total
        speedup = t_isolated / max(t_shared, 1e-9)
        print(f"# fleet of {len(FLEET)}: isolated {t_isolated:.2f}s vs "
              f"shared {t_shared:.2f}s ({speedup:.2f}x) | memo "
              f"{shared.memo.summary()}")
        rows = [("session_fleet", "+".join(FLEET), len(FLEET), timesteps,
                 round(t_isolated, 3), round(t_shared, 3), round(speedup, 2),
                 round(hit_rate, 3), stats["cross_kernel_hits"],
                 stats["entries"])]
        emit(rows, header=("bench", "fleet", "n_kernels", "timesteps",
                           "isolated_s", "shared_s", "speedup",
                           "memo_hit_rate", "cross_kernel_hits",
                           "memo_entries"))
        return rows
    finally:
        unregister_kernel(ALIAS)
