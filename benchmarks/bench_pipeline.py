"""Pipeline-schedule benchmark: gpipe vs 1F1B, with/without the overlapped
pod reduction.

Two kinds of rows:

* ``pipeline_memory`` — schedule-table accounting (device-free): peak live
  microbatch activations per stage and the implied peak activation bytes
  for the reduced stablelm config, gpipe vs 1F1B, across microbatch
  counts.  This is the number 1F1B exists to shrink (bounded at
  ``min(S, M)`` vs gpipe's ``M``) and the trajectory BENCH_ci.json tracks.
  It is the schedule's accounting model — what a runtime retiring
  activations at each ``B`` op realizes — not a measured XLA allocation
  (the CPU reproduction's ``jax.grad`` transpose keeps all residuals).
* ``pipeline_steps`` — measured steps/s of the shard_map train step on a
  host mesh (needs >= 4 forced host devices, as in the CI bench job):
  both schedules, and — when 8 devices allow a ``pod`` axis — the
  compressed pod reduction with the overlapped (per-group, stage-first)
  issue order vs the monolithic one.
"""

import time

from benchmarks.common import emit


def _steps_per_sec(step, state, batches, steps):
    state, _ = step(state, batches[0])           # compile outside the clock
    t0 = time.perf_counter()
    for i in range(steps):
        state, metrics = step(state, batches[i % len(batches)])
    float(metrics["loss"])                       # sync
    return steps / (time.perf_counter() - t0)


def run(steps: int = 4):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.dist.pipeline import SCHEDULES
    from repro.models import lm

    cfg = get_config("stablelm-3b", reduced=True).replace(n_layers=4)
    rows = []
    header = ("bench", "schedule", "n_stages", "n_micro", "peak_live_micro",
              "peak_act_mb", "bubble", "steps_per_s", "overlap")

    # --- schedule-table accounting (no devices) ----------------------------
    seq, mb = 128, 2
    act_bytes = mb * seq * cfg.d_model * jnp.dtype(cfg.dtype).itemsize
    for n_stages, n_micro in ((4, 8), (4, 16), (8, 32)):
        for name, cls in sorted(SCHEDULES.items()):
            sched = cls()
            peak = sched.peak_live_microbatches(n_micro, n_stages)
            rows.append(("pipeline_memory", name, n_stages, n_micro, peak,
                         round(peak * act_bytes / 2**20, 3),
                         round(sched.bubble_fraction(n_micro, n_stages), 3),
                         "", ""))

    # --- measured steps/s (forced multi-device hosts only) -----------------
    n_dev = len(jax.devices())
    if n_dev >= 4:
        from repro.data.pipeline import make_data
        from repro.launch.mesh import make_host_mesh
        from repro.optim import adamw as adamw_fn, constant_schedule
        from repro.train.step import (TrainState, init_ef_state,
                                      make_sharded_train_step, wants_ef)

        cfg = cfg.replace(pipeline_microbatches=4)
        opt = adamw_fn(constant_schedule(1e-3), weight_decay=0.1,
                       max_grad_norm=1.0)
        params = lm.init_model(cfg, jax.random.PRNGKey(0))
        data = make_data(cfg, 32, 16)   # dp_total=4 on both meshes -> M=4
        batches = [data.batch_at(i) for i in range(4)]

        meshes = [("", make_host_mesh(pipe=2))]
        if n_dev >= 8:
            meshes.append(("pods", make_host_mesh(pipe=2, pods=2)))
        for tag, mesh in meshes:
            pods = tag == "pods"
            for name in sorted(SCHEDULES):
                for overlap in ((True, False) if pods else (True,)):
                    ef = (init_ef_state(params, mesh,
                                        spec_tree=lm.model_spec(cfg))
                          if pods and wants_ef(cfg, mesh) else None)
                    state = TrainState(params, opt.init(params),
                                       jnp.zeros((), jnp.int32), ef)
                    step = jax.jit(make_sharded_train_step(
                        cfg, opt, mesh, schedule=name,
                        overlap_pod_reduce=overlap))
                    sps = _steps_per_sec(step, state, batches, steps)
                    sched = SCHEDULES[name]()
                    peak = sched.peak_live_microbatches(
                        cfg.pipeline_microbatches, 2)
                    rows.append((f"pipeline_steps{tag and '_' + tag}",
                                 name, 2, cfg.pipeline_microbatches, peak,
                                 "", "", round(sps, 3),
                                 int(overlap) if pods else ""))
                    print(f"# {tag or 'pipe'} {name} overlap={overlap}: "
                          f"{sps:.2f} steps/s")
    else:
        print(f"# {n_dev} host device(s): skipping measured steps/s "
              "(schedule accounting rows only)")

    emit(rows, header=header)
    return rows
