"""Paper Table 3: compute/memory workload analysis of the optimized vs
baseline schedule (machine counters standing in for Nsight Compute)."""

from repro.core import Machine
from repro.kernels import KERNELS
from repro.sched import cache as sched_cache
from repro.sched import lower, schedule
from repro.sched.api import TARGET
from benchmarks.common import emit, load_agents_summary


def run():
    summary = load_agents_summary()
    m = Machine()
    rows = []
    for name in ("matmul_leakyrelu", "bmm", "rmsnorm"):
        kdef = KERNELS[name]
        cfg = summary.get(name, {}).get("config") or kdef.configs[0]
        base = schedule(lower(kdef.make_spec(cfg)))
        art = sched_cache.load(name, TARGET, cfg)
        progs = {"baseline": base}
        if art is not None:
            progs["cuasmrl"] = art.program
        for label, prog in progs.items():
            c = m.run(prog).counters
            rows.append(("table3", name, label,
                         round(c["ipc"], 4),
                         round(c["dma_busy_in_frac"], 4),
                         round(c["dma_busy_out_frac"], 4),
                         round(c["bw_in_Bpc"] + c["bw_out_Bpc"], 3),
                         int(c["mxm_reuse_hits"]),
                         round(c["stall_sem"], 0)))
    emit(rows, header=("bench", "kernel", "schedule", "ipc",
                       "dma_in_busy", "dma_out_busy", "mem_Bpc",
                       "reuse_hits", "sem_stall_cycles"))
    return rows
