"""Serve-engine load benchmark: latency/throughput vs offered QPS.

Replays the same seeded Poisson trace (mixed prompt/output lengths, two
weighted tenants) against the continuous-batching ``ServeEngine`` and
against the same engine degraded to static gang batching, at each offered
QPS — with and without an RL-optimized schedule plan resolved from a
freshly tuned cache (nearest-bucket index lookups; the plan axis records
the fleet's mean kernel speedup and the modeled tokens/s it implies,
since the simulated machine is not in the CPU serve loop).

Reported per row: delivered tokens/s, p50/p99 end-to-end latency, p50
TTFT, stall/preemption counts.  The suite asserts the continuous-batching
acceptance criterion: at the saturating QPS point, continuous admission
beats gang admission on delivered tokens/s.  In the CI ``--fast`` smoke
set, so the numbers land in ``BENCH_ci.json`` every run.

The ``serve_paged`` cell drains the same seeded shared-prefix burst
(loadgen ``prefix_tokens``) through the paged engine and the dense-slot
engine at a *fixed* ``kv_blocks`` budget, recording delivered tokens/s,
peak KV bytes, and the concurrency high-water mark.  Acceptance: with
prefix sharing the paged engine must keep at least 2x the dense
engine's concurrent sequences resident on the same block budget.  Pool
invariants (``KVBlockPool.check``) run every tick of this cell.
"""

import tempfile
import time

import jax

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import build_stall_table
from repro.models import lm
from repro.sched import OptimizationSession, make_budgeted_strategy
from repro.sched.session import OptimizeRequest
from repro.serve import (ServeEngine, Tenant, TrafficConfig, poisson_trace,
                         run_load)

ARCH = "qwen1.5-4b"
QPS_SWEEP = (4.0, 256.0)         # trickle vs saturating offered load
N_REQUESTS = 24
MAX_BATCH = 4
MAX_SEQ = 48
PLAN_KERNELS = ("rmsnorm", "softmax")


def _build_plan_cache(timesteps: int) -> str:
    """Tune a small kernel fleet into a throwaway cache dir (greedy
    budgeted strategy — the bench measures serving, not search)."""
    cache_dir = tempfile.mkdtemp(prefix="bench_serve_cache_")
    session = OptimizationSession(
        stall_db=build_stall_table(), cache_dir=cache_dir,
        strategy=make_budgeted_strategy("greedy", timesteps=timesteps,
                                        episode_length=8))
    session.optimize_many([OptimizeRequest(kernel=k, force=True)
                           for k in PLAN_KERNELS], max_workers=2)
    return cache_dir


def _mean_plan_speedup(engine) -> float:
    arts = [a for a in engine.plan.values() if a is not None]
    if not arts:
        return 1.0
    return sum(a.speedup for a in arts) / len(arts)


# Paged-vs-dense capacity cell: a shared-system-prompt burst on a tight
# fixed block budget.  Dense slots must hold every request's whole prompt
# privately; paged slots share the 3 prefix blocks and add ~1 private
# block per request.
PAGED_PREFIX = 24                # 3 full blocks at block_size=8
PAGED_KV_BLOCKS = 8
PAGED_BURST = 12


def _paged_capacity_cell(cfg, params):
    traffic = TrafficConfig(
        qps=1000.0, n_requests=PAGED_BURST, n_tenants=1,
        prompt_len=(2, 4), output_len=(4, 8), vocab=cfg.vocab, seed=11,
        prefix_tokens=PAGED_PREFIX, prefix_groups=1)
    burst = poisson_trace(traffic, ["t0"])
    warm_prompt = burst[0].prompt[:PAGED_PREFIX + 1]

    rows, cells = [], {}
    for paged in (True, False):
        engine = ServeEngine.from_config(
            cfg, params=params, max_batch=8, max_seq=MAX_SEQ, block_size=8,
            kv_blocks=PAGED_KV_BLOCKS, tenants=[Tenant("t0")], paged=paged,
            debug_invariants=True)
        # Warm the prefix cache the way a real deployment does: one
        # resident request whose prefill registers the system prompt,
        # then the burst admits against it.
        warm = engine.submit(warm_prompt, 8, tenant="t0")
        for _ in range(200):
            if warm.first_token_time is not None:
                break
            engine.step()
        assert warm.first_token_time is not None, "warm-up never prefilled"
        reqs = [engine.submit(a.prompt, a.max_new_tokens, tenant="t0")
                for a in burst]
        t0 = time.monotonic()
        engine.run(max_steps=20_000)
        wall = time.monotonic() - t0
        assert all(r.done for r in reqs)
        eng = engine.stats()["engine"]
        toks = sum(len(r.output) for r in reqs)
        cells[paged] = eng
        rows.append((
            "serve_paged", ARCH, "paged" if paged else "dense",
            PAGED_KV_BLOCKS, PAGED_BURST, PAGED_PREFIX,
            round(toks / wall, 2), toks, eng["max_active"],
            eng["peak_kv_bytes"], eng["kv_bytes_allocated"],
            eng["passes"], eng["stalls"], eng["preemptions"],
            eng["prefix_hits"], eng["cow_forks"], eng["preempt_spills"]))

    ratio = cells[True]["max_active"] / max(1, cells[False]["max_active"])
    print(f"# paged capacity: {cells[True]['max_active']} vs dense "
          f"{cells[False]['max_active']} concurrent seqs at "
          f"{PAGED_KV_BLOCKS} blocks ({ratio:.1f}x)")
    assert cells[True]["max_active"] >= 2 * cells[False]["max_active"], (
        f"paged engine admitted {cells[True]['max_active']} concurrent "
        f"sequences vs dense {cells[False]['max_active']} on "
        f"{PAGED_KV_BLOCKS} blocks — expected >= 2x")
    return rows


def run(timesteps: int = 48):
    cfg = get_config(ARCH, reduced=True)
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    tenants = lambda: [Tenant("t0", weight=3.0), Tenant("t1", weight=1.0)]
    plan_cache = _build_plan_cache(timesteps)

    rows = []
    sat = {}      # (admission, plans) -> tokens/s at the saturating QPS
    for qps in QPS_SWEEP:
        # Wide output-length mix: the gang baseline holds every lane until
        # its longest member finishes, which is the waste continuous
        # admission exists to reclaim.
        traffic = TrafficConfig(qps=qps, n_requests=N_REQUESTS, n_tenants=2,
                                prompt_len=(2, 16), output_len=(2, 24),
                                vocab=cfg.vocab, seed=7)
        for admission in ("continuous", "gang"):
            for plans in (False, True):
                engine = ServeEngine.from_config(
                    cfg, params=params, max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                    block_size=8, tenants=tenants(), admission=admission,
                    schedule_cache=plan_cache if plans else None)
                report = run_load(engine, traffic)
                speedup = _mean_plan_speedup(engine) if plans else 1.0
                eng = report["stats"]["engine"]
                if qps == max(QPS_SWEEP):
                    sat[(admission, plans)] = report["tokens_per_s"]
                rows.append((
                    "serve_load", ARCH, qps, admission,
                    "plan" if plans else "baseline", report["n_requests"],
                    round(report["tokens_per_s"], 2),
                    round(report["latency_p50_s"] * 1e3, 2),
                    round(report["latency_p99_s"] * 1e3, 2),
                    round(report["ttft_p50_s"] * 1e3, 2),
                    round(speedup, 4),
                    round(report["tokens_per_s"] * speedup, 2),
                    eng["stalls"], eng["preemptions"],
                    round(eng["lane_utilization"], 3)))

    # Acceptance: continuous batching beats static gang batching on
    # delivered tokens/s once the offered load saturates the engine.
    for plans in (False, True):
        cont, gang = sat[("continuous", plans)], sat[("gang", plans)]
        print(f"# saturation ({'plan' if plans else 'baseline'}): "
              f"continuous {cont:.1f} tok/s vs gang {gang:.1f} tok/s "
              f"({cont / gang:.2f}x)")
        assert cont > gang, (
            f"continuous batching did not beat static batching at "
            f"saturation: {cont:.1f} vs {gang:.1f} tok/s (plans={plans})")

    emit(rows, header=("bench", "arch", "qps", "admission", "plans",
                       "n_requests", "tokens_per_s", "latency_p50_ms",
                       "latency_p99_ms", "ttft_p50_ms", "plan_speedup",
                       "modeled_tokens_per_s", "stalls", "preemptions",
                       "lane_utilization"))

    paged_rows = _paged_capacity_cell(cfg, params)
    emit(paged_rows, header=("bench", "arch", "kv", "kv_blocks", "n_requests",
                             "prefix_tokens", "tokens_per_s", "tokens",
                             "max_active", "peak_kv_bytes",
                             "kv_bytes_allocated", "passes", "stalls",
                             "preemptions", "prefix_hits", "cow_forks",
                             "preempt_spills"))
    return rows + paged_rows
