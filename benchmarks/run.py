"""Benchmark aggregator: one module per paper table/figure (DESIGN.md §6).

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the RL-training benches (fig8 / §5.7)")
    args = ap.parse_args()

    from benchmarks import (bench_autotune, bench_kernel_throughput,
                            bench_microbench, bench_moves, bench_rl_sensitivity,
                            bench_roofline, bench_stall_resolution,
                            bench_workload_analysis)

    suites = [
        ("table1_microbench", bench_microbench.run),
        ("fig7_stall_resolution", bench_stall_resolution.run),
        ("autotune", bench_autotune.run),
        ("fig6_kernel_throughput", bench_kernel_throughput.run),
        ("table3_workload", bench_workload_analysis.run),
        ("roofline", bench_roofline.run),
    ]
    if not args.fast:
        suites += [
            ("fig8_rl_sensitivity", bench_rl_sensitivity.run),
            ("sec57_moves", bench_moves.run),
        ]

    for name, fn in suites:
        print(f"\n==== {name} ====", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # keep the suite running; a bench failure
            print(f"BENCH-FAIL,{name},{type(e).__name__}: {e}")
        print(f"# {name} took {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
