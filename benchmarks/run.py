"""Benchmark aggregator: one module per paper table/figure (DESIGN.md §6).

    PYTHONPATH=src python -m benchmarks.run [--fast] [--out BENCH_ci.json]

``--out`` writes a machine-readable summary (per-suite status, wall time,
and whatever rows the suite returned) — CI uploads it as the benchmark
trajectory artifact.
"""

import argparse
import json
import os
import time

SERVE_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              "BENCH_serve_baseline.json")


def _serve_paged_ratio(report):
    """Dense-normalized paged throughput from the ``serve_paged`` rows:
    delivered tokens *per engine pass*, paged over dense, on the same
    trace and block budget.  Pass counts are deterministic (no wall
    clock in the capacity cell), so a >10% drop is a real efficiency
    regression — broken prefix sharing or recompute-style preemption
    inflates the paged pass count immediately."""
    rows = next((r.get("rows") or [] for r in report
                 if r["suite"] == "serve_load" and r["ok"]), [])
    cells = {r[2]: float(r[7]) / float(r[11]) for r in rows
             if r and r[0] == "serve_paged" and float(r[11])}
    if "paged" not in cells or not cells.get("dense"):
        return None
    return cells["paged"] / cells["dense"]


def _check_serve_baseline(report, path):
    """Fail the run when the paged/dense serve throughput ratio regresses
    more than 10% against the committed baseline."""
    ratio = _serve_paged_ratio(report)
    if ratio is None:
        print("# serve baseline: no serve_paged rows this run, skipping")
        return True
    if not os.path.exists(path):
        print(f"# serve baseline: {path} missing, skipping "
              f"(current paged/dense ratio {ratio:.3f})")
        return True
    with open(path) as f:
        base = json.load(f)["paged_over_dense_tokens_per_pass"]
    floor = 0.9 * base
    ok = ratio >= floor
    print(f"# serve baseline: paged/dense tokens-per-pass {ratio:.3f} vs "
          f"committed {base:.3f} (floor {floor:.3f}) -> "
          f"{'ok' if ok else 'REGRESSION'}")
    if not ok:
        print(f"BENCH-FAIL,serve_regression,paged/dense ratio {ratio:.3f} "
              f"fell more than 10% below baseline {base:.3f}")
    return ok


def _jsonable(obj):
    """Best-effort conversion of bench return values for the JSON report.
    allow_nan=False so non-finite floats become strings instead of the
    bare NaN/Infinity tokens that break strict JSON consumers."""
    try:
        json.dumps(obj, allow_nan=False)
        return obj
    except (TypeError, ValueError):
        if isinstance(obj, (list, tuple)):
            return [_jsonable(x) for x in obj]
        if isinstance(obj, dict):
            return {str(k): _jsonable(v) for k, v in obj.items()}
        for conv in ("item", "tolist"):   # numpy scalars/arrays stay numeric
            fn = getattr(obj, conv, None)
            if fn is not None:
                try:
                    return _jsonable(fn())
                except (TypeError, ValueError):
                    pass
        return str(obj)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the RL-training benches (fig8 / §5.7)")
    ap.add_argument("--out", default=None,
                    help="write a JSON summary of every suite here")
    ap.add_argument("--serve-baseline", default=SERVE_BASELINE,
                    help="committed serve-throughput baseline JSON; the run "
                         "fails if the paged/dense tokens/s ratio drops "
                         "more than 10%% below it")
    args = ap.parse_args()

    from benchmarks import (bench_autotune, bench_evaluator, bench_fleet,
                            bench_kernel_throughput, bench_microbench,
                            bench_moves, bench_pipeline, bench_resilience,
                            bench_reward_loop, bench_rl_sensitivity,
                            bench_roofline, bench_serve, bench_session,
                            bench_stall_resolution, bench_workload_analysis)

    suites = [
        ("table1_microbench", bench_microbench.run),
        ("fig7_stall_resolution", bench_stall_resolution.run),
        ("autotune", bench_autotune.run),
        ("fig6_kernel_throughput", bench_kernel_throughput.run),
        ("table3_workload", bench_workload_analysis.run),
        ("roofline", bench_roofline.run),
        # reward-loop throughput: in the --fast set so the CI bench smoke
        # job records the fast-path trajectory in BENCH_ci.json
        ("reward_loop", bench_reward_loop.run),
        # fleet sessions: shared-memo optimize_many vs isolated sessions
        ("session_fleet", bench_session.run),
        # scenario × target campaign: per-bucket tuning + resume + dispatch
        ("fleet_campaign", bench_fleet.run),
        # pipeline schedules: gpipe vs 1F1B memory/throughput + overlapped
        # pod reduction (measured rows need the 8-device CI bench env)
        ("pipeline_schedules", bench_pipeline.run),
        # serve engine under Poisson load: p50/p99 latency + tokens/s vs
        # QPS, continuous vs gang admission, plans on/off (CPU smoke cell)
        ("serve_load", bench_serve.run),
        # fault-injected campaigns through ResilientBackend: success rate,
        # retries absorbed, and bit-exactness vs the fault-free run at
        # transient rates {0, 5, 20}%
        ("resilience", bench_resilience.run),
        # strategy evaluator: the search roster raced under one budget +
        # the memo-trained cost model's held-out rank correlation
        ("strategy_evaluator", bench_evaluator.run),
    ]
    if not args.fast:
        suites += [
            ("fig8_rl_sensitivity", bench_rl_sensitivity.run),
            ("sec57_moves", bench_moves.run),
        ]

    report = []
    for name, fn in suites:
        print(f"\n==== {name} ====", flush=True)
        t0 = time.time()
        entry = {"suite": name, "ok": True}
        try:
            entry["rows"] = _jsonable(fn())
        except Exception as e:  # keep the suite running; a bench failure
            print(f"BENCH-FAIL,{name},{type(e).__name__}: {e}")
            entry.update(ok=False, error=f"{type(e).__name__}: {e}")
        entry["seconds"] = round(time.time() - t0, 3)
        report.append(entry)
        print(f"# {name} took {entry['seconds']:.1f}s", flush=True)

    serve_ok = _check_serve_baseline(report, args.serve_baseline)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"fast": args.fast, "suites": report,
                       "serve_paged_over_dense": _serve_paged_ratio(report)},
                      f, indent=2, allow_nan=False)
        print(f"\n# wrote {args.out} "
              f"({sum(r['ok'] for r in report)}/{len(report)} suites ok)")
    if not all(r["ok"] for r in report) or not serve_ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
