"""Campaign benchmark for the scenario × target axes: a kernel fleet
tuned over the full (scenario bucket × machine target) product through one
``OptimizationSession``, vs the single-point default-bucket baseline.

Reports campaign wall time, the resume pass (identical campaign re-run:
every cell must come back from the scenario-keyed cache index), the shared
memo's hit rate across the product, and a per-(kernel, bucket, target)
cycles table.  Also sanity-checks the serve side: nearest-bucket dispatch
resolves every tuned bucket without optimizing anything new.  In the CI
``--fast`` smoke set, so BENCH_ci.json tracks the campaign trajectory."""

import tempfile
import time

from repro.core import build_stall_table
from repro.sched import OptimizationSession, make_budgeted_strategy
from repro.sched.cache import ScheduleCache
from repro.sched.scenario import Scenario
from repro.launch.optimize import campaign_requests, parse_targets
from benchmarks.common import emit

FLEET = ("rmsnorm", "softmax")
SCENARIOS = (None,                                   # single-point baseline
             Scenario(batch=8, seq_len=4096),
             Scenario(batch=64, seq_len=32768, occupancy="half"))
TARGET_NAMES = "tpu-tsass-v1,tpu-tsass-v2"


def run(timesteps: int = 64):
    db = build_stall_table()
    targets = parse_targets(TARGET_NAMES)
    units = [(k, s) for k in FLEET for s in SCENARIOS]
    reqs = campaign_requests(units, targets, force=True)
    cache_dir = tempfile.mkdtemp(prefix="bench_fleet_")
    session = OptimizationSession(
        stall_db=db, cache_dir=cache_dir,
        strategy=make_budgeted_strategy("greedy", timesteps=timesteps,
                                        episode_length=8))

    t0 = time.perf_counter()
    results = session.optimize_many(reqs, max_workers=2)
    t_campaign = time.perf_counter() - t0

    # resume: the identical campaign is pure index hits
    t0 = time.perf_counter()
    again = session.optimize_many(campaign_requests(units, targets))
    t_resume = time.perf_counter() - t0
    assert all(r.from_cache for r in again), "campaign resume re-searched"

    # serve side: every tuned bucket dispatches as a pure index lookup
    sc = ScheduleCache(cache_dir)
    for k in FLEET:
        for s in SCENARIOS:
            for t in targets:
                art = sc.dispatch(k, s, target=t)
                assert art is not None, (k, s, t.name)

    stats = session.memo.stats()
    hit_rate = stats["hits"] / max(stats["hits"] + stats["misses"], 1)
    cells = len(reqs)
    print(f"# campaign of {cells} cells ({len(FLEET)} kernels × "
          f"{len(SCENARIOS)} buckets × {len(targets)} targets): "
          f"{t_campaign:.2f}s search, {t_resume:.2f}s resume | memo "
          f"{session.memo.summary()}")

    rows = []
    for r in results:
        art = r.artifact
        rows.append(("fleet_campaign", r.kernel, r.scenario or "default",
                     r.target,
                     timesteps, round(art.baseline_cycles, 1),
                     round(art.optimized_cycles, 1),
                     round(art.speedup, 4), round(r.seconds, 3)))
    rows.append(("fleet_campaign_total", "+".join(FLEET), f"{cells}cells",
                 "x".join(t.name for t in targets), timesteps,
                 round(t_campaign, 3), round(t_resume, 3),
                 round(hit_rate, 3), stats["entries"]))
    emit(rows, header=("bench", "kernel", "bucket", "target", "timesteps",
                       "baseline_cycles", "optimized_cycles", "speedup",
                       "seconds"))
    return rows
