"""Paper §5.7: automatic discovery of optimization moves.  Trains a small
agent, replays it deterministically in inference mode, and reports the
top-gain reorderings with their move classes (reuse-cache interleave /
predicated-slot hoist / DMA latency hiding) and the lingering fraction."""

from repro.core import build_stall_table
from repro.core.game import run_inference, train_on_program
from repro.core.moves import lingering_fraction, top_moves
from repro.core.ppo import PPOConfig
from repro.kernels import KERNELS
from repro.sched import lower, schedule
from benchmarks.common import emit


def run(budget: int = 6144):
    db = build_stall_table()
    rows = []
    for name in ("matmul_leakyrelu", "bmm"):   # the two kernels of §5.7
        kdef = KERNELS[name]
        prog = schedule(lower(kdef.make_spec(kdef.configs[0])))
        cfg = PPOConfig(total_timesteps=budget, num_envs=8, num_steps=64,
                        episode_length=64, seed=0)
        res = train_on_program(prog, stall_db=db, cfg=cfg)
        env = run_inference(prog, res.params, stall_db=db,
                            episode_length=64)
        moves = top_moves(env, k=3)
        for mv in moves:
            rows.append(("sec57", name, mv.step, mv.record.moved.opcode,
                         "up" if mv.record.direction == 0 else "down",
                         round(mv.gain_pct, 3), mv.kind))
        rows.append(("sec57", name, "lingering", "", "",
                     round(lingering_fraction(env), 3), "§5.7.2 indicator"))
        print(f"# {name}: inference best {env.best_cycles:.0f} "
              f"(baseline {env.t0:.0f})")
        for mv in moves[:2]:
            print("\n".join("# " + ln for ln in mv.render().splitlines()))
    emit(rows, header=("bench", "kernel", "step", "opcode", "dir",
                       "gain_pct_T0", "class"))
    return rows
