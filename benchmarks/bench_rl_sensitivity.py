"""Paper Fig. 8 / §5.5: PPO hyperparameter sensitivity — learning rate and
batch-size sweeps around the default setting, final episodic returns.
Budgets are kept small (single-core container); the qualitative claim under
test is robustness of the default configuration."""

from repro.core import build_stall_table
from repro.core.game import train_on_program
from repro.core.ppo import PPOConfig
from repro.kernels import KERNELS
from repro.sched import lower, schedule
from benchmarks.common import emit

SETTINGS = [
    ("default", dict(lr=2.5e-4, num_steps=64)),
    ("lr_hi", dict(lr=1e-3, num_steps=64)),
    ("lr_lo", dict(lr=5e-5, num_steps=64)),
    ("batch_small", dict(lr=2.5e-4, num_steps=32)),
]


def run(budget: int = 4096):
    db = build_stall_table()
    kdef = KERNELS["matmul_leakyrelu"]   # the paper sweeps fused GEMM+epilogue
    prog = schedule(lower(kdef.make_spec(kdef.configs[0])))
    rows = []
    for label, kw in SETTINGS:
        cfg = PPOConfig(total_timesteps=budget, num_envs=8,
                        episode_length=64, seed=0, **kw)
        res = train_on_program(prog, stall_db=db, cfg=cfg)
        returns = [r["episodic_return"] for r in res.stats]
        rows.append(("fig8", label, kw["lr"], kw["num_steps"] * 8,
                     round(returns[0], 3), round(returns[-1], 3),
                     round(res.improvement, 4),
                     round(res.stats[-1]["entropy"], 3),
                     round(res.stats[-1]["approx_kl"], 5)))
    emit(rows, header=("bench", "setting", "lr", "batch", "first_return",
                       "final_return", "improvement", "final_entropy",
                       "final_kl"))
    return rows
