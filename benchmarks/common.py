"""Shared helpers for the benchmark suite."""

import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")
CACHE_DIR = os.path.join(os.path.dirname(RESULTS_DIR), ".repro_cache")


def load_agents_summary():
    path = os.path.join(RESULTS_DIR, "agents_summary.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def load_dryrun():
    path = os.path.join(RESULTS_DIR, "dryrun.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return []


def emit(rows, header=None):
    """Print rows as CSV (the harness contract: name,value,derived)."""
    if header:
        print(",".join(header))
    for row in rows:
        print(",".join(str(v) for v in row))
    return rows
