"""Strategy-evaluator bench: the full search roster (PPO / greedy /
random / beam x {oracle, cost, policy}) raced over the §5.7 kernel pair
under one small per-cell measurement budget, plus the trained cost
model's held-out rank correlation against the oracle cycles.  The
headline row pair: beam-cost matching greedy's best cycles on a quarter
of its real measurements.

``lookahead`` is left out of the smoke roster — its per-child rollouts
dominate wall time without changing the comparison; run
``python -m repro.launch.evaluate`` for the full table.
"""

from repro.costmodel import evaluate_strategies
from benchmarks.common import emit

SMOKE_STRATEGIES = ("ppo", "greedy", "random", "beam-oracle", "beam-cost",
                    "beam-policy")


def run(budget: int = 256):
    result = evaluate_strategies(strategies=SMOKE_STRATEGIES,
                                 budget=budget, seed=0, train_steps=800)
    rc = result["rank_correlation"]
    rows = []
    for r in sorted(result["rows"],
                    key=lambda r: (r["kernel"], r["best_cycles"])):
        rows.append(("evaluator", r["strategy"], r["kernel"],
                     round(r["baseline_cycles"]), round(r["best_cycles"]),
                     r["improvement_pct"], r["measurements"], r["seconds"]))
    rows.append(("evaluator", "cost_model", "heldout_spearman", "", "",
                 round(rc, 3) if rc == rc else "nan",
                 result["dataset_rows"], ""))
    print(f"# cost model: held-out Spearman {rc:.3f} over "
          f"{result['dataset_rows']} corpus rows (budget {budget}/cell)")
    emit(rows, header=("bench", "strategy", "kernel", "baseline", "best",
                       "impr_pct", "measurements", "seconds"))
    return rows
