"""Framework §Roofline table: reads results/dryrun.json (produced by
``python -m repro.launch.dryrun --arch all --mesh both --out
results/dryrun.json``) and prints the three roofline terms per cell."""

from benchmarks.common import emit, load_dryrun


def run():
    cells = load_dryrun()
    rows = []
    for c in cells:
        if c.get("status") == "ok":
            r = c["roofline"]
            rows.append(("roofline", c["arch"], c["shape"], c["mesh"],
                         f"{r['compute_s']:.4g}", f"{r['memory_s']:.4g}",
                         f"{r['collective_s']:.4g}", r["dominant"],
                         f"{(r['useful_ratio'] or 0):.3f}"))
        elif c.get("status") == "skip":
            rows.append(("roofline", c["arch"], c["shape"], c["mesh"],
                         "skip", "", "", "", ""))
        else:
            rows.append(("roofline", c["arch"], c["shape"], c["mesh"],
                         "FAIL", "", "", "", ""))
    if not rows:
        rows.append(("roofline", "(run repro.launch.dryrun first)", "", "",
                     "", "", "", "", ""))
    emit(rows, header=("bench", "arch", "shape", "mesh", "compute_s",
                       "memory_s", "collective_s", "dominant",
                       "model/hlo_flops"))
    return rows
