"""Optimize any registered Pallas kernel's TSASS schedule and trace the
discovered moves (paper §5.7).

    PYTHONPATH=src python examples/optimize_kernel.py --kernel fused_ff \
        --timesteps 8192
"""

import argparse

from repro.core import build_stall_table
from repro.core.game import run_inference, train_on_program
from repro.core.moves import lingering_fraction, top_moves
from repro.core.ppo import PPOConfig
from repro.kernels import KERNELS
from repro.sched import lower, schedule


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", default="fused_ff", choices=list(KERNELS))
    ap.add_argument("--timesteps", type=int, default=8192)
    ap.add_argument("--episode-length", type=int, default=96)
    args = ap.parse_args()

    db = build_stall_table()
    kdef = KERNELS[args.kernel]
    o3 = schedule(lower(kdef.make_spec(kdef.configs[0])))
    cfg = PPOConfig(total_timesteps=args.timesteps, num_envs=8,
                    num_steps=128, episode_length=args.episode_length)
    res = train_on_program(o3, stall_db=db, cfg=cfg, verbose=True)
    print(f"\nbaseline {res.baseline_cycles:.0f} -> best "
          f"{res.best_cycles:.0f} ({res.improvement:+.2%})")

    env = run_inference(o3, res.params, stall_db=db,
                        episode_length=args.episode_length)
    print(f"inference episode best: {env.best_cycles:.0f}; "
          f"lingering fraction {lingering_fraction(env):.2f}")
    for mv in top_moves(env, k=3):
        print()
        print(mv.render())


if __name__ == "__main__":
    main()
