"""Optimize a fleet of registered Pallas kernels through the session API,
then deploy from the cache and trace the discovered moves (paper §5.7).

    PYTHONPATH=src python examples/optimize_kernel.py \
        --kernels fused_ff rmsnorm --timesteps 8192

Drives the full redesigned surface end to end: a measurement backend, a
search strategy, declarative requests through
``OptimizationSession.optimize_many`` (shared stall table + cross-kernel
measurement memo), index-based ``deploy()`` (no re-autotune), and — when
PPO ran — the §5.7 inference replay over the trained policy.
"""

import argparse

from repro.core import build_stall_table
from repro.core.game import run_inference
from repro.core.moves import lingering_fraction, top_moves
from repro.kernels import KERNELS
from repro.sched import (OptimizationSession, OptimizeRequest, lower,
                         make_budgeted_strategy, schedule)
from repro.sched.backends import BACKENDS
from repro.sched.session import STRATEGIES


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernels", nargs="+", default=["fused_ff"],
                    choices=list(KERNELS))
    ap.add_argument("--strategy", default="ppo", choices=sorted(STRATEGIES))
    ap.add_argument("--backend", default="fast", choices=sorted(BACKENDS))
    ap.add_argument("--timesteps", type=int, default=8192)
    ap.add_argument("--episode-length", type=int, default=96)
    ap.add_argument("--cache-dir", default=".repro_cache")
    ap.add_argument("--workers", type=int, default=1)
    args = ap.parse_args()

    db = build_stall_table()
    session = OptimizationSession(
        backend=args.backend,
        strategy=make_budgeted_strategy(args.strategy,
                                        timesteps=args.timesteps,
                                        episode_length=args.episode_length),
        stall_db=db, cache_dir=args.cache_dir)
    results = session.optimize_many(
        [OptimizeRequest(kernel=name, force=True, verbose=True)
         for name in args.kernels],
        max_workers=args.workers)

    for res in results:
        art = res.artifact
        print(f"\n{res.kernel}: baseline {art.baseline_cycles:.0f} -> best "
              f"{art.optimized_cycles:.0f} cycles "
              f"({art.speedup:.3f}x, {res.strategy}/{res.backend})")
    if session.memo is not None:
        print(f"shared memo: {session.memo.summary()}")

    # deploy-time lookup: pure cache-index read, no autotune, no training
    art = session.deploy(results[0].kernel)
    print(f"deploy({results[0].kernel}): {len(art.program)} instructions "
          f"at {art.optimized_cycles:.0f} cycles from the cache index")

    res = results[0]
    if res.game is not None:
        o3 = schedule(lower(KERNELS[res.kernel].make_spec(res.config)))
        env = run_inference(o3, res.game.params, stall_db=db,
                            episode_length=args.episode_length)
        print(f"inference episode best: {env.best_cycles:.0f}; "
              f"lingering fraction {lingering_fraction(env):.2f}")
        for mv in top_moves(env, k=3):
            print()
            print(mv.render())


if __name__ == "__main__":
    main()
