"""End-to-end training driver: a ~100M-parameter dense LM for a few hundred
steps on CPU, with checkpointing + restart.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse

from repro.configs import get_config
from repro.train import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="results/lm_ckpt")
    args = ap.parse_args()

    # a ~100M-class config: stablelm-3b family, scaled to laptop size
    cfg = get_config("stablelm-3b").replace(
        n_layers=4, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
        d_ff=1536, vocab=8192, dtype="float32", remat=False, attn_chunk=128)
    tcfg = TrainConfig(steps=args.steps, seq_len=128, global_batch=8,
                       lr=6e-4, warmup=20, ckpt_dir=args.ckpt_dir,
                       ckpt_every=50)
    trainer = Trainer(cfg, tcfg)
    print(f"resuming from step {trainer.start_step}"
          if trainer.start_step else "fresh run")
    log = trainer.run()
    for row in log[:: max(1, len(log) // 12)]:
        print(f"step={row['step']:4d} loss={row['loss']:.4f} "
              f"({row['seconds']*1e3:.0f} ms)")
    print(f"final loss {log[-1]['loss']:.4f} (from {log[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
