"""Batched serving example: KV-cache decode for a sliding-window arch and
an O(1)-state SSM arch (the two long-context families).

    PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import for_config
from repro.serve import generate


def main() -> None:
    for arch in ("gemma3-1b", "mamba2-1.3b"):
        cfg = get_config(arch, reduced=True)
        model = for_config(cfg)
        params = model.init_model(cfg, jax.random.PRNGKey(0))
        prompt = np.random.default_rng(0).integers(
            0, cfg.vocab, (4, 12), dtype=np.int32)
        fn = jax.jit(lambda p, t: generate(p, cfg, t, 20))
        t0 = time.time()
        out = fn(params, prompt)
        out.block_until_ready()
        dt = time.time() - t0
        print(f"{arch}: {4 * 20} tokens in {dt:.2f}s "
              f"(incl. compile); sample: {np.asarray(out[0, :20]).tolist()}")


if __name__ == "__main__":
    main()
