"""Quickstart: the paper's full pipeline on one kernel in ~a minute.

    PYTHONPATH=src python examples/quickstart.py

1. Dependency-microbenchmark the stall-count table (Table 1).
2. Autotune the kernel's block configs (hierarchical search, §3.1).
3. Lower to TSASS, build the -O3 baseline schedule.
4. Train a (tiny-budget) PPO agent on the assembly game (§3.3-3.7).
5. Probabilistically verify + cache the optimized schedule (§4.1-4.2).

Steps 2-5 are one ``session.optimize(request)`` call; deployment is an
index lookup (``session.deploy``) — no retraining, no re-autotune.  The
old one-kernel ``CuAsmRL`` class survives as a deprecated shim over this.
"""

from repro.core import build_stall_table
from repro.core.ppo import PPOConfig
from repro.sched import OptimizationSession, OptimizeRequest


def main() -> None:
    print("== microbenchmarking stall counts (paper §4.3) ==")
    db = build_stall_table()
    print("   ", db)

    ppo = PPOConfig(total_timesteps=4096, num_envs=8, num_steps=64,
                    episode_length=64, seed=0)
    session = OptimizationSession(stall_db=db, cache_dir=".repro_cache")

    print("== hierarchical search + assembly game (paper §3) ==")
    res = session.optimize(OptimizeRequest(kernel="rmsnorm", ppo=ppo,
                                           force=True))
    art = res.artifact
    print(f"   config: {art.config}")
    print(f"   baseline (-O3) cycles : {art.baseline_cycles:.0f}")
    print(f"   CuAsmRL cycles        : {art.optimized_cycles:.0f}")
    print(f"   speedup               : {art.speedup:.3f}x")

    print("== deploy-time lookup (paper §4.2) ==")
    again = session.deploy("rmsnorm")
    print(f"   loaded cached schedule with {len(again.program)} instructions")


if __name__ == "__main__":
    main()
