"""Fault-tolerant measurement & campaign layer: seeded fault injection
(transients / hangs / crash fingerprints / outliers), ResilientBackend
retry + robust timing + circuit breaking, supervised resumable campaigns
with the persistent failure ledger, the previously-untested
probabilistic-verify failure path, cache/memo quarantine, and graceful
serve degradation (``on_missing``)."""

import json
import os
import pickle
import warnings

import pytest

from repro.core import (FaultSpec, FaultyMachine, HardFault, Machine,
                        MeasureError, schedule_fingerprint)
from repro.core.faults import MeasureTimeout
from repro.launch.optimize import campaign_requests, parse_scenarios
from repro.sched import (FailureLedger, FastTimingBackend,
                         OptimizationSession, OptimizeFailure,
                         OptimizeRequest, ResilientBackend, RetryPolicy,
                         baseline, lower, make_backend,
                         make_budgeted_strategy, resolve_schedule)
from repro.sched.backends import (MemoVersionError, SharedMeasureMemo,
                                  warm_start_memo)
from repro.sched.cache import CacheVersionError, ScheduleCache
from repro.sched.resilience import MeasureExhausted, cell_key
from repro.sched.scenario import build_spec, get_target
from repro.sched.session import SearchOutcome
from repro.core.isa import program_text


def _scheduled(kernel_programs, name="rmsnorm"):
    return kernel_programs[name]


def _faulty_factory(**spec_kw):
    spec = FaultSpec(**spec_kw)
    return lambda: FaultyMachine(spec)


# ---------------------------------------------------------------------------
# fault injection (core/faults.py)
# ---------------------------------------------------------------------------

def test_fault_injection_deterministic_and_fingerprint_invariance(
        kernel_programs):
    prog = _scheduled(kernel_programs)

    def trace(seed):
        m = FaultyMachine(FaultSpec(seed=seed, transient_rate=0.3,
                                    outlier_rate=0.2, outlier_scale=5.0))
        out = []
        for _ in range(30):
            try:
                out.append(round(m.time(prog), 3))
            except MeasureError:
                out.append("X")
        return out, dict(m.fault_counters)

    t1, c1 = trace(7)
    t2, c2 = trace(7)
    assert t1 == t2 and c1 == c2            # same seed -> same fault replay
    t3, _ = trace(8)
    assert t1 != t3                          # different seed -> different
    assert c1["transients"] > 0 and c1["outliers"] > 0

    # no faults firing -> byte-identical to the wrapped machine
    clean = FaultyMachine(FaultSpec(seed=0))
    assert clean.time(prog) == Machine().time(prog)
    assert clean.run(prog).cycles == Machine().run(prog).cycles

    # the fingerprint is permutation-invariant (identifies the *cell*,
    # not the ordering the game is mutating) and schedule-hint-blind
    fp = schedule_fingerprint(prog)
    assert schedule_fingerprint(list(reversed(prog))) == fp
    other = _scheduled(kernel_programs, "softmax")
    assert schedule_fingerprint(other) != fp

    crash = FaultyMachine(FaultSpec(seed=0, crash_fingerprints={fp}))
    with pytest.raises(HardFault):
        crash.time(prog)
    with pytest.raises(HardFault):
        crash.time(list(reversed(prog)))     # every permutation crashes
    assert crash.time(other) == Machine().time(other)  # siblings untouched


# ---------------------------------------------------------------------------
# ResilientBackend: retry, timeout, robust statistics, breaker
# ---------------------------------------------------------------------------

def test_resilient_retries_transients_to_exact_value(kernel_programs):
    prog = _scheduled(kernel_programs)
    rb = ResilientBackend(
        FastTimingBackend(_faulty_factory(seed=1, transient_rate=0.5)),
        policy=RetryPolicy(max_retries=10))
    for _ in range(5):
        assert rb.time(prog) == Machine().time(prog)   # retried, bit-exact
    s = rb.stats()
    assert s["transients"] > 0 and s["retries"] == s["transients"]
    assert s["measures"] == 5 and not rb.circuit_open

    # zero retry budget -> exhaustion is a loud typed failure
    dead = ResilientBackend(
        FastTimingBackend(_faulty_factory(seed=1, transient_rate=1.0)),
        policy=RetryPolicy(max_retries=3, breaker_threshold=99))
    with pytest.raises(MeasureExhausted):
        dead.time(prog)
    assert dead.stats()["exhausted"] == 1


def test_resilient_timeout_detects_hangs(kernel_programs):
    prog = _scheduled(kernel_programs)
    rb = ResilientBackend(
        FastTimingBackend(_faulty_factory(seed=0, hang_rate=1.0,
                                          hang_s=0.03)),
        policy=RetryPolicy(max_retries=2, timeout_s=0.005,
                           breaker_threshold=99))
    with pytest.raises(MeasureExhausted) as ei:
        rb.time(prog)
    assert isinstance(ei.value.__cause__, MeasureTimeout)
    assert rb.stats()["timeouts"] == 3       # every attempt blew the deadline

    # a generous deadline lets the (slow) measurement through
    ok = ResilientBackend(
        FastTimingBackend(_faulty_factory(seed=0, hang_rate=1.0,
                                          hang_s=0.001)),
        policy=RetryPolicy(timeout_s=5.0))
    assert ok.time(prog) == Machine().time(prog)


def test_resilient_outlier_rejection_and_adaptive_k(kernel_programs):
    prog = _scheduled(kernel_programs)
    rb = ResilientBackend(
        FastTimingBackend(_faulty_factory(seed=2, outlier_rate=0.4,
                                          outlier_scale=100.0)),
        policy=RetryPolicy(samples=3, max_samples=16))
    vals = [rb.time(prog) for _ in range(6)]
    clean = Machine().time(prog)
    assert vals == [clean] * 6     # median + MAD rejection kills the spikes
    s = rb.stats()
    assert s["outliers_rejected"] > 0
    assert s["sample_escalations"] > 0       # high variance widened k


def test_circuit_breaker_degrades_to_scoreboard(kernel_programs):
    prog = _scheduled(kernel_programs)
    fp = schedule_fingerprint(prog)
    rb = ResilientBackend(
        FastTimingBackend(_faulty_factory(seed=0, crash_fingerprints={fp})),
        policy=RetryPolicy(max_retries=1, breaker_threshold=3))
    for _ in range(2):
        with pytest.raises(HardFault):
            rb.time(prog)
        assert not rb.circuit_open           # below the threshold
    # third consecutive hard failure trips the breaker; the call itself is
    # already served by the deterministic scoreboard fallback
    assert rb.time(prog) == Machine().time(prog)
    assert rb.circuit_open
    assert rb.time(prog) == Machine().time(prog)     # degraded steady state
    s = rb.stats()
    assert s["breaker_trips"] == 1 and s["open_breakers"] == 1
    assert s["degraded"] >= 2
    assert "OPEN" in rb.summary()

    # machines the backend hands out degrade too (the game / verify path),
    # with real dataflow results from the fallback oracle
    m = rb.new_machine()
    assert m.run(prog).cycles == Machine().run(prog).cycles

    # a success before the threshold resets the consecutive count: one
    # crashing cell does not degrade an otherwise healthy target
    healthy = ResilientBackend(
        FastTimingBackend(_faulty_factory(seed=0, crash_fingerprints={fp})),
        policy=RetryPolicy(max_retries=1, breaker_threshold=3))
    other = _scheduled(kernel_programs, "softmax")
    for _ in range(5):
        with pytest.raises(HardFault):
            healthy.time(prog)
        assert healthy.time(other) == Machine().time(other)
    assert not healthy.circuit_open


def test_resilient_passthrough_and_for_target_isolation(kernel_programs):
    prog = _scheduled(kernel_programs)
    rb = make_backend("resilient")           # registered, over fast timing
    assert rb.name == "resilient[fast]"
    # deterministic inner -> machines/memo pass straight through (the
    # memoized fast path stays enabled and bit-exact)
    assert type(rb.new_machine()) is Machine
    assert rb.memo_view(prog, "k") is not None
    assert rb.time(prog) == Machine().time(prog)

    # per-target breakers: wedging one target leaves its sibling closed
    faulty = ResilientBackend(
        FastTimingBackend(_faulty_factory(seed=1, transient_rate=1.0)),
        policy=RetryPolicy(max_retries=0, breaker_threshold=1))
    sibling = faulty.for_target(Machine)
    # threshold 1: the very first exhaustion trips the breaker and the
    # call itself is already served by the degraded fallback
    assert faulty.time(prog) == Machine().time(prog)
    assert faulty.circuit_open and not sibling.circuit_open
    assert sibling.time(prog) == Machine().time(prog)
    agg = faulty.stats()                      # summary aggregates the family
    assert agg["targets"] == 2 and agg["open_breakers"] == 1


# ---------------------------------------------------------------------------
# optimize_many supervision (threaded partial results + verify failures)
# ---------------------------------------------------------------------------

class _MangleStrategy:
    """Returns a schedule with one true-dependent pair swapped — the
    masking-bug shape probabilistic testing (§4.1) exists to catch."""

    name = "mangle"

    def search(self, program, *, stall_db, backend, owner="", verbose=False):
        bad = [ins.copy() for ins in program]
        for i in range(len(bad) - 1):
            a, b = bad[i], bad[i + 1]
            if a.defs and b.uses and set(a.defs) & set(b.uses):
                bad[i], bad[i + 1] = b, a
                break
        cycles = backend.time(bad, owner)
        return SearchOutcome(best_program=bad, best_cycles=cycles,
                             baseline_cycles=cycles, stats=[])


def test_verify_failure_refuses_to_cache(tmp_path, stall_db):
    session = OptimizationSession(strategy=_MangleStrategy(),
                                  cache_dir=str(tmp_path / "cache"),
                                  stall_db=stall_db, verify_seeds=2)
    with pytest.raises(RuntimeError, match="probabilistic testing FAILED"):
        session.optimize(OptimizeRequest(kernel="rmsnorm",
                                         config={"br": 8, "cols": 2048}))
    # the mangled schedule must NOT have been cached
    assert session.cache.lookup_best("rmsnorm") is None


def test_optimize_many_collects_partial_results(tmp_path, stall_db):
    tiny = make_budgeted_strategy("random", timesteps=16, episode_length=8)
    session = OptimizationSession(strategy=tiny,
                                  cache_dir=str(tmp_path / "cache"),
                                  stall_db=stall_db, verify_seeds=2)
    cfg = {"br": 8, "cols": 2048}
    reqs = [OptimizeRequest(kernel="rmsnorm", config=cfg),
            OptimizeRequest(kernel="rmsnorm", config=cfg,
                            strategy=_MangleStrategy(), force=True),
            OptimizeRequest(kernel="softmax", config={"br": 8, "cols": 4096})]

    # threaded collect: the failing sibling is captured, the healthy ones
    # complete and return (the old pool.map discarded them all)
    outcomes = session.optimize_many(reqs, max_workers=3, on_error="collect")
    assert [o.ok for o in outcomes] == [True, False, True]
    failure = outcomes[1]
    assert isinstance(failure, OptimizeFailure)
    assert failure.error_type == "RuntimeError"
    assert "probabilistic testing FAILED" in failure.error
    assert outcomes[0].artifact is not None and outcomes[2].artifact is not None

    # legacy contract: on_error="raise" still propagates the first error
    with pytest.raises(RuntimeError, match="probabilistic testing FAILED"):
        session.optimize_many([reqs[1]], max_workers=2, on_error="raise")
    with pytest.raises(ValueError, match="on_error"):
        session.optimize_many(reqs, on_error="ignore")


# ---------------------------------------------------------------------------
# the acceptance campaign: 20% transients + one always-crashing cell,
# scenarios × targets, bit-exact healthy cells, resumable ledger
# ---------------------------------------------------------------------------

def _campaign_session(cache_dir, stall_db, backend):
    return OptimizationSession(
        backend=backend,
        strategy=make_budgeted_strategy("random", timesteps=16,
                                        episode_length=8),
        cache_dir=str(cache_dir), stall_db=stall_db, verify_seeds=2)


def test_supervised_campaign_with_faults_matches_fault_free_run(
        tmp_path, stall_db):
    scens = parse_scenarios("4x512,8x4096")
    targets = [get_target("tpu-tsass-v1"), get_target("tpu-tsass-v2")]
    units = [(n, s) for n in ("rmsnorm", "softmax") for s in scens]
    reqs = campaign_requests(units, targets)
    assert len(reqs) == 8                     # 2 kernels × 2 scens × 2 tgts

    # the always-crashing cell: softmax @ scens[1] @ tpu-tsass-v1.  Pin
    # the schedules unique to that workload point (configs clamped to the
    # same spec at both points share a fingerprint — pinning those would
    # crash the sibling scenario too), so some autotune measurement in
    # that cell — and only that cell — hard-faults, every pass
    from repro.kernels import get_kernel
    kd = get_kernel("softmax")

    def fps_at(scen):
        return {schedule_fingerprint(baseline.schedule(lower(
            build_spec(kd.make_spec, cfg, scen)))) for cfg in kd.configs}

    crash_fps = fps_at(scens[1]) - fps_at(scens[0])
    assert crash_fps                          # the scenarios do differ
    crash_cell = cell_key("softmax", scens[1], targets[0])

    # fault-free reference campaign (its own cache dir)
    ref = _campaign_session(tmp_path / "ref", stall_db, FastTimingBackend())
    ref_results = ref.optimize_many(reqs)
    assert all(r.ok for r in ref_results)

    # faulty campaign: v1 measures through 20% transients + the crash
    # pins; v2 siblings (via for_target) stay clean
    faulty = ResilientBackend(
        FastTimingBackend(_faulty_factory(
            seed=5, transient_rate=0.2, crash_fingerprints=crash_fps)),
        policy=RetryPolicy(max_retries=8))
    session = _campaign_session(tmp_path / "run", stall_db, faulty)
    ledger = FailureLedger(str(tmp_path / "run" / "campaign_state.json"))

    results = session.optimize_many(reqs, ledger=ledger, max_retries=1)
    by_cell = {session._cell_key(r): out
               for r, out in zip(reqs, results)}

    # exactly the crashing cell failed, with its attempt recorded
    fails = {c: o for c, o in by_cell.items() if not o.ok}
    assert set(fails) == {crash_cell}
    assert fails[crash_cell].error_type == "HardFault"
    assert fails[crash_cell].attempts == 1
    assert set(ledger.failed_cells()) == {crash_cell}
    assert ledger.attempts(crash_cell) == 1
    assert not faulty.circuit_open            # healthy successes reset it

    # every healthy cell is bit-exact vs the fault-free campaign
    # (schedule text AND measured cycles — the memo-backed values agree)
    ref_by_cell = {ref._cell_key(r): out
                   for r, out in zip(reqs, ref_results)}
    for cell, out in by_cell.items():
        if cell == crash_cell:
            continue
        want = ref_by_cell[cell]
        assert program_text(out.artifact.program) == \
            program_text(want.artifact.program), cell
        assert out.artifact.optimized_cycles == \
            want.artifact.optimized_cycles, cell
        assert out.artifact.baseline_cycles == \
            want.artifact.baseline_cycles, cell
        assert not out.degraded

    # resume pass: healthy cells are pure cache hits, ONLY the crashing
    # cell re-runs its search — and fails again (attempts -> 2)
    resume = session.optimize_many(reqs, ledger=ledger, max_retries=1)
    by_cell2 = {session._cell_key(r): o for r, o in zip(reqs, resume)}
    for cell, o in by_cell2.items():
        if cell == crash_cell:
            assert not o.ok and o.attempts == 2 and not o.skipped
        else:
            assert o.ok and o.from_cache
    assert ledger.attempts(crash_cell) == 2

    # third pass: the retry budget (max_retries=1 -> 2 total attempts) is
    # spent; the cell is skipped without re-running, attempts unchanged
    third = session.optimize_many(reqs, ledger=ledger, max_retries=1)
    crash_out = {session._cell_key(r): o
                 for r, o in zip(reqs, third)}[crash_cell]
    assert crash_out.skipped and crash_out.attempts == 2
    assert ledger.attempts(crash_cell) == 2

    # the ledger is persistent: a fresh process sees the same state
    reread = FailureLedger(str(tmp_path / "run" / "campaign_state.json"))
    assert reread.attempts(crash_cell) == 2
    assert "HardFault" in reread.failed_cells()[crash_cell]["error"]


def test_failure_ledger_quarantines_corrupt_state(tmp_path):
    path = str(tmp_path / "campaign_state.json")
    led = FailureLedger(path)
    led.record_failure("k@default@t", RuntimeError("boom"), backoff=0.25)
    assert FailureLedger(path).failed_cells()["k@default@t"]["attempts"] == 1
    with open(path, "w") as f:
        f.write("{ not json")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        fresh = FailureLedger(path)
    assert len(fresh) == 0
    assert os.path.exists(path + ".quarantine")
    assert any("quarantine" in str(x.message) for x in w)
    with open(path + ".quarantine") as f:      # the bad payload survives
        assert f.read() == "{ not json"
    os.replace(path + ".quarantine", path)
    with pytest.raises(RuntimeError, match="corrupt campaign ledger"):
        FailureLedger(path, strict=True)


# ---------------------------------------------------------------------------
# memo warm-start quarantine (satellite)
# ---------------------------------------------------------------------------

def test_memo_warm_start_quarantines_corrupt_payload(tmp_path):
    path = str(tmp_path / "measure_memo.pkl")
    memo = SharedMeasureMemo()
    memo.view([], owner="k")[b"key"] = 42.0
    memo.save(path)
    fresh = SharedMeasureMemo()
    assert warm_start_memo(fresh, path) == 1          # healthy roundtrip

    with open(path, "wb") as f:
        f.write(b"\x80\x04 truncated garbage")
    target = SharedMeasureMemo()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert warm_start_memo(target, path) == 0
    assert len(target) == 0
    assert os.path.exists(path + ".quarantine")
    assert any("quarantine" in str(x.message) for x in w)
    assert warm_start_memo(target, path) == 0         # file gone: empty start

    # strict mode keeps the loud pre-campaign failure
    with open(path, "wb") as f:
        pickle.dump({"format": "something-else"}, f)
    with pytest.raises(MemoVersionError):
        warm_start_memo(SharedMeasureMemo(), path, strict=True)
    assert os.path.exists(path)                       # strict never renames


# ---------------------------------------------------------------------------
# cache quarantine + serve degradation (on_missing)
# ---------------------------------------------------------------------------

def _optimized_cache(tmp_path, stall_db, sub="cache"):
    session = OptimizationSession(
        strategy=make_budgeted_strategy("random", timesteps=16,
                                        episode_length=8),
        cache_dir=str(tmp_path / sub), stall_db=stall_db, verify_seeds=2)
    session.optimize(OptimizeRequest(kernel="rmsnorm"))
    return str(tmp_path / sub)


def test_resolve_schedule_quarantines_corrupt_cache(tmp_path, stall_db):
    cache_dir = _optimized_cache(tmp_path, stall_db)
    kdir = os.path.join(cache_dir, "tpu-tsass-v1", "rmsnorm")
    idx = os.path.join(kdir, "index.json")

    # corrupt index, intact sidecar: the index is quarantined with a
    # warning and the artifact still resolves through the v1 fallback
    with open(idx, "w") as f:
        f.write("not json at all")
    cache = ScheduleCache(cache_dir)
    with pytest.raises(CacheVersionError):
        cache.lookup_best("rmsnorm")          # direct lookups stay loud
    with pytest.warns(UserWarning, match="quarantined"):
        art = resolve_schedule(ScheduleCache(cache_dir), "rmsnorm",
                               on_missing="baseline")
    assert art is not None and art.kernel == "rmsnorm"
    assert os.path.exists(idx + ".quarantine") and not os.path.exists(idx)

    # now also corrupt the sidecar: quarantined (taking its .tsass twin),
    # nothing loadable remains -> -O3 baseline fallback, counted
    sidecars = [f for f in os.listdir(kdir) if f.endswith(".json")]
    assert sidecars
    for f in sidecars:
        with open(os.path.join(kdir, f), "w") as fh:
            fh.write('{"version": 999}')
    cache = ScheduleCache(cache_dir)
    with pytest.warns(UserWarning, match="quarantined"):
        art = resolve_schedule(cache, "rmsnorm", on_missing="baseline")
    assert art is None
    assert cache.fallbacks == 1 and cache.stats()["quarantined"] >= 2
    left = os.listdir(kdir)
    assert all(f.endswith(".quarantine") for f in left) and left

    # strict mode: missing -> FileNotFoundError; corrupt -> loud raise
    with pytest.raises(FileNotFoundError, match="on_missing"):
        resolve_schedule(ScheduleCache(cache_dir), "rmsnorm",
                         on_missing="raise")
    corrupt2 = _optimized_cache(tmp_path, stall_db, sub="cache2")
    idx2 = os.path.join(corrupt2, "tpu-tsass-v1", "rmsnorm", "index.json")
    with open(idx2, "w") as f:
        f.write("garbage")
    with pytest.raises(CacheVersionError):
        resolve_schedule(ScheduleCache(corrupt2), "rmsnorm",
                         on_missing="raise")
    assert os.path.exists(idx2)               # strict mode never renames
    with pytest.raises(ValueError, match="on_missing"):
        resolve_schedule(ScheduleCache(corrupt2), "rmsnorm",
                         on_missing="explode")


def test_serve_engine_on_missing_baseline_vs_strict(tmp_path, monkeypatch):
    import jax
    from repro.configs import get_config
    from repro.models import lm
    from repro.serve import ServeEngine

    cfg = get_config("gemma3-1b", reduced=True)
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    empty_cache = str(tmp_path / "empty_cache")
    os.makedirs(empty_cache, exist_ok=True)

    calls = {"run": 0, "time": 0}
    real_run, real_time = Machine.run, Machine.time
    monkeypatch.setattr(Machine, "run",
                        lambda *a, **k: calls.__setitem__("run", 1) or
                        real_run(*a, **k))
    monkeypatch.setattr(Machine, "time",
                        lambda *a, **k: calls.__setitem__("time", 1) or
                        real_time(*a, **k))

    # baseline mode: every kernel serves the -O3 baseline, counted, and
    # serving never touches a Machine
    engine = ServeEngine.from_config(cfg, params=params, max_batch=2,
                                     max_seq=32, schedule_cache=empty_cache,
                                     on_missing="baseline")
    assert engine.plan and all(a is None for a in engine.plan.values())
    assert engine.counters["schedule_fallbacks"] == len(engine.plan) > 0
    req = engine.submit([3, 5, 7], max_new_tokens=4)
    engine.run()
    assert len(req.output) == 4
    assert calls == {"run": 0, "time": 0}     # zero Machine work at serve

    # strict mode refuses to start degraded
    with pytest.raises(FileNotFoundError, match="on_missing"):
        ServeEngine.from_config(cfg, params=params, max_batch=2, max_seq=32,
                                schedule_cache=empty_cache,
                                on_missing="raise")
