"""Data determinism, checkpoint atomicity, fault-tolerance contract."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint
from repro.configs import get_config
from repro.data.pipeline import make_data
from repro.train import InjectedFailure, TrainConfig, Trainer


def test_data_deterministic_by_step():
    cfg = get_config("stablelm-3b", reduced=True)
    d1 = make_data(cfg, 16, 4, seed=7)
    d2 = make_data(cfg, 16, 4, seed=7)
    for step in (0, 5, 123):
        b1, b2 = d1.batch_at(step), d2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])
    assert not np.array_equal(d1.batch_at(0)["tokens"],
                              d1.batch_at(1)["tokens"])
    b = d1.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)},
            "step": jnp.asarray(7)}
    checkpoint.save(str(tmp_path), 7, tree)
    assert checkpoint.latest_step(str(tmp_path)) == 7
    back, step = checkpoint.restore(str(tmp_path), tree)
    assert step == 7
    for k in ("a",):
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))
    assert back["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_latest_pointer_atomic(tmp_path):
    tree = {"x": jnp.zeros(4)}
    checkpoint.save(str(tmp_path), 10, tree)
    checkpoint.save(str(tmp_path), 20, tree)
    assert checkpoint.latest_step(str(tmp_path)) == 20
    # a stale temp dir must not confuse restore
    os.makedirs(os.path.join(str(tmp_path), "step_00000030.tmp"),
                exist_ok=True)
    assert checkpoint.latest_step(str(tmp_path)) == 20


def test_async_checkpointer(tmp_path):
    ck = checkpoint.AsyncCheckpointer()
    tree = {"w": jnp.ones((64, 64))}
    ck.save(str(tmp_path), 1, tree)
    ck.save(str(tmp_path), 2, tree)   # joins the first
    ck.wait()
    assert checkpoint.latest_step(str(tmp_path)) == 2


def test_failure_injection_and_bitwise_resume(tmp_path):
    """The FT contract: kill at step 14, restart from the step-10
    checkpoint, and the final state/losses equal an uninterrupted run."""
    cfg = get_config("stablelm-3b", reduced=True)
    base = dict(steps=20, seq_len=16, global_batch=2, lr=1e-3, warmup=2,
                ckpt_every=10)

    ref = Trainer(cfg, TrainConfig(**base, ckpt_dir=None)).run()

    ckdir = str(tmp_path / "ck")
    failing = Trainer(cfg, TrainConfig(**base, ckpt_dir=ckdir,
                                       fail_at_step=14))
    with pytest.raises(InjectedFailure):
        failing.run()
    assert checkpoint.latest_step(ckdir) == 10

    resumed = Trainer(cfg, TrainConfig(**base, ckpt_dir=ckdir))
    assert resumed.start_step == 10
    log2 = resumed.run()

    ref_tail = {row["step"]: row["loss"] for row in ref}
    for row in log2:
        assert row["loss"] == pytest.approx(ref_tail[row["step"]],
                                            rel=1e-5), row["step"]


def test_elastic_restore_reshards(tmp_path):
    """Restoring onto a different mesh (here: the 1-device host mesh with
    explicit shardings) — the elastic-resize path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    checkpoint.save(str(tmp_path), 3, tree)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    back, _ = checkpoint.restore(str(tmp_path), tree, shardings=sh)
    assert back["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))


def test_straggler_hook_fires():
    cfg = get_config("stablelm-3b", reduced=True)
    events = []
    tcfg = TrainConfig(steps=8, seq_len=16, global_batch=2,
                       straggler_factor=0.0)   # every step is a "straggler"
    t = Trainer(cfg, tcfg, straggler_hook=lambda s, dt: events.append(s))
    t.run()
    assert events, "straggler hook never fired"
