"""Distribution-layer tests.  Multi-device cases run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test process
keeps the host's real (single-device) view."""

import jax
import pytest

from repro.dist import sharding as shd


def _abstract_mesh(shape, names):
    return jax.sharding.AbstractMesh(tuple(shape), tuple(names))


def test_logical_rules_divisibility_fallback():
    mesh = _abstract_mesh((4,), ("model",))
    spec = shd.spec_for_axes(("embed", "mlp"), mesh, (64, 32))
    assert spec == jax.sharding.PartitionSpec(None, "model")
    # non-divisible dims fall back to replication
    spec = shd.spec_for_axes(("embed", "mlp"), mesh, (64, 30))
    assert spec == jax.sharding.PartitionSpec(None, None)


def test_param_shardings_tree_structure():
    from repro.configs import get_config
    from repro.models import lm
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("stablelm-3b", reduced=True)
    sh = shd.param_shardings(lm.model_spec(cfg), mesh)
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    assert jax.tree.structure(sh) == jax.tree.structure(params)


def test_cache_sharding_rules():
    mesh = _abstract_mesh((2, 4), ("data", "model"))
    # kv heads divisible by model -> head sharding
    assert shd.cache_sharding(mesh, 8, 1024, 8)[2] == "model"
    # kv=1 -> sequence sharding
    spec = shd.cache_sharding(mesh, 8, 1024, 1)
    assert spec[1] in ("model", ("model",))
    # batch=1 long context -> sequence over data+model
    spec = shd.cache_sharding(mesh, 1, 1024, 1)
    assert spec[0] is None and set(spec[1]) == {"data", "model"}


_MOE_EP_CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro import nn
from repro.nn.core import init_params
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = nn.MoEConfig(n_experts=8, top_k=2, d_model=32, d_ff=64,
                   capacity_factor=8.0)  # no drops -> exact match
p = init_params(nn.moe_spec(cfg), jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
dense = nn.apply_moe_dense(p, x, cfg)
from repro.nn.moe import apply_moe_ep, apply_moe_ep_replicated
with mesh:
    ep = apply_moe_ep(p, x, cfg, mesh)
    rep = apply_moe_ep_replicated(p, x, cfg, mesh)
np.testing.assert_allclose(np.asarray(ep), np.asarray(dense), atol=2e-4)
np.testing.assert_allclose(np.asarray(rep), np.asarray(dense), atol=2e-4)
print("MOE-EP-OK")
"""


def test_moe_ep_matches_dense(subproc):
    out = subproc(_MOE_EP_CODE, n_devices=8)
    assert "MOE-EP-OK" in out


_GPIPE_CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro.dist.pipeline import gpipe
mesh = jax.make_mesh((4,), ("pipe",))
S, M, mb, d = 4, 8, 2, 16
ws = jax.random.normal(jax.random.PRNGKey(0), (S, d, d)) / d**0.5
def stage_fn(w, x):
    return jnp.tanh(x @ w)
x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
with mesh:
    y = gpipe(stage_fn, ws, x, mesh, axis="pipe")
want = x
for s in range(S):
    want = jnp.tanh(want @ ws[s])
np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-5)
print("GPIPE-OK")
"""


def test_gpipe_matches_sequential(subproc):
    out = subproc(_GPIPE_CODE, n_devices=4)
    assert "GPIPE-OK" in out


_COMPRESS_CODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist.compress import compressed_psum, ef_state
mesh = jax.make_mesh((8,), ("pod",))
g_global = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 1e-3

def step(g_shard, err):
    return compressed_psum({"w": g_shard}, err, "pod")

fn = jax.shard_map(step, mesh=mesh, in_specs=(P("pod"), P("pod")),
                   out_specs=(P(), P("pod")), check_vma=False)
err = {"w": jnp.zeros((8, 64))}
# accumulated error feedback: the *sum over steps* converges to the true
# mean even though each step quantizes to bf16
acc_c = np.zeros(64); acc_t = np.zeros(64)
for i in range(20):
    avg, err = fn(g_global, err)
    acc_c += np.asarray(avg["w"]).reshape(-1)[:64]
    acc_t += np.asarray(g_global.mean(axis=0))
rel = np.abs(acc_c - acc_t).max() / (np.abs(acc_t).max() + 1e-12)
assert rel < 0.02, rel
print("COMPRESS-OK")
"""


def test_compressed_psum_error_feedback(subproc):
    out = subproc(_COMPRESS_CODE, n_devices=8)
    assert "COMPRESS-OK" in out


_SHARDED_TRAIN_CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.specs import lowerable
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(model=2)
cfg = get_config("stablelm-3b", reduced=True)
# run a REAL sharded train step (not just lowering) on the 8-device host
from repro.models import lm
from repro.optim import adamw as adamw_fn, constant_schedule
from repro.train.step import TrainState, make_train_step
from repro.data.pipeline import make_data
params = lm.init_model(cfg, jax.random.PRNGKey(0))
opt = adamw_fn(constant_schedule(1e-3))
state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
batch = make_data(cfg, 16, 4).batch_at(0)
with mesh:
    step = jax.jit(make_train_step(cfg, opt, mesh=mesh))
    state, m = step(state, batch)
assert np.isfinite(float(m["loss"]))
print("SHARDED-TRAIN-OK", float(m["loss"]))
"""


def test_sharded_train_step_runs(subproc):
    out = subproc(_SHARDED_TRAIN_CODE, n_devices=8)
    assert "SHARDED-TRAIN-OK" in out


_HOST_MESH_PIPE_CODE = """
import jax
from repro.launch.mesh import make_host_mesh
# the pipe axis must COMPOSE with data/model, not replace them
mesh = make_host_mesh(pipe=4)
assert dict(mesh.shape) == {"pipe": 4, "data": 2, "model": 1}, mesh.shape
mesh = make_host_mesh(model=2, pipe=2)
assert dict(mesh.shape) == {"pipe": 2, "data": 2, "model": 2}, mesh.shape
mesh = make_host_mesh(pipe=2, pods=2)
assert dict(mesh.shape) == {"pod": 2, "pipe": 2, "data": 2, "model": 1}
print("HOST-MESH-PIPE-OK")
"""


def test_host_mesh_pipe_composes(subproc):
    out = subproc(_HOST_MESH_PIPE_CODE, n_devices=8)
    assert "HOST-MESH-PIPE-OK" in out


# The shard_map pipeline step must match the plain (single-device) jit step
# numerically for every schedule x TP combination: same init, same batches,
# reduced config -> the loss trajectories agree to float tolerance (the
# pipeline only reorders the same math into microbatch stages; TP only
# splits the same matmuls into psum-joined shards).  The two schedules run
# every microbatch through identical per-stage math, so their metrics must
# agree EXACTLY.
_PIPELINE_STEP_CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.data.pipeline import make_data
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.optim import adamw as adamw_fn, constant_schedule
from repro.train.step import TrainState, make_train_step, \\
    make_sharded_train_step
model = MODEL_N
cfg = get_config("stablelm-3b", reduced=True).replace(
    n_layers=4, pipeline_microbatches=4)
pipe = 4 // model
mesh = make_host_mesh(pipe=pipe, model=model)   # 8 devices -> data=2 left
params = lm.init_model(cfg, jax.random.PRNGKey(0))
opt = adamw_fn(constant_schedule(1e-3), weight_decay=0.1, max_grad_norm=1.0)
def fresh():
    return TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
plain = jax.jit(make_train_step(cfg, opt))
gpipe = jax.jit(make_sharded_train_step(cfg, opt, mesh, schedule="gpipe"))
ofob = jax.jit(make_sharded_train_step(cfg, opt, mesh, schedule="1f1b"))
sp, sg, so = fresh(), fresh(), fresh()
data = make_data(cfg, 16, 8)
for i in range(4):
    sp, mp = plain(sp, data.batch_at(i))
    sg, mg = gpipe(sg, data.batch_at(i))
    so, mo = ofob(so, data.batch_at(i))
    lp, lg, lo = float(mp["loss"]), float(mg["loss"]), float(mo["loss"])
    assert np.isfinite(lg)
    assert abs(lp - lg) / abs(lp) < 1e-4, (i, lp, lg)
    # 1F1B reorders micro-ops, not math: exact agreement with gpipe
    assert lo == lg, (i, lo, lg)
    assert float(mo["grad_norm"]) == float(mg["grad_norm"])
assert abs(float(mp["grad_norm"]) - float(mg["grad_norm"])) \\
    / float(mp["grad_norm"]) < 1e-3
print("PIPELINE-STEP-OK", lg)
"""


@pytest.mark.parametrize("model", [1, 2])
def test_sharded_pipeline_step_matches_plain(subproc, model):
    out = subproc(_PIPELINE_STEP_CODE.replace("MODEL_N", str(model)),
                  n_devices=8)
    assert "PIPELINE-STEP-OK" in out


def test_schedule_tables_cover_all_ops_once():
    from repro.dist.pipeline import SCHEDULES
    for name, cls in SCHEDULES.items():
        for S, M in ((2, 4), (4, 8), (4, 2), (3, 5)):
            table = cls().table(M, S)
            fwd = {(o.stage, o.micro) for o in table if o.phase == "F"}
            bwd = {(o.stage, o.micro) for o in table if o.phase == "B"}
            want = {(s, m) for s in range(S) for m in range(M)}
            assert fwd == bwd == want, (name, S, M)
            assert len(table) == 2 * S * M, (name, S, M)


def test_1f1b_bounds_peak_live_activations():
    """The point of the schedule: for n_micro > n_stages, 1F1B holds at
    most min(S, M) microbatch activations live per stage where gpipe holds
    all M."""
    from repro.dist.pipeline import GPipeSchedule, OneFOneBSchedule
    g, o = GPipeSchedule(), OneFOneBSchedule()
    for S, M in ((2, 8), (4, 8), (3, 12)):
        assert g.peak_live_microbatches(M, S) == M
        assert o.peak_live_microbatches(M, S) == min(S, M)
        assert o.peak_live_microbatches(M, S) < g.peak_live_microbatches(M, S)
        # same bubble: 1F1B trades memory, not throughput
        assert abs(g.bubble_fraction(M, S) - o.bubble_fraction(M, S)) < 1e-9
    # M <= S: both schedules bottom out at M in-flight
    assert o.peak_live_microbatches(2, 4) == 2


def test_get_schedule_rejects_unknown_names():
    from repro.dist.pipeline import get_schedule
    with pytest.raises(ValueError, match="1f1b"):
        get_schedule("pipedream-2bw")
    assert get_schedule("1f1b").name == "1f1b"
    assert get_schedule(get_schedule("gpipe")).name == "gpipe"


# Multi-pod: gradients must actually route through compressed_psum (the
# module function is wrapped with a counter, the error-feedback residual
# must become nonzero), and the compressed trajectory must track the fp32
# psum trajectory within tolerance over several steps.
_MULTIPOD_STEP_CODE = """
import jax, jax.numpy as jnp, numpy as np
import repro.dist.compress as comp
calls = []
orig = comp.compressed_psum
comp.compressed_psum = lambda *a, **k: (calls.append(1), orig(*a, **k))[1]
from repro.configs import get_config
from repro.data.pipeline import make_data
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.optim import adamw as adamw_fn, constant_schedule
from repro.train.step import TrainState, init_ef_state, \
    make_sharded_train_step, wants_ef
cfg = get_config("stablelm-3b", reduced=True).replace(
    n_layers=4, pipeline_microbatches=2)
mesh = make_host_mesh(pipe=2, pods=2)  # (pod=2, pipe=2, data=2, model=1)
assert wants_ef(cfg, mesh)
params = lm.init_model(cfg, jax.random.PRNGKey(0))
opt = adamw_fn(constant_schedule(1e-3), weight_decay=0.1, max_grad_norm=1.0)
sc = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32),
                init_ef_state(params, mesh))
sf = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
step_c = jax.jit(make_sharded_train_step(cfg, opt, mesh))
step_f = jax.jit(make_sharded_train_step(cfg, opt, mesh,
                                         compress_pod=False))
# the overlapped (per-group, stage-first) reduction is a pure reordering
# of the same elementwise quantize+psum: bit-identical trajectory
so = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32),
                init_ef_state(params, mesh))
step_o = jax.jit(make_sharded_train_step(cfg, opt, mesh,
                                         overlap_pod_reduce=False))
data = make_data(cfg, 16, 8)
for i in range(5):
    sc, mc = step_c(sc, data.batch_at(i))
    sf, mf = step_f(sf, data.batch_at(i))
    so, mo = step_o(so, data.batch_at(i))
    assert float(mo["loss"]) == float(mc["loss"]), (i, "overlap changed math")
assert calls, "compressed_psum was never invoked"
ef_l1 = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(sc.ef))
assert ef_l1 > 0, "error-feedback residual stayed zero: no quantization"
lc, lf = float(mc["loss"]), float(mf["loss"])
assert np.isfinite(lc) and abs(lc - lf) / abs(lf) < 2e-2, (lc, lf)
print("MULTIPOD-COMPRESS-OK", lc, lf, ef_l1)
"""


def test_multipod_grads_route_through_compressed_psum(subproc):
    out = subproc(_MULTIPOD_STEP_CODE, n_devices=8)
    assert "MULTIPOD-COMPRESS-OK" in out


_PIPE_LOWERABLE_CODE = """
import jax
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.specs import lowerable, sharded_train_lowerable
cfg = get_config("stablelm-3b", reduced=True).replace(
    n_layers=4, pipeline_microbatches=4)
# lowerable() routes train cells on a pipe mesh through the sharded step
fn, args = lowerable(cfg, "train_4k", make_host_mesh(pipe=4))
assert jax.jit(fn).lower(*args) is not None
# and the multi-pod variant carries error-feedback state in its sds
cfg2 = cfg.replace(pipeline_microbatches=2)
mesh2 = make_host_mesh(pipe=2, pods=2)
fn2, (state_sds, batch_sds) = sharded_train_lowerable(cfg2, mesh2, seq=16,
                                                      batch=8)
assert state_sds.ef is not None
assert jax.jit(fn2).lower(state_sds, batch_sds) is not None
print("PIPE-LOWERABLE-OK")
"""


def test_pipe_mesh_lowerable(subproc):
    out = subproc(_PIPE_LOWERABLE_CODE, n_devices=8)
    assert "PIPE-LOWERABLE-OK" in out
