"""Distribution-layer tests.  Multi-device cases run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test process
keeps the host's real (single-device) view."""

import jax
import numpy as np
import pytest

from repro.dist import sharding as shd


def _abstract_mesh(shape, names):
    return jax.sharding.AbstractMesh(tuple(shape), tuple(names))


def test_logical_rules_divisibility_fallback():
    mesh = _abstract_mesh((4,), ("model",))
    spec = shd.spec_for_axes(("embed", "mlp"), mesh, (64, 32))
    assert spec == jax.sharding.PartitionSpec(None, "model")
    # non-divisible dims fall back to replication
    spec = shd.spec_for_axes(("embed", "mlp"), mesh, (64, 30))
    assert spec == jax.sharding.PartitionSpec(None, None)


def test_param_shardings_tree_structure():
    from repro.configs import get_config
    from repro.models import lm
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("stablelm-3b", reduced=True)
    sh = shd.param_shardings(lm.model_spec(cfg), mesh)
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    assert jax.tree.structure(sh) == jax.tree.structure(params)


def test_cache_sharding_rules():
    mesh = _abstract_mesh((2, 4), ("data", "model"))
    # kv heads divisible by model -> head sharding
    assert shd.cache_sharding(mesh, 8, 1024, 8)[2] == "model"
    # kv=1 -> sequence sharding
    spec = shd.cache_sharding(mesh, 8, 1024, 1)
    assert spec[1] in ("model", ("model",))
    # batch=1 long context -> sequence over data+model
    spec = shd.cache_sharding(mesh, 1, 1024, 1)
    assert spec[0] is None and set(spec[1]) == {"data", "model"}


_MOE_EP_CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro import nn
from repro.nn.core import init_params
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = nn.MoEConfig(n_experts=8, top_k=2, d_model=32, d_ff=64,
                   capacity_factor=8.0)  # no drops -> exact match
p = init_params(nn.moe_spec(cfg), jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
dense = nn.apply_moe_dense(p, x, cfg)
from repro.nn.moe import apply_moe_ep, apply_moe_ep_replicated
with mesh:
    ep = apply_moe_ep(p, x, cfg, mesh)
    rep = apply_moe_ep_replicated(p, x, cfg, mesh)
np.testing.assert_allclose(np.asarray(ep), np.asarray(dense), atol=2e-4)
np.testing.assert_allclose(np.asarray(rep), np.asarray(dense), atol=2e-4)
print("MOE-EP-OK")
"""


def test_moe_ep_matches_dense(subproc):
    out = subproc(_MOE_EP_CODE, n_devices=8)
    assert "MOE-EP-OK" in out


_GPIPE_CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro.dist.pipeline import gpipe
mesh = jax.make_mesh((4,), ("pipe",))
S, M, mb, d = 4, 8, 2, 16
ws = jax.random.normal(jax.random.PRNGKey(0), (S, d, d)) / d**0.5
def stage_fn(w, x):
    return jnp.tanh(x @ w)
x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
with mesh:
    y = gpipe(stage_fn, ws, x, mesh, axis="pipe")
want = x
for s in range(S):
    want = jnp.tanh(want @ ws[s])
np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-5)
print("GPIPE-OK")
"""


def test_gpipe_matches_sequential(subproc):
    out = subproc(_GPIPE_CODE, n_devices=4)
    assert "GPIPE-OK" in out


_COMPRESS_CODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist.compress import compressed_psum, ef_state
mesh = jax.make_mesh((8,), ("pod",))
g_global = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 1e-3

def step(g_shard, err):
    return compressed_psum({"w": g_shard}, err, "pod")

fn = jax.shard_map(step, mesh=mesh, in_specs=(P("pod"), P("pod")),
                   out_specs=(P(), P("pod")), check_vma=False)
err = {"w": jnp.zeros((8, 64))}
# accumulated error feedback: the *sum over steps* converges to the true
# mean even though each step quantizes to bf16
acc_c = np.zeros(64); acc_t = np.zeros(64)
for i in range(20):
    avg, err = fn(g_global, err)
    acc_c += np.asarray(avg["w"]).reshape(-1)[:64]
    acc_t += np.asarray(g_global.mean(axis=0))
rel = np.abs(acc_c - acc_t).max() / (np.abs(acc_t).max() + 1e-12)
assert rel < 0.02, rel
print("COMPRESS-OK")
"""


def test_compressed_psum_error_feedback(subproc):
    out = subproc(_COMPRESS_CODE, n_devices=8)
    assert "COMPRESS-OK" in out


_SHARDED_TRAIN_CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.specs import lowerable
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(model=2)
cfg = get_config("stablelm-3b", reduced=True)
# run a REAL sharded train step (not just lowering) on the 8-device host
from repro.models import lm
from repro.optim import adamw as adamw_fn, constant_schedule
from repro.train.step import TrainState, make_train_step
from repro.data.pipeline import make_data
params = lm.init_model(cfg, jax.random.PRNGKey(0))
opt = adamw_fn(constant_schedule(1e-3))
state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
batch = make_data(cfg, 16, 4).batch_at(0)
with mesh:
    step = jax.jit(make_train_step(cfg, opt, mesh=mesh))
    state, m = step(state, batch)
assert np.isfinite(float(m["loss"]))
print("SHARDED-TRAIN-OK", float(m["loss"]))
"""


def test_sharded_train_step_runs(subproc):
    out = subproc(_SHARDED_TRAIN_CODE, n_devices=8)
    assert "SHARDED-TRAIN-OK" in out
