"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + finite values, plus decode-path checks.
This is deliverable (f)'s smoke-test requirement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.data.pipeline import make_data
from repro.models import for_config
from repro.optim import adamw, constant_schedule
from repro.serve import decode_step, init_caches
from repro.train.step import TrainState, make_train_step

SEQ, BATCH = 32, 2


@pytest.fixture(scope="module")
def trained():
    """arch -> (cfg, params) cache shared across tests in this module."""
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch, reduced=True)
        model = for_config(cfg)
        out[arch] = (cfg, model.init_model(cfg, jax.random.PRNGKey(0)))
    return out


@pytest.mark.parametrize("arch", list(ARCHS))
def test_train_step_smoke(arch, trained):
    cfg, params = trained[arch]
    batch = make_data(cfg, SEQ, BATCH).batch_at(0)
    opt = adamw(constant_schedule(1e-3))
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    step = jax.jit(make_train_step(cfg, opt))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state.step) == 1


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).family != "encdec"])
def test_forward_shapes(arch, trained):
    cfg, params = trained[arch]
    from repro.models import lm
    tokens = jnp.zeros((BATCH, SEQ), jnp.int32)
    logits = jax.jit(lambda p, t: lm.forward(p, t, cfg))(params, tokens)
    assert logits.shape == (BATCH, SEQ, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", list(ARCHS))
def test_decode_step_smoke(arch, trained):
    cfg, params = trained[arch]
    caches = init_caches(cfg, BATCH, SEQ)
    token = jnp.zeros((BATCH, 1), jnp.int32)
    fn = jax.jit(lambda p, c, t, pos: decode_step(p, c, t, pos, cfg))
    logits, caches = fn(params, caches, token, 0)
    assert logits.shape == (BATCH, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    logits2, _ = fn(params, caches, token, 1)
    assert not np.allclose(np.asarray(logits), np.asarray(logits2))


@pytest.mark.parametrize("arch", ["gemma3-1b", "mamba2-1.3b"])
def test_decode_consistent_with_forward(arch, trained):
    """Greedy decode over a teacher-forced prompt must reproduce the
    forward logits at every position."""
    cfg, params = trained[arch]
    from repro.models import lm
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, 12)), jnp.int32)
    full = lm.forward(params, tokens, cfg)
    caches = init_caches(cfg, 1, 12)
    for pos in range(8):
        logits, caches = decode_step(params, caches, tokens[:, pos:pos + 1],
                                     pos, cfg)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, pos]), atol=2e-3,
                                   err_msg=f"{arch} pos={pos}")


def test_loss_decreases_stablelm():
    cfg = get_config("stablelm-3b", reduced=True)
    from repro.train import TrainConfig, Trainer
    tcfg = TrainConfig(steps=25, seq_len=32, global_batch=4, lr=5e-3,
                       warmup=2, ckpt_dir=None)
    log = Trainer(cfg, tcfg).run()
    assert log[-1]["loss"] < log[0]["loss"]


def test_generate_shapes():
    cfg = get_config("qwen1.5-4b", reduced=True)
    from repro.models import lm
    from repro.serve import generate
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    prompt = jnp.zeros((2, 4), jnp.int32)
    out = jax.jit(lambda p, t: generate(p, cfg, t, 6))(params, prompt)
    assert out.shape == (2, 10)


def test_window_schedule_gemma_pattern():
    from repro.models.lm import window_schedule
    from repro.nn.attention import NO_WINDOW
    cfg = get_config("gemma3-1b")
    ws = window_schedule(cfg)
    assert len(ws) == 26
    assert (ws == NO_WINDOW).sum() == 4            # layers 5, 11, 17, 23
    assert ws[5] == NO_WINDOW and ws[0] == 512
    # 5 local : 1 global within each full period
    assert list(ws[:6]).count(512) == 5


def test_param_count_estimates():
    """n_params() tracks the actual initialized parameter count."""
    from repro.utils.tree import param_count
    for arch in ["stablelm-3b", "gemma3-1b", "mamba2-1.3b"]:
        cfg = get_config(arch, reduced=True)
        model = for_config(cfg)
        params = model.init_model(cfg, jax.random.PRNGKey(0))
        actual = param_count(params)
        est = cfg.n_params()
        assert 0.4 < est / actual < 2.5, (arch, est, actual)
