"""Optimizer/schedule math vs hand-rolled numpy references."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adam, adamw, constant_schedule, cosine_schedule,
                         linear_schedule, linear_warmup_cosine)
from repro.optim.adamw import apply_updates


def test_adamw_matches_numpy_reference():
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.1
    opt = adamw(constant_schedule(lr), b1=b1, b2=b2, eps=eps, weight_decay=wd)
    w = jnp.asarray(np.random.default_rng(0).standard_normal((4, 3)),
                    jnp.float32)
    params = {"w": w}
    state = opt.init(params)
    m = np.zeros((4, 3)); v = np.zeros((4, 3))
    wn = np.asarray(w)
    for t in range(1, 6):
        g = np.full((4, 3), 0.5, np.float32) * t
        updates, state = opt.update({"w": jnp.asarray(g)}, state, params)
        params = apply_updates(params, updates)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / (1 - b1 ** t), v / (1 - b2 ** t)
        wn = wn - lr * (mh / (np.sqrt(vh) + eps) + wd * wn)
        np.testing.assert_allclose(np.asarray(params["w"]), wn, atol=1e-5)


def test_weight_decay_skips_1d_params():
    opt = adamw(constant_schedule(1e-2), weight_decay=1.0)
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    state = opt.init(params)
    zero = jax.tree.map(jnp.zeros_like, params)
    updates, _ = opt.update(zero, state, params)
    assert float(jnp.abs(updates["w"]).sum()) > 0    # decayed
    assert float(jnp.abs(updates["b"]).sum()) == 0   # not decayed


def test_grad_clipping():
    opt = adam(constant_schedule(1.0), max_grad_norm=1e-6)
    params = {"w": jnp.ones((8,))}
    state = opt.init(params)
    huge = {"w": jnp.full((8,), 1e9)}
    updates, _ = opt.update(huge, state, params)
    assert np.isfinite(np.asarray(updates["w"])).all()


def test_schedules():
    s = linear_schedule(1.0, 100)
    assert float(s(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(s(jnp.asarray(100))) == pytest.approx(0.0)
    c = cosine_schedule(1.0, 100, min_frac=0.1)
    assert float(c(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(c(jnp.asarray(100))) == pytest.approx(0.1)
    w = linear_warmup_cosine(1.0, 10, 100)
    assert float(w(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(w(jnp.asarray(10))) <= 1.0


def test_bf16_params_keep_f32_moments():
    opt = adamw(constant_schedule(1e-3))
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = opt.init(params)
    assert state.mu["w"].dtype == jnp.float32
    updates, _ = opt.update({"w": jnp.ones((4, 4), jnp.bfloat16)},
                            state, params)
    assert updates["w"].dtype == jnp.bfloat16
