"""Learned cost-model subsystem tests: memo export, dataset build /
serialization, featurizer, model training, env reordering primitives,
lazy strategy registration, and the headline acceptance criteria
(held-out Spearman >= 0.8; beam-cost matching greedy's best cycles on
<= 25% of its real measurements)."""

import numpy as np
import pytest

from repro.core.env import AssemblyGame
from repro.costmodel import (CostDataset, CostModel, CostModelVersionError,
                             ProgramFeaturizer, evaluate_strategies)
from repro.costmodel.dataset import FEATURE_DIM
from repro.sched.backends import FastTimingBackend
from repro.sched.session import (STRATEGIES, GreedySwapStrategy,
                                 make_budgeted_strategy, make_strategy)

KERNEL = "matmul_leakyrelu"


@pytest.fixture(scope="module")
def warm_backend(stall_db, kernel_programs):
    """A FastTimingBackend whose memo holds a greedy run's measurements."""
    backend = FastTimingBackend()
    GreedySwapStrategy(max_steps=8).search(
        kernel_programs[KERNEL], stall_db=stall_db, backend=backend,
        owner=KERNEL)
    return backend


@pytest.fixture(scope="module")
def warm_dataset(warm_backend, stall_db, kernel_programs):
    return CostDataset.from_memo(
        warm_backend.memo, {KERNEL: kernel_programs[KERNEL]},
        stall_db=stall_db)


# ---------------------------------------------------------------------------
# memo export
# ---------------------------------------------------------------------------

def test_export_entries_roundtrip(warm_backend, kernel_programs):
    memo = warm_backend.memo
    entries = list(memo.export_entries())
    assert len(entries) == memo.stats()["entries"] > 0
    n = len(kernel_programs[KERNEL])
    for e in entries:
        assert e.cycles > 0
        assert e.writer == KERNEL
        if e.permutation is not None:
            assert sorted(e.permutation.tolist()) == list(range(n))
    # at least one non-root schedule came through with its permutation
    assert sum(e.permutation is not None for e in entries) > 1


# ---------------------------------------------------------------------------
# featurizer
# ---------------------------------------------------------------------------

def test_featurizer_is_order_sensitive(stall_db, kernel_programs):
    prog = kernel_programs[KERNEL]
    fz = ProgramFeaturizer(prog, stall_db=stall_db)
    env = AssemblyGame(prog, stall_db=stall_db, episode_length=4)
    root = env.id_at.copy()
    q = env.action_swap_pos(env.valid_actions()[0])
    child = root.copy()
    child[q - 1], child[q] = child[q], child[q - 1]
    a, b = fz.features(root), fz.features(child)
    assert a.shape == (FEATURE_DIM,)
    assert not np.array_equal(a, b)
    # features_many stacks the same vectors
    many = fz.features_many([root, child])
    np.testing.assert_array_equal(many[0], a)
    np.testing.assert_array_equal(many[1], b)
    # and is deterministic
    np.testing.assert_array_equal(a, fz.features(root))


# ---------------------------------------------------------------------------
# dataset
# ---------------------------------------------------------------------------

def test_dataset_build_is_deterministic(warm_backend, stall_db,
                                        kernel_programs, warm_dataset):
    again = CostDataset.from_memo(
        warm_backend.memo, {KERNEL: kernel_programs[KERNEL]},
        stall_db=stall_db)
    np.testing.assert_array_equal(warm_dataset.X, again.X)
    np.testing.assert_array_equal(warm_dataset.y, again.y)
    np.testing.assert_array_equal(warm_dataset.group, again.group)
    np.testing.assert_array_equal(warm_dataset.split, again.split)


def test_dataset_split_no_leak(warm_backend, warm_dataset):
    from repro.costmodel.dataset import _split_of
    ds = warm_dataset
    assert len(ds) > 20
    tr, ev = ds.train, ds.eval
    assert len(tr) + len(ev) == len(ds)
    assert len(tr) > 0 and len(ev) > 0
    for entry in warm_backend.memo.export_entries():
        if entry.permutation is None:
            continue
        # the split is a pure function of the schedule's identity (its
        # timing records + permutation) — no dataset-composition leak...
        s = _split_of(entry.records, entry.permutation, 0.25)
        assert s == _split_of(entry.records, entry.permutation, 0.25)
        # ...and widening eval_fraction only ever grows the eval side
        if s == 1:
            assert _split_of(entry.records, entry.permutation, 0.5) == 1
        else:
            assert _split_of(entry.records, entry.permutation, 0.1) == 0


def test_dataset_save_load_roundtrip(tmp_path, warm_dataset):
    path = str(tmp_path / "ds.npz")
    n = warm_dataset.save(path)
    assert n == len(warm_dataset)
    back = CostDataset.load(path)
    np.testing.assert_array_equal(warm_dataset.X, back.X)
    np.testing.assert_array_equal(warm_dataset.y, back.y)
    np.testing.assert_array_equal(warm_dataset.split, back.split)
    assert back.feature_version == warm_dataset.feature_version


def test_dataset_load_rejects_foreign_npz(tmp_path):
    path = str(tmp_path / "other.npz")
    np.savez(path, X=np.zeros((2, 3)), y=np.zeros(2))
    with pytest.raises(CostModelVersionError):
        CostDataset.load(path)


def test_dataset_load_rejects_garbage(tmp_path):
    path = str(tmp_path / "junk.npz")
    with open(path, "wb") as f:
        f.write(b"not an npz payload")
    with pytest.raises(CostModelVersionError):
        CostDataset.load(path)


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def test_model_fit_is_bit_reproducible(warm_dataset):
    m1, h1 = CostModel.fit(warm_dataset, steps=60, seed=3)
    m2, h2 = CostModel.fit(warm_dataset, steps=60, seed=3)
    for k in m1.params:
        np.testing.assert_array_equal(np.asarray(m1.params[k]),
                                      np.asarray(m2.params[k]))
    assert h1 == h2
    # a different seed trains a different model
    m3, _ = CostModel.fit(warm_dataset, steps=60, seed=4)
    assert any(not np.array_equal(np.asarray(m1.params[k]),
                                  np.asarray(m3.params[k]))
               for k in m1.params)


def test_model_save_load_roundtrip(tmp_path, warm_dataset):
    model, _ = CostModel.fit(warm_dataset, steps=60, seed=0)
    path = str(tmp_path / "model.npz")
    model.save(path)
    back = CostModel.load(path)
    X = warm_dataset.X[:16]
    np.testing.assert_allclose(model.predict_log(X), back.predict_log(X),
                               rtol=1e-6)
    assert back.feature_version == model.feature_version


def test_model_load_rejects_foreign_npz(tmp_path):
    path = str(tmp_path / "other.npz")
    np.savez(path, w0=np.zeros((3, 3)))
    with pytest.raises(CostModelVersionError):
        CostModel.load(path)


# ---------------------------------------------------------------------------
# env reordering primitives the search strategies lean on
# ---------------------------------------------------------------------------

def test_set_order_measure_matches_probe(stall_db, kernel_programs):
    prog = kernel_programs[KERNEL]
    env = AssemblyGame(prog, stall_db=stall_db, episode_length=8)
    root = env.id_at.copy()
    q = env.action_swap_pos(env.valid_actions()[0])
    probed = env.probe_swap(q)
    child = root.copy()
    child[q - 1], child[q] = child[q], child[q - 1]
    env.set_order(child)
    assert env.measure_schedule() == probed
    np.testing.assert_array_equal(env.id_at, child)
    # and back: the root re-measures to the baseline
    env.set_order(root)
    assert env.measure_schedule() == env.t0


def test_set_order_rejects_non_permutation(stall_db, kernel_programs):
    env = AssemblyGame(kernel_programs[KERNEL], stall_db=stall_db,
                       episode_length=4)
    bad = env.id_at.copy()
    bad[0] = bad[1]
    with pytest.raises(ValueError):
        env.set_order(bad)


# ---------------------------------------------------------------------------
# lazy strategy registration
# ---------------------------------------------------------------------------

def test_strategies_registry_resolves_lazily():
    assert "beam" in STRATEGIES and "lookahead" in STRATEGIES
    beam = make_strategy("beam", width=2, depth=4, max_measurements=8)
    assert type(beam).__name__ == "BeamSearchStrategy"
    assert beam.name == "beam-oracle"
    la = make_strategy("lookahead", lookahead=2)
    assert type(la).__name__ == "GreedyLookaheadStrategy"
    # after first resolution the registry holds the class itself
    assert not isinstance(STRATEGIES["beam"], str)


def test_make_budgeted_strategy_guided(stall_db, kernel_programs):
    beam = make_budgeted_strategy("beam", timesteps=16, episode_length=4)
    assert beam.max_measurements == 16 and beam.depth == 4
    backend = FastTimingBackend()
    out = beam.search(kernel_programs["bmm"], stall_db=stall_db,
                      backend=backend, owner="bmm")
    assert out.best_cycles <= out.baseline_cycles
    assert backend.memo.stats()["misses"] <= 16 + 1   # root + capped sweep


# ---------------------------------------------------------------------------
# acceptance: the subsystem's headline numbers (fixed seed)
# ---------------------------------------------------------------------------

def test_acceptance_spearman_and_guided_budget(stall_db):
    result = evaluate_strategies(
        strategies=("ppo", "greedy", "beam-cost"), budget=512, seed=0,
        train_steps=1500, stall_db=stall_db)
    # memo-trained model ranks held-out schedules with the oracle
    assert result["rank_correlation"] >= 0.8
    rows = {(r["strategy"], r["kernel"]): r for r in result["rows"]}
    for kernel in ("matmul_leakyrelu", "bmm"):
        greedy = rows[("greedy", kernel)]
        beam = rows[("beam-cost", kernel)]
        # verified best: beam-cost reaches greedy's best cycles...
        assert beam["best_cycles"] <= greedy["best_cycles"]
        # ...spending at most a quarter of greedy's real measurements
        assert beam["measurements"] <= 0.25 * greedy["measurements"]
        assert beam["measurements"] > 0
