"""Single-device unit tests for the dist-layer sharding rules.

Everything here runs on AbstractMesh (no device allocation), so each
``cache_sharding`` branch and the ``spec_for_axes`` divisibility fallback
are covered without the 8-device subprocess harness of test_dist.py.
"""

import jax
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd


def _mesh(shape, names):
    return jax.sharding.AbstractMesh(tuple(shape), tuple(names))


# ---------------------------------------------------------------------------
# spec_for_axes
# ---------------------------------------------------------------------------

def test_spec_for_axes_divisibility_fallback_per_dim():
    mesh = _mesh((2, 4), ("data", "model"))
    # both dims divisible: embed -> data (FSDP), mlp -> model (TP)
    assert shd.spec_for_axes(("embed", "mlp"), mesh, (64, 32)) \
        == P("data", "model")
    # mlp not divisible by model=4 -> only that dim falls back
    assert shd.spec_for_axes(("embed", "mlp"), mesh, (64, 30)) \
        == P("data", None)
    # embed not divisible by data=2 -> only that dim falls back
    assert shd.spec_for_axes(("embed", "mlp"), mesh, (63, 32)) \
        == P(None, "model")


def test_spec_for_axes_missing_mesh_axis_replicates():
    mesh = _mesh((4,), ("model",))
    assert shd.spec_for_axes(("embed", "mlp"), mesh, (64, 32)) \
        == P(None, "model")


def test_spec_for_axes_never_reuses_a_mesh_axis():
    mesh = _mesh((4,), ("model",))
    # vocab and mlp both prefer model; only the first dim gets it
    assert shd.spec_for_axes(("vocab", "mlp"), mesh, (64, 64)) \
        == P("model", None)


def test_spec_for_axes_unknown_and_scan_axes_replicate():
    mesh = _mesh((2, 4), ("data", "model"))
    assert shd.spec_for_axes(("layers", "embed", "mlp"), mesh, (8, 64, 32)) \
        == P(None, "data", "model")
    assert shd.spec_for_axes((None, "nonesuch"), mesh, (8, 8)) == P(None, None)


# ---------------------------------------------------------------------------
# dp helpers / batch_spec
# ---------------------------------------------------------------------------

def test_dp_axes_and_sizes():
    assert shd.dp_axes(_mesh((2, 4), ("data", "model"))) == "data"
    assert shd.dp_axes(_mesh((2, 2, 4), ("pod", "data", "model"))) \
        == ("pod", "data")
    assert shd.dp_axes(_mesh((4,), ("model",))) is None
    assert shd.dp_size(_mesh((2, 2, 4), ("pod", "data", "model"))) == 4
    assert shd.model_size(_mesh((2, 2, 4), ("pod", "data", "model"))) == 4
    assert shd.model_size(_mesh((4,), ("pipe",))) == 1


def test_batch_spec_divisibility_fallback():
    mesh = _mesh((4, 2), ("data", "model"))
    assert shd.batch_spec(mesh, 8) == P("data", None)
    assert shd.batch_spec(mesh, 6) == P(None, None)        # 6 % 4 != 0
    assert shd.batch_spec(mesh, 8, ndim=3) == P("data", None, None)
    assert shd.batch_spec(_mesh((4,), ("pipe",)), 8) == P(None, None)


# ---------------------------------------------------------------------------
# cache_sharding — one test per branch
# ---------------------------------------------------------------------------

def test_cache_sharding_head_branch():
    mesh = _mesh((2, 4), ("data", "model"))
    assert shd.cache_sharding(mesh, 8, 1024, 8) \
        == P("data", None, "model", None)


def test_cache_sharding_mqa_sequence_branch():
    mesh = _mesh((2, 4), ("data", "model"))
    assert shd.cache_sharding(mesh, 8, 1024, 1) \
        == P("data", "model", None, None)
    # kv=2 not divisible by model=4 -> same sequence-sharded branch
    assert shd.cache_sharding(mesh, 8, 1024, 2) \
        == P("data", "model", None, None)


def test_cache_sharding_long_context_branch():
    mesh = _mesh((2, 4), ("data", "model"))
    spec = shd.cache_sharding(mesh, 1, 1024, 1)
    assert spec[0] is None and set(spec[1]) == {"data", "model"}


def test_cache_sharding_full_fallback_replicates():
    mesh = _mesh((2, 4), ("data", "model"))
    # nothing divides: odd batch, prime seq, odd kv heads
    assert shd.cache_sharding(mesh, 3, 1021, 3) == P(None, None, None, None)
    # divisible batch but seq/heads indivisible: batch-only sharding
    assert shd.cache_sharding(mesh, 8, 1021, 3) == P("data", None, None, None)


def test_cache_sharding_model_only_mesh():
    mesh = _mesh((4,), ("model",))
    # no dp axes at all -> sequence over model when divisible
    assert shd.cache_sharding(mesh, 8, 1024, 8) \
        == P(None, ("model",), None, None)


# ---------------------------------------------------------------------------
# decode_cache_shardings leaf classification (shapes only, via eval_shape)
# ---------------------------------------------------------------------------

def test_decode_cache_shardings_covers_all_families():
    from repro.configs import get_config
    from repro.serve.decode import init_caches
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("stablelm-3b", "mamba2-1.3b", "deepseek-v2-lite-16b"):
        cfg = get_config(arch, reduced=True)
        caches = jax.eval_shape(lambda: init_caches(cfg, 2, 64))
        sh = shd.decode_cache_shardings(cfg, caches, mesh)
        assert jax.tree.structure(sh) == jax.tree.structure(caches)
