"""Single-device unit tests for the dist-layer sharding rules.

Everything here runs on AbstractMesh (no device allocation), so each
``cache_sharding`` branch and the ``spec_for_axes`` divisibility fallback
are covered without the 8-device subprocess harness of test_dist.py.
"""

import jax
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd


def _mesh(shape, names):
    return jax.sharding.AbstractMesh(tuple(shape), tuple(names))


# ---------------------------------------------------------------------------
# spec_for_axes
# ---------------------------------------------------------------------------

def test_spec_for_axes_divisibility_fallback_per_dim():
    mesh = _mesh((2, 4), ("data", "model"))
    # both dims divisible: embed -> data (FSDP), mlp -> model (TP)
    assert shd.spec_for_axes(("embed", "mlp"), mesh, (64, 32)) \
        == P("data", "model")
    # mlp not divisible by model=4 -> only that dim falls back
    assert shd.spec_for_axes(("embed", "mlp"), mesh, (64, 30)) \
        == P("data", None)
    # embed not divisible by data=2 -> only that dim falls back
    assert shd.spec_for_axes(("embed", "mlp"), mesh, (63, 32)) \
        == P(None, "model")


def test_spec_for_axes_missing_mesh_axis_replicates():
    mesh = _mesh((4,), ("model",))
    assert shd.spec_for_axes(("embed", "mlp"), mesh, (64, 32)) \
        == P(None, "model")


def test_spec_for_axes_never_reuses_a_mesh_axis():
    mesh = _mesh((4,), ("model",))
    # vocab and mlp both prefer model; only the first dim gets it
    assert shd.spec_for_axes(("vocab", "mlp"), mesh, (64, 64)) \
        == P("model", None)


def test_spec_for_axes_unknown_and_scan_axes_replicate():
    mesh = _mesh((2, 4), ("data", "model"))
    assert shd.spec_for_axes(("layers", "embed", "mlp"), mesh, (8, 64, 32)) \
        == P(None, "data", "model")
    assert shd.spec_for_axes((None, "nonesuch"), mesh, (8, 8)) == P(None, None)


# ---------------------------------------------------------------------------
# dp helpers / batch_spec
# ---------------------------------------------------------------------------

def test_dp_axes_and_sizes():
    assert shd.dp_axes(_mesh((2, 4), ("data", "model"))) == "data"
    assert shd.dp_axes(_mesh((2, 2, 4), ("pod", "data", "model"))) \
        == ("pod", "data")
    assert shd.dp_axes(_mesh((4,), ("model",))) is None
    assert shd.dp_size(_mesh((2, 2, 4), ("pod", "data", "model"))) == 4
    assert shd.model_size(_mesh((2, 2, 4), ("pod", "data", "model"))) == 4
    assert shd.model_size(_mesh((4,), ("pipe",))) == 1


def test_batch_spec_divisibility_fallback():
    mesh = _mesh((4, 2), ("data", "model"))
    assert shd.batch_spec(mesh, 8) == P("data", None)
    assert shd.batch_spec(mesh, 6) == P(None, None)        # 6 % 4 != 0
    assert shd.batch_spec(mesh, 8, ndim=3) == P("data", None, None)
    assert shd.batch_spec(_mesh((4,), ("pipe",)), 8) == P(None, None)


# ---------------------------------------------------------------------------
# cache_sharding — one test per branch
# ---------------------------------------------------------------------------

def test_cache_sharding_head_branch():
    mesh = _mesh((2, 4), ("data", "model"))
    assert shd.cache_sharding(mesh, 8, 1024, 8) \
        == P("data", None, "model", None)


def test_cache_sharding_mqa_sequence_branch():
    mesh = _mesh((2, 4), ("data", "model"))
    assert shd.cache_sharding(mesh, 8, 1024, 1) \
        == P("data", "model", None, None)
    # kv=2 not divisible by model=4 -> same sequence-sharded branch
    assert shd.cache_sharding(mesh, 8, 1024, 2) \
        == P("data", "model", None, None)


def test_cache_sharding_long_context_branch():
    mesh = _mesh((2, 4), ("data", "model"))
    spec = shd.cache_sharding(mesh, 1, 1024, 1)
    assert spec[0] is None and set(spec[1]) == {"data", "model"}


def test_cache_sharding_full_fallback_replicates():
    mesh = _mesh((2, 4), ("data", "model"))
    # nothing divides: odd batch, prime seq, odd kv heads
    assert shd.cache_sharding(mesh, 3, 1021, 3) == P(None, None, None, None)
    # divisible batch but seq/heads indivisible: batch-only sharding
    assert shd.cache_sharding(mesh, 8, 1021, 3) == P("data", None, None, None)


def test_cache_sharding_model_only_mesh():
    mesh = _mesh((4,), ("model",))
    # no dp axes at all -> sequence over model when divisible
    assert shd.cache_sharding(mesh, 8, 1024, 8) \
        == P(None, ("model",), None, None)


# ---------------------------------------------------------------------------
# host mesh shape arithmetic (pipe/pod compose with data/model)
# ---------------------------------------------------------------------------

def test_host_mesh_shape_pipe_composes():
    from repro.launch.mesh import host_mesh_shape
    assert host_mesh_shape(8) == ((8, 1), ("data", "model"))
    assert host_mesh_shape(8, model=2) == ((4, 2), ("data", "model"))
    # pipe no longer replaces data/model — it composes
    assert host_mesh_shape(8, pipe=4) \
        == ((4, 2, 1), ("pipe", "data", "model"))
    assert host_mesh_shape(8, model=2, pipe=2) \
        == ((2, 2, 2), ("pipe", "data", "model"))
    assert host_mesh_shape(8, pipe=2, pods=2) \
        == ((2, 2, 2, 1), ("pod", "pipe", "data", "model"))
    assert host_mesh_shape(16, model=2, pipe=2, pods=2) \
        == ((2, 2, 2, 2), ("pod", "pipe", "data", "model"))


def test_host_mesh_shape_rejects_indivisible():
    import pytest
    from repro.launch.mesh import host_mesh_shape
    with pytest.raises(ValueError):
        host_mesh_shape(8, pipe=3)


def test_production_mesh_pipe_carves_data():
    import pytest
    from repro.launch.mesh import make_production_mesh
    with pytest.raises(ValueError):
        make_production_mesh(pipe=3)   # must divide the 16-way data axis


# ---------------------------------------------------------------------------
# shard_map pipeline-step specs
# ---------------------------------------------------------------------------

def test_sharded_param_specs_split_layers_over_pipe():
    from repro.configs import get_config
    from repro.models import lm
    cfg = get_config("stablelm-3b", reduced=True)
    spec_tree = lm.model_spec(cfg)
    specs = shd.sharded_param_specs(spec_tree)
    assert jax.tree.structure(specs) == jax.tree.structure(
        spec_tree, is_leaf=lambda x: hasattr(x, "axes"))
    flat = jax.tree.leaves(specs["layers"])
    assert flat and all(s == P("pipe") for s in flat)
    assert all(s == P() for s in jax.tree.leaves(specs["embed"]))
    ef = shd.sharded_ef_specs(spec_tree)
    assert all(s == P("pod", "pipe") for s in jax.tree.leaves(ef["layers"]))
    assert all(s == P("pod") for s in jax.tree.leaves(ef["embed"]))


def test_pipe_size_helper():
    assert shd.pipe_size(_mesh((4,), ("pipe",))) == 4
    assert shd.pipe_size(_mesh((2, 4), ("data", "model"))) == 1


def test_make_sharded_train_step_validates_eagerly():
    import pytest
    from repro.configs import get_config
    from repro.optim import adamw as adamw_fn, constant_schedule
    from repro.train.step import make_sharded_train_step
    opt = adamw_fn(constant_schedule(1e-3))
    cfg = get_config("stablelm-3b", reduced=True)
    # no pipe axis
    with pytest.raises(ValueError, match="pipe"):
        make_sharded_train_step(cfg, opt, _mesh((2, 4), ("data", "model")))
    # tensor parallelism composes for dense configs with divisible dims...
    assert make_sharded_train_step(
        cfg, opt, _mesh((2, 2, 2), ("pipe", "data", "model"))) is not None
    # ...but TP dims that do not divide the model axis are rejected
    with pytest.raises(ValueError, match="divisible by model"):
        make_sharded_train_step(
            cfg.replace(d_ff=cfg.d_ff + 1), opt,
            _mesh((2, 2, 2), ("pipe", "data", "model")))
    # and non-dense families have no explicit-TP stage path
    with pytest.raises(ValueError, match="dense family"):
        make_sharded_train_step(
            get_config("mamba2-1.3b", reduced=True), opt,
            _mesh((2, 2, 2), ("pipe", "data", "model")))
    # unknown schedule names fail eagerly with the valid choices
    with pytest.raises(ValueError, match="gpipe"):
        make_sharded_train_step(
            cfg, opt, _mesh((2, 2, 1), ("pipe", "data", "model")),
            schedule="interleaved")
    # layer stack must split evenly across stages (reduced has 2 layers)
    with pytest.raises(ValueError, match="divisible"):
        make_sharded_train_step(
            cfg.replace(n_layers=2), opt,
            _mesh((4, 2, 1), ("pipe", "data", "model")))
    # non-uniform families are rejected
    with pytest.raises(ValueError, match="family"):
        make_sharded_train_step(
            get_config("zamba2-2.7b", reduced=True), opt,
            _mesh((2, 2, 1), ("pipe", "data", "model")))


# ---------------------------------------------------------------------------
# decode_cache_shardings leaf classification (shapes only, via eval_shape)
# ---------------------------------------------------------------------------

def test_decode_cache_shardings_covers_all_families():
    from repro.configs import get_config
    from repro.serve.decode import init_caches
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("stablelm-3b", "mamba2-1.3b", "deepseek-v2-lite-16b"):
        cfg = get_config(arch, reduced=True)
        caches = jax.eval_shape(lambda: init_caches(cfg, 2, 64))
        sh = shd.decode_cache_shardings(cfg, caches, mesh)
        assert jax.tree.structure(sh) == jax.tree.structure(caches)
