"""Pallas kernel validation: shape/dtype sweeps, interpret=True vs the
pure-jnp oracles in repro.kernels.ref."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.bmm import bmm
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_ff import fused_ff
from repro.kernels.matmul_leakyrelu import matmul_leakyrelu
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.softmax import softmax
from repro.kernels.ssd import ssd

_ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _rand(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.5).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,n,k,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (256, 128, 256, 128, 128, 128),
    (128, 256, 128, 64, 128, 64),
])
def test_matmul_leakyrelu(dtype, m, n, k, bm, bn, bk):
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a, b = _rand(ka, (m, k), dtype), _rand(kb, (k, n), dtype)
    got = matmul_leakyrelu(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.matmul_leakyrelu(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=_ATOL[dtype], rtol=2e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bmm(dtype):
    ka, kb = jax.random.split(jax.random.PRNGKey(1))
    a, b = _rand(ka, (3, 128, 128), dtype), _rand(kb, (3, 128, 128), dtype)
    got = bmm(a, b, bm=64, bn=64, bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref.bmm(a, b), np.float32),
                               atol=_ATOL[dtype], rtol=2e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_ff(dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    x = _rand(ks[0], (128, 128), dtype)
    wg, wu = _rand(ks[1], (128, 128), dtype), _rand(ks[2], (128, 128), dtype)
    got = fused_ff(x, wg, wu, bm=64, bn=64, bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref.fused_ff(x, wg, wu), np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=3e-2)


@pytest.mark.parametrize("rows,cols,br", [(512, 4096, 8), (64, 1024, 16)])
def test_softmax_paper_config(rows, cols, br):
    x = _rand(jax.random.PRNGKey(3), (rows, cols), jnp.float32) * 4
    got = softmax(x, br=br, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.softmax(x)),
                               atol=2e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(dtype):
    x = _rand(jax.random.PRNGKey(4), (64, 2048), dtype)
    g = _rand(jax.random.PRNGKey(5), (2048,), dtype) + 1.0
    got = rmsnorm(x, g, br=8, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref.rmsnorm(x, g), np.float32),
                               atol=_ATOL[dtype], rtol=2e-2)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("s,d,bq,bk", [(256, 64, 128, 128), (512, 32, 128, 256)])
def test_flash_attention(causal, s, d, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = _rand(ks[0], (1, 4, s, d), jnp.float32)
    k = _rand(ks[1], (1, 4, s, d), jnp.float32)
    v = _rand(ks[2], (1, 4, s, d), jnp.float32)
    got = flash_attention(q, k, v, bq=bq, bk=bk, causal=causal,
                          interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_flash_attention_paper_config():
    """Table 2: B=1, n_head=4, seq_len=4096, d_head=32 (scaled down 4x in
    sequence to keep interpret-mode CI time sane)."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = _rand(ks[0], (1, 4, 1024, 32), jnp.bfloat16)
    k = _rand(ks[1], (1, 4, 1024, 32), jnp.bfloat16)
    v = _rand(ks[2], (1, 4, 1024, 32), jnp.bfloat16)
    got = flash_attention(q, k, v, bq=128, bk=128, interpret=True)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)


@pytest.mark.parametrize("chunk", [32, 64])
def test_ssd_vs_scan_oracle(chunk):
    BH, S, P, N = 2, 128, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(8), 4)
    x = _rand(ks[0], (BH, S, P), jnp.float32)
    a = -jnp.abs(_rand(ks[1], (BH, S), jnp.float32)) * 0.2
    b = _rand(ks[2], (BH, S, N), jnp.float32)
    c = _rand(ks[3], (BH, S, N), jnp.float32)
    got = ssd(x, a, b, c, chunk=chunk, interpret=True)
    want = ref.ssd_chunk(x[:, :, None, :], a[:, :, None],
                         b[:, :, None, :], c[:, :, None, :])[:, :, 0, :]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)
