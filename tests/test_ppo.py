"""PPO component tests: GAE vs numpy reference, masked sampling, learning
on a tiny budget."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ppo import (PPOConfig, compute_gae, init_agent,
                            masked_entropy, sample_action)


def _gae_numpy(rewards, values, dones, last_value, gamma, lam):
    T, B = rewards.shape
    adv = np.zeros((T, B), np.float32)
    next_adv = np.zeros(B, np.float32)
    next_val = last_value
    for t in range(T - 1, -1, -1):
        nonterm = 1.0 - dones[t]
        delta = rewards[t] + gamma * next_val * nonterm - values[t]
        next_adv = delta + gamma * lam * nonterm * next_adv
        adv[t] = next_adv
        next_val = values[t]
    return adv, adv + values


def test_gae_matches_numpy_reference():
    rng = np.random.default_rng(0)
    T, B = 17, 5
    rewards = rng.standard_normal((T, B)).astype(np.float32)
    values = rng.standard_normal((T, B)).astype(np.float32)
    dones = (rng.random((T, B)) < 0.15).astype(np.float32)
    last = rng.standard_normal(B).astype(np.float32)
    adv, ret = compute_gae(rewards, values, dones, last, 0.99, 0.95)
    adv_np, ret_np = _gae_numpy(rewards, values, dones, last, 0.99, 0.95)
    np.testing.assert_allclose(np.asarray(adv), adv_np, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ret), ret_np, atol=1e-5)


def test_masked_sampling_never_invalid():
    key = jax.random.PRNGKey(0)
    params = init_agent(key, n_rows=40, feat_dim=12, num_actions=10)
    state = jax.random.normal(key, (16, 40, 12))
    mask = np.zeros((16, 10), np.float32)
    mask[:, [1, 4, 7]] = 1.0
    for s in range(5):
        a, logp, v = sample_action(params, jax.random.PRNGKey(s), state,
                                   jnp.asarray(mask))
        assert set(np.asarray(a).tolist()) <= {1, 4, 7}
        assert np.isfinite(np.asarray(logp)).all()


def test_masked_entropy_bounds():
    logits = jnp.zeros((4, 8))
    mask = jnp.asarray(np.tile([1, 1, 1, 1, 0, 0, 0, 0], (4, 1)),
                       jnp.float32)
    ent = masked_entropy(logits, mask)
    np.testing.assert_allclose(np.asarray(ent), np.log(4.0), atol=1e-5)


def test_ppo_learns_on_kernel(stall_db, kernel_programs):
    """A small budget must already raise episodic return above the initial
    (near-zero) level — the qualitative Fig. 8 claim."""
    from repro.core.game import train_on_program
    cfg = PPOConfig(total_timesteps=2048, num_envs=4, num_steps=64,
                    episode_length=48, seed=0)
    res = train_on_program(kernel_programs["rmsnorm"], stall_db=stall_db,
                           cfg=cfg)
    assert res.best_cycles <= res.baseline_cycles
    assert res.improvement >= 0.0
    assert len(res.stats) == cfg.num_updates
    for row in res.stats:
        assert np.isfinite(row["approx_kl"]) and np.isfinite(row["entropy"])
    # learning signal: the last update's return exceeds the first's
    assert res.stats[-1]["episodic_return"] >= res.stats[0]["episodic_return"]
