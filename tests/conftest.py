"""Shared fixtures.  NOTE: no XLA device-count flags here — smoke tests and
benches must see the host's single device; multi-device tests spawn
subprocesses with their own XLA_FLAGS (see tests/test_dist.py)."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.fixture(scope="session")
def stall_db():
    from repro.core import build_stall_table
    return build_stall_table()


@pytest.fixture(scope="session")
def kernel_programs(stall_db):
    """name -> -O3 baseline program for every kernel (first config)."""
    from repro.kernels import KERNELS
    from repro.sched import lower, schedule
    out = {}
    for name, kdef in KERNELS.items():
        out[name] = schedule(lower(kdef.make_spec(kdef.configs[0])))
    return out


def run_in_subprocess(code: str, n_devices: int = 8, timeout: int = 600):
    """Run python code in a fresh process with a forced host device count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n--- stdout ---\n"
            f"{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_in_subprocess
