"""State-embedding unit tests (paper §3.4): feature-dim consistency,
padding / validity invariants, determinism, and the overflow guard."""

import numpy as np
import pytest

from repro.core import embedding
from repro.core.analysis import analyze
from repro.core.isa import NUM_SEMAPHORES


@pytest.fixture(scope="module")
def cases(stall_db, kernel_programs):
    """(name, program, analysis) for two structurally different kernels."""
    out = []
    for name in ("matmul_leakyrelu", "rmsnorm"):
        prog = kernel_programs[name]
        out.append((name, prog, analyze(prog, stall_db)))
    return out


def test_fixed_features_matches_row_layout():
    # valid + wait bits + read/write bar + yield + stall + is_mem + pred
    assert embedding.FIXED_FEATURES == 1 + NUM_SEMAPHORES + 2 + 1 + 1 + 1 + 1
    assert embedding.fixed_feature_dim() == embedding.FIXED_FEATURES


def test_feature_dim_consistency(cases):
    for name, prog, analysis in cases:
        f = embedding.feature_dim(analysis)
        assert f == embedding.FIXED_FEATURES + analysis.max_operands
        row = embedding.embed_instruction(prog[0], analysis)
        assert row.shape == (f,)
        mat = embedding.embed_program(prog, analysis)
        assert mat.shape == (len(prog), f)
        assert mat.dtype == np.float32


def test_fixed_prefix_is_kernel_independent(cases):
    # the aggregate featurizer (repro.costmodel.dataset) leans on exactly
    # this: the first FIXED_FEATURES columns mean the same thing for every
    # kernel even though the full row width differs
    for _, prog, analysis in cases:
        mat = embedding.embed_program(prog, analysis)
        fixed = mat[:, :embedding.FIXED_FEATURES]
        assert np.all(fixed[:, 0] == 1.0)                     # valid
        wait = fixed[:, 1:1 + NUM_SEMAPHORES]
        assert set(np.unique(wait)) <= {0.0, 1.0}             # wait bits
        assert np.all(fixed[:, 1 + NUM_SEMAPHORES:3 + NUM_SEMAPHORES] >= -1)
        assert np.all(fixed[:, 4 + NUM_SEMAPHORES] >= 0)      # stall / 16
        assert set(np.unique(fixed[:, 5 + NUM_SEMAPHORES])) <= {-1.0, 1.0}


def test_padding_rows_are_invalid(cases):
    _, prog, analysis = cases[0]
    n, rows = len(prog), len(prog) + 7
    mat = embedding.embed_program(prog, analysis, n_rows=rows)
    assert mat.shape == (rows, embedding.feature_dim(analysis))
    assert np.all(mat[:n, 0] == 1.0)        # real rows marked valid
    assert np.all(mat[n:, 0] == 0.0)        # padding rows marked invalid
    assert np.all(mat[n:, 1:] == -1.0)      # padding features are the fill
    # padding does not disturb the real rows
    np.testing.assert_array_equal(mat[:n], embedding.embed_program(
        prog, analysis))


def test_embedding_is_deterministic(cases):
    for _, prog, analysis in cases:
        a = embedding.embed_program(prog, analysis, n_rows=len(prog) + 3)
        b = embedding.embed_program(prog, analysis, n_rows=len(prog) + 3)
        np.testing.assert_array_equal(a, b)


def test_program_longer_than_rows_raises(cases):
    _, prog, analysis = cases[0]
    with pytest.raises(ValueError, match="longer than"):
        embedding.embed_program(prog, analysis, n_rows=len(prog) - 1)
