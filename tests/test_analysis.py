"""Static-analysis pass tests (§3.2): stall inference, denylist, tables."""

from repro.core import analyze
from repro.core.machine import true_fixed_latency
from repro.core.parser import parse_program

_PROG = """
[B------:R-:W-:-:S08] SMOV UR16, 0x0 ;
[B------:R-:W-:-:S08] SMOV UR2, 0x0 ;
[B------:R-:W-:-:S05] SMULW R4.64, R0, 0x1000 ;
[B------:R-:W-:-:S01] LABEL L0 ;
[B------:R-:W-:-:S04] SADD R8, R8, 0x40 ;
[B------:R-:W-:-:S04] SADDX R9, R9, RZ ;
[B------:R-:W2:-:S08] CPYIN.4096 [UR2+0x0], desc[UR16][R8.64] ; // tile=in_a:1 grp=1
[B------:R-:W3:-:S08] CPYIN.4096 [UR2+0x1000], desc[UR16][R4.64] ; // tile=in_b:1 grp=2
[B--23--:R-:W4:-:S08] LDV R40, [UR2+0x0] ; // tile=in_a:1
[B------:R-:W-:-:S01] EXIT ;
"""


def test_resolution_classes(stall_db):
    ana = analyze(parse_program(_PROG), stall_db)
    fr = ana.resolution_fractions()
    # SADD producer is in the db; SADDX is inferred; the R4.64 CPYIN's
    # producer (SMULW) is across the label -> denylist
    assert fr["db"] > 0 and fr["infer"] > 0 and fr["denylist"] > 0
    deny = list(ana.denylist)
    assert len(deny) == 1
    assert "R4" in parse_program(_PROG)[deny[0]].operands[1]


def test_inferred_stall_is_safe_overestimate(stall_db, kernel_programs):
    """The original schedule is valid, so inferred values are >= the true
    latency (the paper: 'either overestimated or exact')."""
    for name, prog in kernel_programs.items():
        ana = analyze(prog, stall_db)
        for opcode, inferred in ana.stall_table.items():
            if opcode in stall_db:
                continue
            true = true_fixed_latency(opcode)
            if true is not None:
                assert inferred >= true, (name, opcode, inferred, true)


def test_saddx_inference_matches_paper_anecdote(stall_db, kernel_programs):
    """§3.2: IADD3.X inferred from schedules, close to the true value."""
    ana = analyze(kernel_programs["rmsnorm"], stall_db)
    assert "SADDX" in ana.stall_table
    true = true_fixed_latency("SADDX")
    assert true <= ana.stall_table["SADDX"] <= true + 2


def test_uniform_registers_excluded(stall_db):
    ana = analyze(parse_program(_PROG), stall_db)
    for (i, key), _ in ana.resolution.items():
        if isinstance(key, str):
            assert not key.startswith("UR")


def test_action_space_excludes_denylist(stall_db, kernel_programs):
    for name, prog in kernel_programs.items():
        ana = analyze(prog, stall_db)
        assert ana.mem_slots, name
        assert not (set(ana.mem_slots) & ana.denylist), name
        for i in ana.mem_slots:
            assert prog[i].is_schedulable()


def test_embedding_tables(stall_db, kernel_programs):
    from repro.core.embedding import embed_program, feature_dim
    prog = kernel_programs["softmax"]
    ana = analyze(prog, stall_db)
    assert ana.max_operands >= 2 and len(ana.reg_table) > 0
    emb = embed_program(prog, ana)
    assert emb.shape == (len(prog), feature_dim(ana))
    assert (emb[:, 0] == 1.0).all()          # validity column
    padded = embed_program(prog, ana, n_rows=len(prog) + 7)
    assert (padded[len(prog):, 0] == 0.0).all()
