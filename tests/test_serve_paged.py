"""Paged KV serving: physical block-pool pages behind per-request block
tables.  Covers bit-exactness of the paged decode path vs the static
``generate()`` reference (dense/windowed/SSM/MLA families), copy-free
spill preemption-resume, hash-based prefix sharing with copy-on-write,
the block-geometry edge cases (block_size=1, max_seq not a multiple of
the block size, prompts ending exactly on a block boundary), pool
invariants under churn, and the zero-measurement guarantee on the paged
hot path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve import (KVBlockPool, ServeEngine, TrafficConfig, generate,
                         poisson_trace)

MAX_SEQ = 48


def _model(arch):
    cfg = get_config(arch, reduced=True)
    return cfg, lm.init_model(cfg, jax.random.PRNGKey(0))


def _refs(params, cfg, prompts, n, max_seq=MAX_SEQ):
    """Batched static-path reference (equal lengths -> one compile)."""
    out = generate(params, cfg, jnp.asarray(prompts, jnp.int32), n,
                   max_seq=max_seq)
    return [row.tolist() for row in np.asarray(out)]


def _ref_one(params, cfg, prompt, n, max_seq=MAX_SEQ):
    out = generate(params, cfg, np.asarray(prompt, np.int32)[None], n,
                   max_seq=max_seq)
    return np.asarray(out)[0, len(prompt):].tolist()


# ---------------------------------------------------------------------------
# paged == dense == generate(), across cache families
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen1.5-4b", "gemma3-1b", "mamba2-1.3b"])
def test_paged_bitexact_vs_sequential_generate(arch):
    """The block-table indirection must not change a single logit:
    per-request streams under paged continuous batching (staggered
    arrivals, slot churn) match the one-request-at-a-time static path.
    Covers absolute caches (qwen), ring-buffer windows re-expressed as
    trailing page windows (gemma), and slot-major recurrent state riding
    next to paged attention leaves (mamba)."""
    cfg, params = _model(arch)
    engine = ServeEngine.from_config(cfg, params=params, max_batch=3,
                                     max_seq=MAX_SEQ, block_size=8,
                                     prefill_chunk=2, paged=True,
                                     debug_invariants=True)
    rng = np.random.default_rng(0)
    jobs = []
    for _ in range(4):
        plen, n = int(rng.integers(3, 14)), int(rng.integers(2, 10))
        jobs.append((rng.integers(0, cfg.vocab, plen,
                                  dtype=np.int32).tolist(), n))
    reqs = [engine.submit(p, n) for p, n in jobs[:2]]
    for _ in range(3):
        engine.step()
    reqs += [engine.submit(p, n) for p, n in jobs[2:]]
    engine.run()
    for req, (prompt, n) in zip(reqs, jobs):
        assert req.output == _ref_one(params, cfg, prompt, n), \
            f"request {req.id} diverged under paged decode"
        assert len(req.output) == n and not req.truncated
    assert engine.pool.stats()["free_blocks"] == engine.pool.num_blocks
    engine.pool.check()


def test_paged_mla_decode_bitexact():
    """The MLA paged path (latent c_kv + shared k_rope pages) matches the
    dense MLA decode stream."""
    cfg, params = _model("deepseek-v2-lite-16b")
    engine = ServeEngine.from_config(cfg, params=params, max_batch=2,
                                     max_seq=32, block_size=8, paged=True,
                                     debug_invariants=True)
    jobs = [([1, 2, 3, 4, 5], 6), ([9, 8, 7], 5)]
    reqs = [engine.submit(p, n) for p, n in jobs]
    engine.run()
    for req, (prompt, n) in zip(reqs, jobs):
        assert req.output == _ref_one(params, cfg, prompt, n, max_seq=32)


# ---------------------------------------------------------------------------
# copy-free preemption: spill to host, resume by remap
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen1.5-4b", "mamba2-1.3b"])
def test_spill_preemption_resumes_bitexact_without_recompute(arch):
    """With the pool oversubscribed, stalled victims are spilled —
    their pages copied to host and blocks freed — and later resumed by
    re-uploading into fresh blocks.  Streams stay bit-exact and no
    request is ever teacher-force recomputed (``resume_tokens`` stays
    empty; that is the dense path's preemption)."""
    cfg, params = _model(arch)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, 12).tolist() for _ in range(3)]
    refs = _refs(params, cfg, prompts, 20)
    engine = ServeEngine.from_config(cfg, params=params, max_batch=4,
                                     max_seq=MAX_SEQ, block_size=8,
                                     kv_blocks=6, paged=True,
                                     share_prefix=False,
                                     debug_invariants=True)
    reqs = [engine.submit(p, 20) for p in prompts]
    engine.run(max_steps=5000)
    assert engine.counters["preempt_spills"] > 0, "pool never pressured"
    assert engine.counters["resume_uploads"] > 0
    for req, ref in zip(reqs, refs):
        assert list(req.prompt) + list(req.output) == ref
        assert not req.truncated
        assert not req.resume_tokens, "spill resume must not recompute"


# ---------------------------------------------------------------------------
# prefix sharing + copy-on-write
# ---------------------------------------------------------------------------

def test_prefix_sharing_cow_under_concurrent_decode():
    """A resident request's prompt blocks (including the partial tail
    block, registered under the whole-prompt key) are shared by later
    identical/prefix-matching admissions; the sharer's first private
    write copy-on-write-forks the shared partial block, and all three
    concurrent streams stay bit-exact."""
    cfg, params = _model("qwen1.5-4b")
    engine = ServeEngine.from_config(cfg, params=params, max_batch=3,
                                     max_seq=MAX_SEQ, block_size=8,
                                     paged=True, debug_invariants=True)
    rng = np.random.default_rng(7)
    base = rng.integers(0, cfg.vocab, 10).tolist()     # 1 full + partial
    tail = rng.integers(0, cfg.vocab, 3).tolist()
    a = engine.submit(base, 8)
    for _ in range(6):                    # A prefills + starts decoding
        engine.step()
    assert a.first_token_time is not None
    b = engine.submit(base, 8)            # identical prompt: shares 10
    c = engine.submit(base[:8] + tail, 8)  # shares the full block only
    engine.run()

    assert engine.counters["prefix_hits"] == 2
    stats = engine.pool.stats()
    assert stats["shared_tokens_reused"] == 10 + 8
    assert engine.counters["cow_forks"] >= 1, \
        "B's first private write must fork the shared partial block"
    for req, prompt in ((a, base), (b, base), (c, base[:8] + tail)):
        assert req.output == _ref_one(params, cfg, prompt, 8), \
            f"request {req.id} diverged under prefix sharing"
    assert engine.pool.stats()["free_blocks"] == engine.pool.num_blocks
    engine.pool.check()


def test_shared_prefix_trace_generator_is_seeded_and_layered():
    """loadgen: ``prefix_tokens`` draws from a separate rng stream, so
    the base trace (arrivals, lengths, suffixes) replays token-for-token
    identically with the knob on or off, and the Zipf group choice
    concentrates reuse on the hottest prefix."""
    base = poisson_trace(TrafficConfig(seed=7, n_requests=16))
    pref = poisson_trace(TrafficConfig(seed=7, n_requests=16,
                                       prefix_tokens=16, prefix_groups=4))
    assert [a.at for a in base] == [a.at for a in pref]
    assert all(p.prompt[16:] == b.prompt
               and p.max_new_tokens == b.max_new_tokens
               for b, p in zip(base, pref))
    heads = [tuple(a.prompt[:16]) for a in pref]
    assert len(set(heads)) <= 4
    hottest = max(set(heads), key=heads.count)
    assert heads.count(hottest) >= len(heads) / 4    # Zipf skew
    again = poisson_trace(TrafficConfig(seed=7, n_requests=16,
                                        prefix_tokens=16, prefix_groups=4))
    assert [a.prompt for a in again] == [a.prompt for a in pref]


# ---------------------------------------------------------------------------
# block-geometry edges
# ---------------------------------------------------------------------------

def test_block_size_one():
    """One token per page: every advance grows the table by one block."""
    cfg, params = _model("qwen1.5-4b")
    engine = ServeEngine.from_config(cfg, params=params, max_batch=2,
                                     max_seq=24, block_size=1, paged=True,
                                     debug_invariants=True)
    jobs = [([3, 1, 4, 1, 5], 6), ([2, 7], 5)]
    reqs = [engine.submit(p, n) for p, n in jobs]
    engine.run()
    for req, (prompt, n) in zip(reqs, jobs):
        assert req.output == _ref_one(params, cfg, prompt, n, max_seq=24)
    assert engine.pool.stats()["free_blocks"] == engine.pool.num_blocks


def test_max_seq_not_multiple_of_block_size():
    """max_seq=42 over 8-token blocks: the last block is only partially
    addressable; truncation still lands exactly at max_seq."""
    cfg, params = _model("qwen1.5-4b")
    engine = ServeEngine.from_config(cfg, params=params, max_batch=2,
                                     max_seq=42, block_size=8, paged=True,
                                     debug_invariants=True)
    req = engine.submit([5, 4, 3, 2, 1, 0], 60)        # must truncate
    engine.run()
    assert req.truncated
    # every cache position 0..41 is written; the final emitted token
    # rides without a cache slot, so the stream is max_seq + 1 long
    assert len(req.prompt) + len(req.output) == 43
    ref = _ref_one(params, cfg, [5, 4, 3, 2, 1, 0], 60, max_seq=42)
    assert req.output == ref[:len(req.output)]


def test_prompt_exactly_fills_last_block():
    """A 16-token prompt at block_size=8 ends on a block boundary: the
    first generated token's write opens a fresh block, and when the
    whole prompt is full blocks the partial-tail registration is a
    no-op (everything shareable is already keyed)."""
    cfg, params = _model("qwen1.5-4b")
    engine = ServeEngine.from_config(cfg, params=params, max_batch=2,
                                     max_seq=MAX_SEQ, block_size=8,
                                     paged=True, debug_invariants=True)
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, 16).tolist()
    a = engine.submit(prompt, 6)
    for _ in range(8):
        engine.step()
    assert a.first_token_time is not None
    b = engine.submit(prompt, 6)          # shares both full prompt blocks
    engine.run()
    assert engine.counters["prefix_hits"] == 1
    assert engine.pool.stats()["shared_tokens_reused"] == 16
    for req in (a, b):
        assert req.output == _ref_one(params, cfg, prompt, 6)


# ---------------------------------------------------------------------------
# pool-level invariants under churn
# ---------------------------------------------------------------------------

def test_paged_pool_invariants_under_shared_churn():
    """Seeded alloc_shared/advance/commit/free churn with overlapping
    prompts: refcounts, registry keys, and block accounting hold after
    every operation (``check()`` raises on any violation)."""
    rng = np.random.default_rng(13)
    pool = KVBlockPool(num_blocks=24, block_size=4, max_seq=32,
                       num_slots=6)
    prompts = {}
    live = {}
    next_id = 0
    for _ in range(300):
        op = rng.choice(["admit", "advance", "free"])
        if op == "admit" and len(live) < 6:
            plen = int(rng.integers(2, 12))
            if rng.random() < 0.5 and prompts:
                donor = prompts[int(rng.choice(list(prompts)))]
                prompt = (donor + [int(x) for x in
                                   rng.integers(0, 50, 2)])[:plen] \
                    if plen > len(donor) else donor[:plen]
            else:
                prompt = [int(x) for x in rng.integers(0, 50, plen)]
            if pool.can_admit_shared(prompt):
                t = pool.alloc_shared(next_id, prompt)
                live[next_id] = [len(prompt), prompt]
                prompts[next_id] = prompt
                next_id += 1
        elif op == "advance" and live:
            rid = int(rng.choice(list(live)))
            pos, prompt = live[rid]
            if pos < 32 and pool.can_advance(rid, pos, write=True):
                pool.advance(rid, pos, write=True)
                tokens = prompt + [int(x) for x in
                                   rng.integers(0, 50, pos + 1)]
                pool.commit(rid, tokens[:pos + 1], pos,
                            prompt_len=len(prompt))
                live[rid][0] = pos + 1
        elif op == "free" and live:
            rid = int(rng.choice(list(live)))
            pool.free(rid)
            del live[rid], prompts[rid]
        pool.check()
    for rid in list(live):
        pool.free(rid)
    pool.check()
    assert pool.stats()["free_blocks"] == pool.num_blocks


# ---------------------------------------------------------------------------
# zero-measurement paged hot path
# ---------------------------------------------------------------------------

def test_paged_serve_hot_path_zero_measurements(tmp_path, stall_db,
                                                monkeypatch):
    """The paged engine keeps the serve-path guarantee: schedules are
    index lookups — zero ``Machine.run``/``Machine.time``/autotune calls
    while serving (prefix sharing and spills included)."""
    import sys

    from repro.core import Machine
    from repro.sched import OptimizationSession, make_budgeted_strategy
    from repro.sched.cache import ScheduleCache
    from repro.sched.session import OptimizeRequest

    session = OptimizationSession(
        strategy=make_budgeted_strategy("greedy", timesteps=64,
                                        episode_length=8),
        cache_dir=str(tmp_path / "cache"), stall_db=stall_db,
        verify_seeds=2)
    session.optimize(OptimizeRequest(kernel="rmsnorm"))

    calls = {"run": 0, "time": 0, "autotune": 0}
    real_run, real_time = Machine.run, Machine.time
    autotune_mod = sys.modules["repro.sched.autotune"]

    def counting(name, fn):
        def wrapper(*a, **kw):
            calls[name] += 1
            return fn(*a, **kw)
        return wrapper

    monkeypatch.setattr(Machine, "run", counting("run", real_run))
    monkeypatch.setattr(Machine, "time", counting("time", real_time))
    monkeypatch.setattr(autotune_mod, "autotune",
                        counting("autotune", autotune_mod.autotune))

    cfg, params = _model("qwen1.5-4b")
    engine = ServeEngine.from_config(
        cfg, params=params, max_batch=2, max_seq=32, block_size=8,
        paged=True, debug_invariants=True,
        schedule_cache=ScheduleCache(str(tmp_path / "cache")))
    engine.submit([1, 2, 3, 4], 4)
    engine.submit([1, 2, 3, 4], 4)       # shares the admission prefix
    engine.run()
    assert calls == {"run": 0, "time": 0, "autotune": 0}
