"""Assembly-game environment mechanics (§3.3–§3.6)."""

import numpy as np
import pytest

from repro.core import AssemblyGame


def test_reward_is_eq3(stall_db, kernel_programs):
    env = AssemblyGame(kernel_programs["rmsnorm"], stall_db=stall_db)
    env.reset()
    va = env.valid_actions()
    assert va
    prev = env.prev_cycles
    _, reward, _, info = env.step(va[0])
    expected = (prev - info["cycles"]) / env.t0 * 100.0
    assert reward == pytest.approx(expected)


def test_episode_terminates_at_length(stall_db, kernel_programs):
    env = AssemblyGame(kernel_programs["softmax"], stall_db=stall_db,
                       episode_length=5)
    env.reset()
    rng = np.random.default_rng(0)
    done = False
    for t in range(5):
        va = env.valid_actions()
        if not va:
            done = True
            break
        _, _, done, _ = env.step(int(rng.choice(va)))
    assert done


def test_best_survives_reset(stall_db, kernel_programs):
    env = AssemblyGame(kernel_programs["ssd"], stall_db=stall_db,
                       episode_length=30)
    rng = np.random.default_rng(0)
    env.reset()
    for _ in range(30):
        va = env.valid_actions()
        if not va:
            break
        env.step(int(rng.choice(va)))
    best_after_ep1 = env.best_cycles
    env.reset()
    assert env.best_cycles <= best_after_ep1


def test_invalid_action_raises(stall_db, kernel_programs):
    env = AssemblyGame(kernel_programs["rmsnorm"], stall_db=stall_db)
    env.reset()
    mask = env.action_mask()
    invalid = int(np.argmin(mask))
    if mask[invalid] == 0:
        with pytest.raises(ValueError):
            env.step(invalid)


def test_slot_positions_track_instructions(stall_db, kernel_programs):
    env = AssemblyGame(kernel_programs["flash_attention"], stall_db=stall_db)
    env.reset()
    # every slot's position must point at a schedulable memory instruction
    for k, pos in env.slot_pos.items():
        assert env.program[pos].is_schedulable()
    rng = np.random.default_rng(4)
    for _ in range(20):
        va = env.valid_actions()
        if not va:
            break
        env.step(int(rng.choice(va)))
    for k, pos in env.slot_pos.items():
        assert env.program[pos].is_schedulable()


def test_obs_shapes_and_mask(stall_db, kernel_programs):
    env = AssemblyGame(kernel_programs["bmm"], stall_db=stall_db)
    obs = env.reset()
    assert obs["state"].shape == (env.n, env.feature_dim)
    assert obs["mask"].shape == (env.num_actions,)
    assert set(np.unique(obs["mask"])) <= {0.0, 1.0}
